"""Benchmark entry point: one section per paper table/figure plus the
device tier and the roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (one per measurement) and a
human-readable summary. ``--full`` lengthens runs; default is quick mode.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _csv_rows(rows, key_metric="p99.99", scale=1000.0):
    out = []
    for r in rows:
        name = r.get("figure", "bench")
        for k in ("query", "rate", "nodes", "mode", "jobs", "batch"):
            if k in r:
                name += f".{k}={r[k]}"
        if r.get(key_metric) is not None:
            us = r[key_metric] * scale       # ms -> us
        elif "p99.9" in r:
            # p99.99 reported unreliable (<10k samples): fall back a decade
            us = (r["p99.9"] or 0.0) * scale
        elif "us_per_call" in r:
            us = r["us_per_call"]
        elif "us_per_step" in r:
            us = r["us_per_step"]
        else:
            us = 0.0
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("figure",))
        out.append(f"{name},{us:.3f},{derived}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="smoke target: only the p99.99 latency harness "
                         "(both tiers); emits BENCH_latency.json")
    ap.add_argument("--skip-host", action="store_true",
                    help="skip the wall-clock host-tier figures")
    ap.add_argument("--backend", choices=("inproc", "mp"), default="inproc",
                    help="execution substrate for the paced host-tier run: "
                         "cooperative in-process simulation (default) or "
                         "real worker processes over shared-memory rings")
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="cooperative threads (inproc) / worker processes "
                         "(mp) for the paced host-tier run; default 2")
    args = ap.parse_args()
    quick = not args.full

    from . import bench_device_tier, bench_figures, bench_latency, roofline

    all_rows = []
    print("name,us_per_call,derived")

    latency_rows = lambda: bench_latency.rows(  # noqa: E731
        quick=quick, backend=args.backend, workers=args.workers)
    if args.quick:
        # CI smoke target: the latency harness alone keeps the perf
        # trajectory (BENCH_latency.json) accumulating per PR; it runs
        # the host tier (both substrates: inproc + mp saturation curve),
        # the device tier AND the host_to_device bridge (the device-placed
        # window vertex), taking precedence over --skip-host
        sections = [("latency", latency_rows)]
    else:
        sections = []
        if not args.skip_host:
            # the latency harness drives the wall-clock host tier too
            sections.append(("latency", latency_rows))
            sections += [
                ("fig7",
                 lambda: bench_figures.fig7_throughput_vs_latency(quick)),
                ("fig8", lambda: bench_figures.fig8_scaleout_latency(quick)),
                ("fig9",
                 lambda: bench_figures.fig9_latency_distribution(quick)),
                ("fig10",
                 lambda: bench_figures.fig10_scaleout_throughput(quick)),
                ("fig13",
                 lambda: bench_figures.fig13_fault_tolerance_overhead(quick)),
                ("sec7.7", lambda: bench_figures.sec77_multitenancy(quick)),
            ]
        sections += [
            ("device_q5",
             lambda: bench_device_tier.bench_vector_q5(quick=quick)),
            ("kernels", lambda: bench_device_tier.bench_kernels(quick=quick)),
        ]

    for name, fn in sections:
        try:
            rows = fn()
        except Exception as e:  # pragma: no cover
            print(f"{name},0.0,ERROR={e!r}", flush=True)
            continue
        all_rows.extend(rows)
        for line in _csv_rows(rows):
            print(line, flush=True)

    # roofline summary (from the dry-run artifacts, if present)
    rl = roofline.full_table()
    for r in rl:
        print(f"roofline.{r['arch']}.{r['shape']},"
              f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.1f},"
              f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};"
              f"bound={r['roofline_fraction_bound']:.3f};"
              f"gib={r['temp_gib_per_chip']:.1f}", flush=True)

    out = pathlib.Path(__file__).resolve().parents[1] / "experiments"
    out.mkdir(exist_ok=True)
    (out / "bench_results.json").write_text(
        json.dumps({"figures": all_rows, "roofline": rl}, indent=1,
                   default=float))
    print(f"# wrote {out / 'bench_results.json'}", file=sys.stderr)
    # the latency section appends the per-run record (git SHA, saturation
    # A/B, paced + device percentiles) to the cumulative cross-PR log
    traj = out.parent / "BENCH_trajectory.json"
    if traj.exists():
        n = len(json.loads(traj.read_text()))
        print(f"# perf trajectory: {traj} ({n} records)", file=sys.stderr)


if __name__ == "__main__":
    main()
