"""Device-tier throughput: the compiled datapath (vectorized Q5) on one
CPU core, reproducing the paper's events/second/core claim in compiled
form, plus kernel micro-benchmarks (jnp reference timings on CPU; the
Pallas kernels themselves target TPU and are validated in interpret mode).
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.streaming import (StreamExecutor, StreamJobConfig,
                             VectorWindowSpec)


def bench_vector_q5(n_keys: int = 4096, steps: int = 50,
                    quick=True) -> List[Dict]:
    """Events/s/core of the fused accumulate+combine+emit step at the
    paper-extreme Q5 config (1 s window, 10 ms slide); each step advances
    10 ms of event time so windows emit continuously."""
    if quick:
        steps = 30
    rows = []
    for batch in (8192, 65536):
        spec = VectorWindowSpec(size_ms=1000, slide_ms=10,
                                n_key_buckets=n_keys,
                                max_windows_per_step=2, ring_margin=8)
        ex = StreamExecutor(StreamJobConfig(window=spec, batch_size=batch))
        rng = np.random.RandomState(0)
        batches = []
        for i in range(steps + 1):
            ts = i * 10 + np.sort(rng.randint(0, 10, batch)).astype(np.int32)
            batches.append({
                "ts": jnp.asarray(ts),
                "key": jnp.asarray(rng.randint(0, n_keys, batch),
                                   jnp.int32),
                "value": jnp.ones((batch,), jnp.float32),
                "valid": jnp.ones((batch,), bool),
                "wm": jnp.asarray(-1, jnp.int32)})
        state = ex.init_state()
        state, _ = ex.step(state, batches[0])   # warmup / compile
        jax.block_until_ready(state["panes"])
        t0 = time.perf_counter()
        for b in batches[1:]:
            state, out = ex.step(state, b)
        jax.block_until_ready(state["panes"])
        dt = time.perf_counter() - t0
        ev_s = steps * batch / dt
        rows.append({"figure": "device_q5", "batch": batch, "keys": n_keys,
                     "events_per_sec_per_core": round(ev_s, 0),
                     "us_per_step": round(dt / steps * 1e6, 1)})
    return rows


def bench_kernels(quick=True) -> List[Dict]:
    """CPU timings of the jnp kernel references (compiled); the Pallas
    kernels are TPU-targeted and correctness-checked in interpret mode."""
    from repro.kernels import ref
    rows = []
    n, k, r = 8192, 1024, 16
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, k, n), jnp.int32)
    slots = jnp.asarray(rng.randint(0, r, n), jnp.int32)
    vals = jnp.asarray(rng.rand(n), jnp.float32)
    valid = jnp.ones((n,), bool)
    f = jax.jit(lambda a, b, c, d: ref.window_agg_ref(a, b, c, d, k, r))
    f(keys, slots, vals, valid).block_until_ready()
    t0 = time.perf_counter()
    iters = 20 if quick else 100
    for _ in range(iters):
        out = f(keys, slots, vals, valid)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / iters * 1e6
    rows.append({"figure": "kernel_window_agg", "n": n, "K": k, "R": r,
                 "us_per_call": round(us, 1),
                 "events_per_sec": round(n / (us / 1e6), 0)})

    b, h, s, dh = 4, 8, 4096, 128
    q = jnp.asarray(rng.randn(b, h, dh), jnp.float32)
    kk = jnp.asarray(rng.randn(b, h, s, dh), jnp.float32)
    vv = jnp.asarray(rng.randn(b, h, s, dh), jnp.float32)
    g = jax.jit(lambda a, b_, c: ref.decode_attention_ref(a, b_, c, s - 1))
    g(q, kk, vv).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = g(q, kk, vv)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / iters * 1e6
    rows.append({"figure": "kernel_decode_attn", "B": b, "H": h, "S": s,
                 "us_per_call": round(us, 1)})
    return rows
