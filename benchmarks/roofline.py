"""Roofline analysis per (arch x shape x mesh) cell.

Three terms per the task spec, on TPU v5e constants (197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI):

    compute_term    = FLOPs_per_chip / 197e12
    memory_term     = HBM_bytes_per_chip / 819e9
    collective_term = collective_bytes_per_chip / 50e9

FLOPs/bytes come from an ANALYTIC model of the compiled step (formulas
below), cross-checked against ``compiled.cost_analysis()`` — the CPU
backend counts scan bodies ONCE, so the raw HLO numbers undercount by
~n_layer_groups; both are reported.  Collective bytes likewise: the HLO
text is parsed per instruction (recorded in the dry-run JSONs) and the
analytic schedule (FSDP all-gathers + TP/SP all-reduce pairs + DP grad
reduce-scatter) provides the per-step total.

MODEL_FLOPS (the "useful work" numerator) is 6*N*D for dense training /
6*N_active*D for MoE, 2*N_active*B per decoded token, 2*N_active*D for
prefill — attention context FLOPs and remat recompute count as overhead,
so the ratio MODEL_FLOPS / step_FLOPs exposes remat/causal/capacity waste.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from repro.configs import REGISTRY, SHAPES, applicable_cells
from repro.launch.specs import MICROBATCHES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# analytic step model
# ---------------------------------------------------------------------------

def _attn_layers(cfg) -> int:
    kinds = cfg.layer_kinds() * cfg.n_groups
    return sum(1 for k in kinds if k == "attn")


def _ssm_layers(cfg) -> int:
    kinds = cfg.layer_kinds() * cfg.n_groups
    return sum(1 for k in kinds if k in ("mamba", "rwkv6"))


def _matmul_params(cfg, active: bool) -> int:
    c = cfg.param_counts()
    base = c["active"] if active else c["total"]
    # embedding lookup is a gather, not a matmul; the LM head IS a matmul
    base -= c["embed"]
    if cfg.tie_embeddings:
        base += cfg.vocab_size * cfg.d_model
    return base


def _ctx_flops_fwd(cfg, B, S) -> float:
    """Causal attention context FLOPs, forward (QK^T + PV)."""
    L = _attn_layers(cfg)
    dh, H = cfg.head_dim_, cfg.n_heads
    eff_S = min(S, cfg.swa_window) if cfg.attention == "swa" else S
    # causal: half the S x eff_S rectangle
    return L * 4 * B * S * eff_S * H * dh * 0.5


def _ssm_flops_per_token(cfg) -> float:
    """Recurrent state update FLOPs per token (excludes projections,
    which are in the param count)."""
    L = _ssm_layers(cfg)
    if cfg.ssm_kind == "rwkv6" or cfg.family == "ssm":
        H = cfg.d_model // cfg.rwkv_head_size
        dh = cfg.rwkv_head_size
        per = 6 * H * dh * dh
    else:
        per = 0.0
    if cfg.ssm_kind == "mamba" or cfg.family == "hybrid":
        di = cfg.expand * cfg.d_model
        per = 8 * di * cfg.d_state
    return L * per


def analytic_cell(arch: str, shape: str, chips: int) -> Dict[str, float]:
    cfg = REGISTRY[arch]
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    P_act = _matmul_params(cfg, active=True)
    P_tot = _matmul_params(cfg, active=False)
    cf = cfg.capacity_factor if cfg.n_experts else 1.0
    c = cfg.param_counts()
    n_params_total = c["total"]

    if spec.kind == "train":
        D_tok = B * S
        model_flops = 6 * c["active"] * D_tok
        # fwd + remat-fwd + bwd = 4x fwd matmuls; MoE pays capacity factor
        step_flops = 8 * P_act * cf * D_tok + 4 * _ctx_flops_fwd(cfg, B, S) \
            + 4 * _ssm_flops_per_token(cfg) * D_tok
        # weights: bf16 read x3 (fwd, remat, bwd) + fp32 p/m/v read+write
        # + fp32 grads write+read
        w_bytes = n_params_total * (3 * 2 + 4 * 2 * 4)
        # activations: scan carries + block intermediates, bf16, ~6 copies
        act_bytes = 6 * cfg.n_layers * D_tok * cfg.d_model * 2
        step_bytes = w_bytes + act_bytes
        # collectives per chip: FSDP all-gather (bf16, fwd+bwd) and grad
        # reduce-scatter (fp32) move ~the model-shard's param bytes; TP/SP
        # all-reduce pairs move ~4x the residual stream per layer
        tp = 16
        p_shard = n_params_total / tp
        dp_coll = 2 * p_shard * 2 + p_shard * 4
        tp_coll = 4 * cfg.n_layers * (D_tok / chips * tp) * cfg.d_model * 2 \
            * 2 / tp
        coll_bytes = dp_coll + tp_coll
    elif spec.kind == "prefill":
        D_tok = B * S
        model_flops = 2 * c["active"] * D_tok
        step_flops = 2 * P_act * cf * D_tok + _ctx_flops_fwd(cfg, B, S) \
            + _ssm_flops_per_token(cfg) * D_tok
        cache_bytes = _cache_bytes(cfg, B, S)
        step_bytes = n_params_total * 2 + 4 * cfg.n_layers * D_tok \
            * cfg.d_model * 2 + cache_bytes
        tp = 16
        coll_bytes = 4 * cfg.n_layers * (D_tok / chips * tp) \
            * cfg.d_model * 2 * 2 / tp
    else:  # decode
        model_flops = 2 * c["active"] * B
        step_flops = 2 * P_act * cf * B + _ctx_decode_flops(cfg, B, S) \
            + _ssm_flops_per_token(cfg) * B
        # decode is memory bound: read all weights + the whole KV cache
        step_bytes = n_params_total * 2 + _cache_bytes(cfg, B, S)
        tp = 16
        coll_bytes = 4 * cfg.n_layers * B * cfg.d_model * 2 * 2 / tp
    return {
        "model_flops": model_flops,
        "step_flops": step_flops,
        "step_bytes": step_bytes,
        "coll_bytes_per_chip": coll_bytes,
        "flops_per_chip": step_flops / chips,
        "bytes_per_chip": step_bytes / chips,
    }


def _cache_bytes(cfg, B, S) -> float:
    kinds = cfg.layer_kinds() * cfg.n_groups
    total = 0.0
    for k in kinds:
        if k == "attn":
            eff = min(S, cfg.swa_window) if cfg.attention == "swa" else S
            total += 2 * B * eff * cfg.n_kv_heads * cfg.head_dim_ * 2
        elif k == "mamba":
            di = cfg.expand * cfg.d_model
            total += B * di * cfg.d_state * 4 + B * (cfg.d_conv - 1) * di * 2
        elif k == "rwkv6":
            H = cfg.d_model // cfg.rwkv_head_size
            total += B * H * cfg.rwkv_head_size ** 2 * 4 + 2 * B \
                * cfg.d_model * 2
    return total


def _ctx_decode_flops(cfg, B, S) -> float:
    L = _attn_layers(cfg)
    dh, H = cfg.head_dim_, cfg.n_heads
    eff = min(S, cfg.swa_window) if cfg.attention == "swa" else S
    return L * 4 * B * eff * H * dh


# ---------------------------------------------------------------------------
# table generation
# ---------------------------------------------------------------------------

def load_dryrun(arch: str, shape: str, mesh: str,
                tag: str = "") -> Optional[dict]:
    suffix = f"__{tag}" if tag and tag != "baseline" else ""
    f = DRYRUN_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def roofline_row(arch: str, shape: str, mesh: str = "16x16",
                 tag: str = "") -> Optional[Dict]:
    dr = load_dryrun(arch, shape, mesh, tag)
    if dr is None:
        return None
    chips = dr["chips"]
    a = analytic_cell(arch, shape, chips)
    compute_t = a["flops_per_chip"] / PEAK_FLOPS
    memory_t = a["bytes_per_chip"] / HBM_BW
    coll_t = a["coll_bytes_per_chip"] / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t,
             "collective": coll_t}
    dominant = max(terms, key=terms.get)
    step_t = max(terms.values())
    mfu_bound = (a["model_flops"] / chips / step_t) / PEAK_FLOPS
    return {
        "arch": arch, "shape": shape, "mesh": mesh,
        "kind": dr["kind"], "tag": tag or "baseline",
        "compute_s": compute_t, "memory_s": memory_t,
        "collective_s": coll_t, "dominant": dominant,
        "model_flops": a["model_flops"],
        "step_flops": a["step_flops"],
        "useful_ratio": a["model_flops"] / a["step_flops"],
        "roofline_fraction_bound": mfu_bound,
        "hlo_flops_per_chip_raw": dr["flops"],
        "hlo_coll_bytes_raw": dr["collective_bytes"]["total"],
        "temp_gib_per_chip": dr["memory"]["temp_bytes"] / 2**30,
        "microbatches": dr["meta"].get("microbatches", 1),
    }


def full_table(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    rows = []
    for arch, shape in applicable_cells():
        r = roofline_row(arch, shape, mesh, tag)
        if r:
            rows.append(r)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | kind | compute s | memory s | collective s | "
           "dominant | useful ratio | roofline frac (bound) | GiB/chip |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction_bound']:.2%} "
            f"| {r['temp_gib_per_chip']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = full_table()
    print(markdown_table(rows))
