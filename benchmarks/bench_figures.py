"""Host-tier benchmarks, one per paper figure (§7).

Rates are scaled to a pure-Python single-core datapath; each figure
reports the same metric the paper plots.  ``quick=True`` (the default in
``benchmarks.run``) trims durations to keep the whole suite < ~2 min.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (CollectorSink, JetCluster, JobConfig, Journal,
                        JournalSource, Pipeline, VirtualClock, WallClock,
                        GUARANTEE_EXACTLY_ONCE)
from repro.core.engine import JOB_COMPLETED
from repro.nexmark import NexmarkGenerator, queries
from repro.nexmark.generator import fill_journal
from repro.nexmark.model import Bid

from .common import (LatencySink, _SinkAdapter, percentiles,
                     run_q5_latency)


# ---------------------------------------------------------------------------
# Figure 7: throughput per core vs latency (Q5, small slide, 1 node)
# ---------------------------------------------------------------------------

def fig7_throughput_vs_latency(quick=True) -> List[Dict]:
    rates = [2000, 5000, 10000, 20000] if quick else \
        [2000, 5000, 10000, 20000, 40000, 80000]
    dur = 4.0 if quick else 10.0
    rows = []
    for rate in rates:
        pct, achieved, lats = run_q5_latency(
            rate=rate, duration_s=dur, n_nodes=1, threads=2,
            window_ms=1000, slide_ms=20, n_keys=100)
        rows.append({"figure": "fig7", "rate": rate,
                     "achieved": round(float(achieved), 1),
                     "samples": len(lats), **pct})
    return rows


# ---------------------------------------------------------------------------
# Figure 8: latency vs cluster size at fixed input rate (all queries ~ Q5)
# ---------------------------------------------------------------------------

def fig8_scaleout_latency(quick=True) -> List[Dict]:
    sizes = [1, 2] if quick else [1, 2, 4]
    rows = []
    for n in sizes:
        pct, achieved, lats = run_q5_latency(
            rate=5000, duration_s=3.0 if quick else 8.0, n_nodes=n,
            threads=2, window_ms=1000, slide_ms=50, n_keys=100)
        rows.append({"figure": "fig8", "nodes": n, "dop": n * 2,
                     "samples": len(lats), **pct})
    return rows


# ---------------------------------------------------------------------------
# Figure 9/11/12: latency distribution per query
# ---------------------------------------------------------------------------

def fig9_latency_distribution(quick=True) -> List[Dict]:
    from repro.core import PacedGeneratorSource
    from .common import LatencySink, _SinkAdapter
    rows = []
    rate, dur = 5000, 3.0 if quick else 8.0
    gen = NexmarkGenerator(rate=rate, n_keys=100)

    # Q1 / Q2: stateless — latency is per-event (arrival - ideal emit time)
    for qname, builder in (("q1", queries.q1), ("q2", queries.q2)):
        clock = WallClock()
        cluster = JetCluster(n_nodes=1, cooperative_threads=2, clock=clock)
        t0 = [None]
        sink = LatencySink(clock, t0)
        total = int(rate * dur)
        p = builder(lambda: PacedGeneratorSource(gen, rate=rate,
                                                 max_events=total),
                    lambda: _SinkAdapter(sink))
        t0[0] = clock.now()
        job = cluster.submit(p.to_dag())
        deadline = time.monotonic() + dur * 3 + 10
        while job.status != JOB_COMPLETED and time.monotonic() < deadline:
            cluster.step()
        lats = [(t - (t0[0] + ev.ts / 1000.0)) * 1000.0
                for t, ev in sink.samples]
        lats = lats[len(lats) // 5:]
        rows.append({"figure": "fig9", "query": qname,
                     "samples": len(lats), **percentiles(lats)})

    # Q5: windowed aggregate
    pct, _, lats = run_q5_latency(rate=rate, duration_s=dur, n_nodes=1,
                                  window_ms=1000, slide_ms=50, n_keys=100)
    rows.append({"figure": "fig9", "query": "q5", "samples": len(lats),
                 **pct})

    # Q8: windowed join (persons x auctions)
    clock = WallClock()
    cluster = JetCluster(n_nodes=1, cooperative_threads=2, clock=clock)
    t0 = [None]
    sink = LatencySink(clock, t0)
    total = int(rate * dur)
    p = queries.q8(
        lambda: PacedGeneratorSource(gen, rate=rate, max_events=total),
        lambda: PacedGeneratorSource(gen, rate=rate, max_events=total),
        lambda: _SinkAdapter(sink), window_ms=1000, slide_ms=100)
    t0[0] = clock.now()
    job = cluster.submit(p.to_dag())
    deadline = time.monotonic() + dur * 3 + 10
    while job.status != JOB_COMPLETED and time.monotonic() < deadline:
        cluster.step()
    lats = [(t - (t0[0] + (ev.ts + 1) / 1000.0)) * 1000.0
            for t, ev in sink.samples]
    lats = lats[len(lats) // 5:]
    rows.append({"figure": "fig9", "query": "q8", "samples": len(lats),
                 **percentiles(lats)})

    # Q13: bounded side-input hash join (per-event latency)
    from repro.core import ListSource
    from repro.nexmark.model import Auction
    clock = WallClock()
    cluster = JetCluster(n_nodes=1, cooperative_threads=2, clock=clock)
    t0 = [None]
    sink = LatencySink(clock, t0)
    side = [Auction(i, i + 1, 0, 100, 10_000, 0) for i in range(100)]
    p = queries.q13(
        lambda: PacedGeneratorSource(gen, rate=rate, max_events=total),
        lambda: ListSource(side),
        lambda: _SinkAdapter(sink))
    t0[0] = clock.now()
    job = cluster.submit(p.to_dag())
    deadline = time.monotonic() + dur * 3 + 10
    while job.status != JOB_COMPLETED and time.monotonic() < deadline:
        cluster.step()
    lats = [(t - (t0[0] + ev.ts / 1000.0)) * 1000.0
            for t, ev in sink.samples]
    lats = lats[len(lats) // 5:]
    rows.append({"figure": "fig9", "query": "q13", "samples": len(lats),
                 **percentiles(lats)})
    return rows


# ---------------------------------------------------------------------------
# Figure 10: max throughput vs cluster size (500ms slide)
# ---------------------------------------------------------------------------

def fig10_scaleout_throughput(quick=True) -> List[Dict]:
    """Max sustained events/s per cluster size: calibrated measurement —
    per-node capacity is measured on real wall clock; multi-node runs are
    simulated in-process (all nodes share one core), so we report measured
    single-node capacity and the exchange-overhead-corrected scaling."""
    sizes = [1, 2] if quick else [1, 2, 4]
    rows = []
    base_rate = None
    for n in sizes:
        # binary-search-lite: increase rate until p99 blows past 250ms
        rate, last_good = 4000, 0
        for _ in range(3 if quick else 5):
            pct, achieved, _ = run_q5_latency(
                rate=rate, duration_s=2.5, n_nodes=n, threads=2,
                window_ms=1000, slide_ms=500, n_keys=100)
            if pct["p99"] < 250.0:
                last_good = rate
                rate *= 2
            else:
                break
        if base_rate is None:
            base_rate = last_good
        rows.append({"figure": "fig10", "nodes": n,
                     "max_rate_measured": last_good,
                     "note": "in-process sim shares one core"})
    return rows


# ---------------------------------------------------------------------------
# Figure 13: snapshot overhead (exactly-once, 1s interval)
# ---------------------------------------------------------------------------

def fig13_fault_tolerance_overhead(quick=True) -> List[Dict]:
    rows = []
    for guarantee, label in (("none", "ft-off"),
                             (GUARANTEE_EXACTLY_ONCE, "ft-exactly-once")):
        pct, achieved, lats = run_q5_latency(
            rate=5000, duration_s=3.0 if quick else 8.0, n_nodes=2,
            window_ms=1000, slide_ms=50, n_keys=100,
            guarantee=guarantee, snapshot_interval_s=1.0)
        rows.append({"figure": "fig13", "mode": label,
                     "samples": len(lats), **pct})
    return rows


# ---------------------------------------------------------------------------
# §7.7: multi-tenancy — N concurrent Q5 jobs on one node
# ---------------------------------------------------------------------------

def sec77_multitenancy(quick=True) -> List[Dict]:
    from repro.core import PacedGeneratorSource
    from .common import LatencySink, _SinkAdapter
    n_jobs = 10 if quick else 50
    rate_per_job = 400
    dur = 3.0 if quick else 8.0
    clock = WallClock()
    cluster = JetCluster(n_nodes=1, cooperative_threads=2, clock=clock)
    gen = NexmarkGenerator(rate=rate_per_job, n_keys=50)
    sinks = []
    t0 = [None]
    jobs = []
    total = int(rate_per_job * dur)
    for _ in range(n_jobs):
        sink = LatencySink(clock, t0)
        sinks.append(sink)
        p = queries.q5(lambda: PacedGeneratorSource(gen, rate=rate_per_job,
                                                    max_events=total),
                       lambda s=sink: _SinkAdapter(s),
                       window_ms=1000, slide_ms=100)
        jobs.append(p)
    t0[0] = clock.now()
    submitted = [cluster.submit(p.to_dag()) for p in jobs]
    deadline = time.monotonic() + dur * 4 + 15
    while (not all(j.status == JOB_COMPLETED for j in submitted)
           and time.monotonic() < deadline):
        cluster.step()
    lats = [l for s in sinks for l in s.latencies_ms()]
    lats = lats[len(lats) // 5:]
    return [{"figure": "sec7.7", "jobs": n_jobs,
             "aggregate_rate": n_jobs * rate_per_job,
             "samples": len(lats), **percentiles(lats)}]
