"""Chaos soak harness: paced NEXMark Q5 under seeded fault injection.

Every scenario runs the same paced Q5 twice — once clean, once under a
:class:`~repro.runtime.chaos.ChaosSchedule` derived from an integer seed
— and checks the two result sets are identical (exactly-once: zero loss,
zero spurious results; raw replay overlap is reported separately).  For
each fired fault the harness records the **recovery gap**: wall time from
the injection instant to the first result arriving after it, which under
a kill covers detection, teardown, restore-from-committed-snapshot,
re-fork and replay.  Inter-arrival gap percentiles over the whole run
(p50..p99.99) put those gaps in context against the no-chaos baseline.

Scenarios:

* ``chaos_q5`` — the seeded schedule (kill / stall / raise / drop_ack /
  delay_ack, every kind at least once per schedule) against the mp
  substrate, plus an in-process run of the same schedule (kinds the
  substrate cannot express are skipped and recorded);
* ``rescale_q5`` — elastic ``add_node`` mid-run (cooperative whole-job
  restart) with the same equality check and gap measurement;
* ``active_active_flip`` — hot-standby replica loss (§4.6): kill the
  primary mid-stream, the standby keeps emitting; dedup-by-record-id
  output must still be complete;
* ``corruption_q5`` — the durable snapshot chain under seeded *storage*
  faults: each corruption fault (bit-flip / truncate / manifest delete)
  damages the newest committed on-disk snapshot and is chased by a
  worker kill in the same tick, so recovery must detect the damage and
  fall back down the verified chain.  Per fault the harness records the
  victim snapshot id, the recovery gap, and (from the job's recovery
  log) the skipped ids + reasons proving the fallback was *verified*,
  not lucky;
* ``poison_q5`` — a record that deterministically crashes its vertex:
  the crash-loop escalation ladder must fingerprint it, pinpoint the
  exact record, quarantine it to the dead-letter queue (exactly-once
  accounting) and complete within the restart budget with output equal
  to a run that never saw the record.

Results land under a ``chaos`` key in ``BENCH_latency.json`` and as a
compact record appended to ``BENCH_trajectory.json``; ``--smoke`` (the
CI gate) can additionally dump the recovery diagnostics of its
corruption + poison passes via ``--diagnostics PATH`` for the CI
artifact.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

GAP_PCTS = (50.0, 90.0, 99.0, 99.9, 99.99)


def _result_key(ev):
    return (ev.ts, ev.key, ev.value.window_end, ev.value.value)


def _unique(out) -> List:
    return sorted({_result_key(ev) for _, ev in out})


def _gap_stats(arrivals: List[float]) -> Dict[str, float]:
    """Inter-arrival gap percentiles (ms) across the whole run."""
    if len(arrivals) < 2:
        return {}
    gaps = np.diff(np.sort(np.asarray(arrivals))) * 1000.0
    stats = {f"p{p:g}": round(float(np.percentile(gaps, p)), 3)
             for p in GAP_PCTS}
    stats["max"] = round(float(gaps.max()), 3)
    stats["samples"] = int(len(gaps))
    return stats


def _paced_q5(backend: str, rate: float, total: int, threads: int,
              n_nodes: int, seed: Optional[int] = None,
              n_faults: int = 5, rescale_at: Optional[int] = None,
              window_ms: int = 100, slide_ms: int = 20,
              timeout_s: float = 300.0, kinds=None,
              snapshot_dir=None, schedule=None,
              restart_policy=None) -> Dict:
    """One paced Q5 run; chaos when ``seed`` or an explicit ``schedule``
    is set, elastic rescale when ``rescale_at`` is set, durable on-disk
    snapshot chain when ``snapshot_dir`` is set.  Returns raw sink output
    plus job/fault bookkeeping."""
    from repro.core import (CollectorSink, JetCluster, JobConfig,
                            PacedGeneratorSource, GUARANTEE_EXACTLY_ONCE)
    from repro.core.engine import JOB_COMPLETED
    from repro.nexmark import NexmarkGenerator, queries
    from repro.runtime.chaos import ChaosController, ChaosSchedule

    gen = NexmarkGenerator(rate=rate, n_keys=40)
    cluster = JetCluster(n_nodes=n_nodes, cooperative_threads=threads,
                         backend=backend, snapshot_dir=snapshot_dir)
    out: list = []
    p = queries.q5(
        lambda: PacedGeneratorSource(gen, rate=rate, max_events=total),
        lambda: CollectorSink(out, with_time=True),
        window_ms=window_ms, slide_ms=slide_ms)
    # a tight barrier deadline so a chaos-dropped ack aborts (and is seen
    # to abort) within the run instead of outliving it
    job = cluster.submit(p.to_dag(), JobConfig(
        processing_guarantee=GUARANTEE_EXACTLY_ONCE,
        snapshot_interval_s=0.1, barrier_timeout_s=0.75,
        restart_policy=restart_policy))
    controller = None
    if schedule is None and seed is not None:
        # expected unique results ~= total/1000ms * slide panes; using the
        # raw sink length as the logical clock only needs rough proportions
        expected = max(200, (total * 1000 // int(rate)) // slide_ms)
        schedule = ChaosSchedule.from_seed(
            seed, n_faults, expected,
            **({} if kinds is None else {"kinds": kinds}))
    if schedule is not None:
        controller = ChaosController(cluster, job, out, schedule)
    rescaled_at: Optional[float] = None
    try:
        deadline = time.monotonic() + timeout_s
        while job.status != JOB_COMPLETED:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"chaos run did not complete (status {job.status}, "
                    f"faults {controller.log if controller else None})")
            cluster.step()
            if controller is not None:
                controller.tick()
            if (rescale_at is not None and rescaled_at is None
                    and len(out) >= rescale_at and job.snapshots_taken > 0):
                cluster.add_node()
                rescaled_at = time.monotonic()
    finally:
        cluster.shutdown()
    return {
        "out": out,
        "faults": controller.schedule.faults if controller else [],
        "rescaled_at": rescaled_at,
        "auto_restarts": job.auto_restarts,
        "cooperative_restarts": job.restarts - job.auto_restarts,
        "snapshots_aborted": job.snapshots_aborted,
        "failures": [repr(f) for f in job.failures],
        # restores with skipped ids + reasons, escalations, dead letters
        "recovery": job.recovery_diagnostics(),
    }


def _recovery_gap_ms(arrivals: List[float], t_fire: float) -> Optional[float]:
    after = [t for t in arrivals if t > t_fire]
    if not after:
        return None
    return round((min(after) - t_fire) * 1000.0, 3)


def _verify(clean_out, chaos_out) -> Dict:
    clean_u, chaos_u = _unique(clean_out), _unique(chaos_out)
    lost = len(set(clean_u) - set(chaos_u))
    spurious = len(set(chaos_u) - set(clean_u))
    return {
        "results_match": clean_u == chaos_u,
        "unique_results": len(chaos_u),
        "lost": lost,
        "spurious": spurious,
        # raw re-emissions from post-restore replay (deduplicated above;
        # nonzero is EXPECTED for collector sinks under restarts)
        "replay_duplicates": len(chaos_out) - len({
            _result_key(ev) for _, ev in chaos_out}),
    }


def chaos_q5(backend: str = "mp", seed: int = 1, n_faults: int = 5,
             rate: float = 60_000, total: int = 48_000,
             threads: int = 2, n_nodes: int = 2) -> Dict:
    clean = _paced_q5(backend, rate, total, threads, n_nodes)
    chaos = _paced_q5(backend, rate, total, threads, n_nodes, seed=seed,
                      n_faults=n_faults)
    arrivals = [t for t, _ in chaos["out"]]
    faults = []
    for f in chaos["faults"]:
        rec = {"kind": f.kind, "at_result": f.at_result,
               "fired": f.fired, "skipped": f.skipped}
        if f.fired:
            rec["fired_at_result"] = f.fired_at_result
            rec["recovery_gap_ms"] = _recovery_gap_ms(arrivals, f.fired_at)
        faults.append(rec)
    return {
        "scenario": "chaos_q5", "backend": backend, "seed": seed,
        "rate": rate, "total_events": total, "workers": threads,
        "nodes": n_nodes,
        "faults": faults,
        "fault_kinds_fired": sorted({f["kind"] for f in faults
                                     if f["fired"]}),
        "auto_restarts": chaos["auto_restarts"],
        "snapshots_aborted": chaos["snapshots_aborted"],
        "verification": _verify(clean["out"], chaos["out"]),
        "arrival_gap_ms": _gap_stats(arrivals),
        "clean_arrival_gap_ms": _gap_stats([t for t, _ in clean["out"]]),
    }


def rescale_q5(backend: str = "mp", rate: float = 60_000,
               total: int = 36_000, threads: int = 2,
               n_nodes: int = 2, rescale_at: int = 200) -> Dict:
    clean = _paced_q5(backend, rate, total, threads, n_nodes)
    scaled = _paced_q5(backend, rate, total, threads, n_nodes,
                       rescale_at=rescale_at)
    arrivals = [t for t, _ in scaled["out"]]
    gap = (None if scaled["rescaled_at"] is None
           else _recovery_gap_ms(arrivals, scaled["rescaled_at"]))
    return {
        "scenario": "rescale_q5", "backend": backend, "rate": rate,
        "total_events": total, "workers": threads,
        "nodes_before": n_nodes, "nodes_after": n_nodes + 1,
        "rescale_recovery_gap_ms": gap,
        "cooperative_restarts": scaled["cooperative_restarts"],
        "verification": _verify(clean["out"], scaled["out"]),
    }


def corruption_q5(backend: str = "mp", seed: int = 1, n_faults: int = 2,
                  rate: float = 60_000, total: int = 48_000,
                  threads: int = 2, n_nodes: int = 2) -> Dict:
    """Seeded snapshot-corruption soak: each corruption fault damages the
    newest committed on-disk snapshot and is chased by a kill in the same
    tick, so the very next recovery must fall back through the damage.
    Verified exactly-once against a clean run; per fault the record shows
    the victim snapshot id and recovery gap, and ``fallback`` proves every
    corrupted id was rejected with a verification reason."""
    import tempfile

    from repro.core.engine import RestartPolicy
    from repro.runtime.chaos import CORRUPTION_KINDS, ChaosSchedule

    clean = _paced_q5(backend, rate, total, threads, n_nodes)
    expected = max(200, (total * 1000 // int(rate)) // 20)
    schedule = ChaosSchedule.corruption_from_seed(seed, n_faults, expected)
    with tempfile.TemporaryDirectory(prefix="jet-chaos-snap-") as d:
        damaged = _paced_q5(
            backend, rate, total, threads, n_nodes, schedule=schedule,
            snapshot_dir=d,
            restart_policy=RestartPolicy(max_restarts=4 * n_faults))
    arrivals = [t for t, _ in damaged["out"]]
    faults = []
    for f in schedule.faults:
        rec = {"kind": f.kind, "at_result": f.at_result,
               "fired": f.fired, "skipped": f.skipped}
        if f.fired:
            rec["fired_at_result"] = f.fired_at_result
            rec["recovery_gap_ms"] = _recovery_gap_ms(arrivals, f.fired_at)
            if "snapshot_id" in f.params:
                rec["snapshot_id"] = f.params["snapshot_id"]
        faults.append(rec)
    recovery = damaged["recovery"]
    skipped = [s for r in recovery["recovery_log"]
               if r["event"] == "restore" for s in r["skipped"]]
    corrupted = sorted({f.params["snapshot_id"] for f in schedule.faults
                        if f.fired and f.kind in CORRUPTION_KINDS})
    rejected = {s["snapshot_id"] for s in skipped
                if "verification failed" in s["reason"]
                or "restore load failed" in s["reason"]}
    return {
        "scenario": "corruption_q5", "backend": backend, "seed": seed,
        "rate": rate, "total_events": total, "workers": threads,
        "nodes": n_nodes,
        "faults": faults,
        "corrupted_snapshots": corrupted,
        "fallback": {
            # every corrupted epoch was rejected for cause, none restored
            "all_corrupted_rejected": all(sid in rejected
                                          for sid in corrupted),
            "skipped": skipped,
            "max_depth": max((r["fallback_depth"]
                              for r in recovery["recovery_log"]
                              if "fallback_depth" in r), default=0),
        },
        "auto_restarts": damaged["auto_restarts"],
        "verification": _verify(clean["out"], damaged["out"]),
        "arrival_gap_ms": _gap_stats(arrivals),
        "recovery": recovery,
    }


class _PoisonGate:
    """Pass-through processor that raises (or, for the expected-run twin,
    silently drops) on one specific record — the deterministic poison.
    The trap matches (ts, key, pickled value): the exact identity the
    engine's quarantine filter uses."""

    def __init__(self, trap, raise_on_hit: bool):
        self.trap = trap
        self.raise_on_hit = raise_on_hit

    def _hit(self, ev) -> bool:
        import pickle
        t = self.trap
        if ev.ts != t[0] or ev.key != t[1]:
            return False
        return pickle.dumps(ev.value, protocol=4) == t[2]

    def process(self, ordinal, inbox):
        ob = self.outbox
        while len(inbox):
            ev = inbox.peek()
            if self._hit(ev):
                if self.raise_on_hit:
                    raise RuntimeError("poison record reached the gate")
                inbox.remove()
                continue
            if not ob.offer(ev):
                return
            inbox.remove()


def poison_q5(rate: float = 20_000, total: int = 8_000,
              poison_seq: int = 900, threads: int = 2,
              n_nodes: int = 2, timeout_s: float = 300.0) -> Dict:
    """Deterministic poison record against the escalation ladder: a gate
    vertex crashes on one specific bid every replay; the engine must
    fingerprint the recurrence, pinpoint the record, quarantine it
    (dead-letter, exactly once) and complete within the restart budget
    with output equal to a run that never saw the record."""
    import pickle

    from repro.core import (CollectorSink, JetCluster, JobConfig,
                            PacedGeneratorSource, Processor,
                            GUARANTEE_EXACTLY_ONCE)
    from repro.core.engine import JOB_COMPLETED, JOB_FAILED, RestartPolicy
    from repro.core.pipeline import Pipeline
    from repro.core.window import counting, sliding
    from repro.nexmark import NexmarkGenerator
    from repro.nexmark.queries import bid_auction, is_bid

    gen = NexmarkGenerator(rate=rate, n_keys=40)
    seq = poison_seq
    while not is_bid(gen(seq)[2]):
        seq += 1
    ts, key, value = gen(seq)
    trap = (ts, key, pickle.dumps(value, protocol=4))

    class Gate(_PoisonGate, Processor):
        pass

    def one_run(raise_on_hit: bool):
        cluster = JetCluster(n_nodes=n_nodes, cooperative_threads=threads,
                             backend="inproc")
        out: list = []
        p = Pipeline.create()
        (p.read_from(lambda: PacedGeneratorSource(
                NexmarkGenerator(rate=rate, n_keys=40),
                rate=rate, max_events=total), name="bids")
            .custom_transform("gate", lambda: Gate(trap, raise_on_hit))
            .filter(is_bid)
            .with_key(bid_auction)
            .window(sliding(100, 20))
            .aggregate(counting())
            .write_to(lambda: CollectorSink(out, with_time=True)))
        job = cluster.submit(p.to_dag(), JobConfig(
            processing_guarantee=GUARANTEE_EXACTLY_ONCE,
            snapshot_interval_s=0.1,
            restart_policy=RestartPolicy(max_restarts=8,
                                         fingerprint_threshold=2)))
        try:
            deadline = time.monotonic() + timeout_s
            while job.status not in (JOB_COMPLETED, JOB_FAILED):
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"poison run stuck: {job.recovery_diagnostics()}")
                cluster.step()
        finally:
            cluster.shutdown()
        return out, job, job.status == JOB_COMPLETED

    expected_out, _, expected_done = one_run(raise_on_hit=False)
    out, job, completed = one_run(raise_on_hit=True)
    recovery = job.recovery_diagnostics()
    return {
        "scenario": "poison_q5", "backend": "inproc", "rate": rate,
        "total_events": total, "poison_seq": seq,
        "poison_record": repr(value),
        "completed": completed and expected_done,
        "quarantined": len(job.dead_letters),
        "auto_restarts": job.auto_restarts,
        "restart_budget": 8,
        "escalations": [e for e in recovery["recovery_log"]
                        if e["event"] == "escalation"],
        # the surviving stream vs a run that never saw the record
        "verification": _verify(expected_out, out),
        "recovery": recovery,
    }


def active_active_flip(rate: float = 2_000, total: int = 2_000,
                       kill_after_results: int = 50) -> Dict:
    """Hot-standby flip (§4.6, in-process replicas): primary dies
    mid-stream, the standby keeps emitting — the recovery gap is the
    output stream's ordinary cadence, no restore involved."""
    from repro.core import PacedGeneratorSource
    from repro.core.engine import JOB_COMPLETED
    from repro.core.processor import SinkProcessor
    from repro.nexmark import NexmarkGenerator, queries
    from repro.snapshot import ActiveActiveRunner

    arrivals: List[float] = []

    def build(sink_consumer):
        def consume(ev):
            arrivals.append(time.monotonic())
            sink_consumer(ev)
        gen = NexmarkGenerator(rate=rate, n_keys=40)
        return queries.q5(
            lambda: PacedGeneratorSource(gen, rate=rate, max_events=total),
            lambda: SinkProcessor(consume), window_ms=100, slide_ms=20)

    runner = ActiveActiveRunner(
        build, id_fn=lambda ev: (ev.ts, ev.key, ev.value.window_end),
        n_nodes=2)
    killed_at: Optional[float] = None
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline:
        runner.step()
        if (killed_at is None
                and len(runner.output.results) >= kill_after_results
                and runner.jobs[0].status != JOB_COMPLETED):
            runner.kill_replica(0)
            killed_at = time.monotonic()
        done = [j.status == JOB_COMPLETED
                for i, j in enumerate(runner.jobs) if i != runner.failed]
        if done and all(done):
            break
    n_results = len(runner.output.results)
    survivors = {rep for rep, _ in runner.output.results.values()}
    return {
        "scenario": "active_active_flip", "rate": rate,
        "total_events": total,
        "flip_recovery_gap_ms": (None if killed_at is None
                                 else _recovery_gap_ms(arrivals, killed_at)),
        "results": n_results,
        "standby_contributed": 1 in survivors,
        "duplicates_deduped": runner.output.duplicates,
        "primary_killed": killed_at is not None,
    }


def run(quick: bool = True, seeds=(1, 2, 3)) -> Dict:
    from repro.core.shm_ring import sweep_leaked_rings

    swept = sweep_leaked_rings()
    seeds = seeds[:1] if quick else seeds
    mp_runs = [chaos_q5("mp", seed=s) for s in seeds]
    section = {
        "meta": {
            "metric": "recovery gap (ms) per injected fault; exactly-once "
                      "verification against a clean run",
            "quick": quick,
            "seeds": list(seeds),
            "swept_leaked_rings": len(swept),
        },
        "mp": mp_runs,
        "inproc": chaos_q5("inproc", seed=seeds[0]),
        "rescale": rescale_q5("mp"),
        "active_active": active_active_flip(),
        "corruption": corruption_q5("mp", seed=seeds[0]),
        "poison": poison_q5(),
    }
    return section


def smoke(seed: int = 1) -> Dict:
    """CI gate: one seeded worker kill + one delayed barrier ack against
    2 mp workers, one seeded snapshot corruption (+ chasing kill) over a
    durable chain, and one deterministic poison record — each verified
    exactly-once against a clean run.  Writes no reports; the caller
    exits nonzero when ``ok`` is False."""
    from repro.runtime.chaos import KIND_DELAY_ACK, KIND_KILL
    from repro.core.shm_ring import sweep_leaked_rings

    sweep_leaked_rings()
    kinds = (KIND_KILL, KIND_DELAY_ACK)
    clean = _paced_q5("mp", 60_000, 36_000, 2, 1)
    chaos = _paced_q5("mp", 60_000, 36_000, 2, 1, seed=seed,
                      n_faults=len(kinds), kinds=kinds)
    fired = sorted({f.kind for f in chaos["faults"] if f.fired})
    verification = _verify(clean["out"], chaos["out"])
    corruption = corruption_q5("mp", seed=seed, n_faults=1,
                               total=36_000, n_nodes=1)
    poison = poison_q5()
    return {
        "scenario": "smoke", "seed": seed, "fault_kinds_fired": fired,
        "auto_restarts": chaos["auto_restarts"],
        "snapshots_aborted": chaos["snapshots_aborted"],
        "verification": verification,
        "corruption": corruption,
        "poison": poison,
        "ok": (verification["results_match"] and set(fired) == set(kinds)
               and corruption["verification"]["results_match"]
               and corruption["fallback"]["all_corrupted_rejected"]
               and bool(corruption["corrupted_snapshots"])
               and poison["completed"] and poison["quarantined"] == 1
               and poison["verification"]["results_match"]),
    }


def update_reports(section: Dict,
                   root: Optional[pathlib.Path] = None) -> List[pathlib.Path]:
    """Attach the chaos section to ``BENCH_latency.json`` and append a
    compact record to ``BENCH_trajectory.json``."""
    import subprocess
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[1]
    paths = []
    latency = root / "BENCH_latency.json"
    try:
        report = json.loads(latency.read_text())
    except (FileNotFoundError, ValueError):
        report = {}
    report["chaos"] = section
    latency.write_text(json.dumps(report, indent=1, default=float) + "\n")
    paths.append(latency)

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip() \
            or "unknown"
    except Exception:
        sha = "unknown"
    mp_runs = section.get("mp", [])
    gaps = [f["recovery_gap_ms"] for r in mp_runs for f in r["faults"]
            if f.get("recovery_gap_ms") is not None]
    record = {
        "sha": sha,
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "chaos_seeds": section["meta"]["seeds"],
        "chaos_fault_kinds_fired": sorted({k for r in mp_runs
                                           for k in r["fault_kinds_fired"]}),
        "chaos_verified": all(r["verification"]["results_match"]
                              for r in mp_runs),
        "chaos_max_recovery_gap_ms": max(gaps) if gaps else None,
        "chaos_rescale_gap_ms":
            section["rescale"]["rescale_recovery_gap_ms"],
        "chaos_active_active_gap_ms":
            section["active_active"]["flip_recovery_gap_ms"],
        "corruption_verified":
            (section["corruption"]["verification"]["results_match"]
             and section["corruption"]["fallback"]
                 ["all_corrupted_rejected"]),
        "corruption_snapshots": section["corruption"]
            ["corrupted_snapshots"],
        "poison_quarantined":
            (section["poison"]["completed"]
             and section["poison"]["quarantined"] == 1
             and section["poison"]["verification"]["results_match"]),
    }
    trajectory = root / "BENCH_trajectory.json"
    try:
        records = json.loads(trajectory.read_text())
        if not isinstance(records, list):
            records = []
    except (FileNotFoundError, ValueError):
        records = []
    records.append(record)
    trajectory.write_text(json.dumps(records, indent=1, default=float) + "\n")
    paths.append(trajectory)
    return paths


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all seeds (default: one seed, quick mode)")
    ap.add_argument("--seeds", type=int, nargs="*", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 1 kill + 1 delayed ack on 2 mp "
                         "workers, plus a snapshot-corruption and a "
                         "poison-record pass; no report writes, nonzero "
                         "exit on verification failure")
    ap.add_argument("--diagnostics", type=pathlib.Path, default=None,
                    help="with --smoke: dump the corruption + poison "
                         "recovery diagnostics (restores, skipped "
                         "snapshots + reasons, escalations, dead "
                         "letters) to this JSON file (the CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        import sys
        result = smoke()
        print(json.dumps(result, indent=1, default=float))
        if args.diagnostics is not None:
            args.diagnostics.write_text(json.dumps({
                "ok": result["ok"],
                "corruption": result["corruption"],
                "poison": result["poison"],
            }, indent=1, default=float) + "\n")
            print(f"# wrote {args.diagnostics}")
        sys.exit(0 if result["ok"] else 1)
    seeds = tuple(args.seeds) if args.seeds else (1, 2, 3)
    section = run(quick=not args.full, seeds=seeds)
    for p in update_reports(section):
        print(f"# updated {p}")
    print(json.dumps(section, indent=1, default=float))
