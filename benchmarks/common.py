"""Benchmark harness helpers.

Latency methodology (paper §7.1): the latency clock for a window result
starts at the *ideal occurrence time* of its window end (the generator's
pacing schedule pins event time to wall time) and stops when the engine
emits the result at the sink.  Any scheduling delay in the engine shows up
in the number.  Rates are scaled to what a single CPU core running a pure
Python datapath sustains (the JVM figures in the paper are ~100x higher;
shapes of the curves, not absolute numbers, are the reproduction target —
the COMPILED device tier closes the absolute gap, see
bench_streaming_device).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (CollectorSink, JetCluster, JobConfig,
                        PacedGeneratorSource, Pipeline, WallClock)
from repro.core.engine import JOB_COMPLETED
from repro.nexmark import NexmarkGenerator, queries

PCTS = (50, 90, 99, 99.9, 99.99)


def percentiles(latencies_ms: List[float]) -> Dict[str, float]:
    if not latencies_ms:
        return {f"p{p}": float("nan") for p in PCTS}
    arr = np.asarray(latencies_ms)
    return {f"p{p}": round(float(np.percentile(arr, p)), 3) for p in PCTS}


class LatencySink:
    """Collects (arrival_wall, item); computes window-result latency."""

    def __init__(self, clock, t0_holder):
        self.samples: List[Tuple[float, object]] = []
        self.clock = clock
        self.t0_holder = t0_holder

    def __call__(self, ev):
        self.samples.append((self.clock.now(), ev))

    def latencies_ms(self) -> List[float]:
        t0 = self.t0_holder[0]
        out = []
        for t_arr, ev in self.samples:
            # ideal wall time of the window end (event time is ms since t0)
            ideal = t0 + (ev.ts + 1) / 1000.0
            out.append((t_arr - ideal) * 1000.0)
        return out


def run_q5_latency(rate: float, duration_s: float, n_nodes: int = 1,
                   threads: int = 2, window_ms: int = 1000,
                   slide_ms: int = 20, n_keys: int = 100,
                   guarantee: str = "none",
                   snapshot_interval_s: float = 1.0,
                   query=queries.q5, warmup_s: float = 1.0,
                   max_events: Optional[int] = None):
    """Run Q5 at a paced rate against the wall clock; returns (percentile
    dict, achieved_rate, latencies)."""
    clock = WallClock()
    cluster = JetCluster(n_nodes=n_nodes, cooperative_threads=threads,
                         clock=clock, link_latency_s=0.0002)
    gen = NexmarkGenerator(rate=rate, n_keys=n_keys)
    t0_holder = [None]
    sink = LatencySink(clock, t0_holder)
    total = max_events or int(rate * duration_s)

    def src():
        return PacedGeneratorSource(gen, rate=rate, max_events=total)

    p = query(src, lambda: _SinkAdapter(sink), window_ms=window_ms,
              slide_ms=slide_ms)
    cfg = JobConfig(processing_guarantee=guarantee,
                    snapshot_interval_s=snapshot_interval_s)
    t0_holder[0] = clock.now()
    job = cluster.submit(p.to_dag(), cfg)
    deadline = time.monotonic() + duration_s * 3 + 10
    while job.status != JOB_COMPLETED and time.monotonic() < deadline:
        cluster.step()
    # drop warmup
    cut = t0_holder[0] + warmup_s
    lats = [l for (t, ev), l in zip(sink.samples, sink.latencies_ms())
            if t >= cut]
    achieved = len(sink.samples) and total / (sink.samples[-1][0]
                                              - t0_holder[0])
    return percentiles(lats), achieved, lats


class _SinkAdapter:
    """Processor-factory shim for CollectorSink-style callables."""

    def __new__(cls, consumer):
        from repro.core.processor import SinkProcessor
        return SinkProcessor(consumer)


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
