"""The paper's headline metric: event-time -> emission latency at the
99.99th percentile, plus events/s/core, on BOTH tiers.

Methodology (paper §7.1): the latency clock for a window result starts at
the *ideal occurrence time* of its window end — the generator's pacing
schedule pins event time to wall time — and stops when the engine emits
the result at the sink.  Scheduling delay, batching delay, snapshot
pauses: everything the engine does shows up in the number.  Latencies are
recorded into an HdrHistogram-style log-bucketed histogram so the p99.99
is a real measured quantile, not an interpolation over a handful of
samples.

Results land in ``BENCH_latency.json`` at the repo root so successive PRs
accumulate a perf trajectory.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

REPORT_PCTS = (50.0, 90.0, 99.0, 99.9, 99.99)
#: a p99.99 needs at least this many samples before the quantile is a
#: measurement rather than "roughly the max of a small run"
P9999_MIN_SAMPLES = 10_000


class LatencyHistogram:
    """HdrHistogram-style fixed-precision histogram of microsecond values.

    Values are bucketed logarithmically by magnitude with
    ``2**sub_bucket_bits`` linear sub-buckets per power of two, giving a
    bounded relative error (~1/2**sub_bucket_bits) across the whole range
    with O(1) record cost and compact storage — the same scheme
    HdrHistogram uses, sized here for 1 us .. ~60 s.
    """

    def __init__(self, max_value_us: int = 60_000_000,
                 sub_bucket_bits: int = 7):
        self.sub_bucket_bits = sub_bucket_bits
        self.sub_bucket_count = 1 << sub_bucket_bits
        # number of magnitude buckets needed to cover max_value_us
        buckets = 1
        top = self.sub_bucket_count
        while top < max_value_us:
            top <<= 1
            buckets += 1
        self.bucket_count = buckets
        self.max_value_us = max_value_us
        # bucket 0 holds values [0, sub_bucket_count) at resolution 1;
        # bucket b >= 1 holds [sub_bucket_count * 2**(b-1), ... * 2**b)
        # in sub_bucket_count/2 live sub-buckets of width 2**b
        self.counts = np.zeros(
            (buckets + 1) * self.sub_bucket_count, dtype=np.int64)
        self.total = 0
        self.min_us = float("inf")
        self.max_us = 0.0

    def _index(self, v: int) -> int:
        if v < self.sub_bucket_count:
            return v
        bucket = v.bit_length() - self.sub_bucket_bits
        sub = v >> bucket
        return (bucket << self.sub_bucket_bits) + sub

    def record(self, value_us: float) -> None:
        v = int(value_us)
        if v < 0:
            v = 0
        elif v > self.max_value_us:
            v = self.max_value_us
        self.counts[self._index(v)] += 1
        self.total += 1
        if value_us < self.min_us:
            self.min_us = value_us
        if value_us > self.max_us:
            self.max_us = value_us

    def record_many(self, values_us) -> None:
        for v in values_us:
            self.record(v)

    def percentile(self, pct: float) -> float:
        """Value (us) at the given percentile, upper-bucket-edge biased."""
        if self.total == 0:
            return float("nan")
        target = int(np.ceil(pct / 100.0 * self.total))
        running = 0
        nz = np.nonzero(self.counts)[0]
        for idx in nz:
            running += int(self.counts[idx])
            if running >= target:
                bucket = idx >> self.sub_bucket_bits
                sub = idx & (self.sub_bucket_count - 1)
                width = 1 if bucket == 0 else 1 << bucket
                base = sub if bucket == 0 else sub << bucket
                return float(base + width - 1)
        return self.max_us

    def summary_ms(self) -> Dict[str, float]:
        out = {f"p{p:g}": round(self.percentile(p) / 1000.0, 3)
               for p in REPORT_PCTS}
        out["min"] = round(0.0 if self.total == 0 else self.min_us / 1000.0, 3)
        out["max"] = round(self.max_us / 1000.0, 3)
        out["samples"] = self.total
        if self.total < P9999_MIN_SAMPLES:
            # 1 in 10k: with fewer samples the quantile is just the max of
            # a small run — report it as unreliable instead of meaningless
            out["p99.99"] = None
            out["warning"] = (f"p99.99 unreliable: {self.total} samples "
                              f"< {P9999_MIN_SAMPLES}")
        return out


# ---------------------------------------------------------------------------
# Host tier: NEXMark Q5 through the cooperative tasklet engine
# ---------------------------------------------------------------------------


def host_q5_latency(rate: float = 20_000, duration_s: float = 4.0,
                    window_ms: int = 1000, slide_ms: int = 20,
                    n_keys: int = 100, threads: int = 2,
                    warmup_s: float = 1.0, disorder_ms: int = 0,
                    disorder_seed: int = 7,
                    block_size: Optional[int] = None,
                    placement: str = "host",
                    device: Optional[Dict] = None) -> Dict:
    """Paced Q5 on the host tier; returns percentiles + events/s/core.

    ``disorder_ms`` > 0 runs the generator through a seeded bounded shuffle
    (events arrive up to that much event time out of order) with a matching
    watermark lag — the p99.99 then includes the completeness wait the lag
    imposes, which is the honest cost of disorder tolerance.

    ``placement="device"`` swaps the host two-stage window plan for the
    device-offloaded window vertex (core/device_window.py): EventBlocks
    pack into padded device batches, the compiled StreamExecutor
    aggregates, and results cross back to host events — the end-to-end
    ``host_to_device`` bridge measurement.

    The whole cluster simulation runs on one OS thread, so aggregate
    events/s == events/s/core."""
    from repro.core import (JetCluster, JobConfig, PacedGeneratorSource,
                            WallClock)
    from repro.core.engine import JOB_COMPLETED
    from repro.nexmark import (DisorderedNexmarkGenerator, NexmarkGenerator,
                               queries)
    from .common import _SinkAdapter

    clock = WallClock()
    cluster = JetCluster(n_nodes=1, cooperative_threads=threads, clock=clock)
    gen = NexmarkGenerator(rate=rate, n_keys=n_keys)
    if disorder_ms > 0:
        gen = DisorderedNexmarkGenerator(gen, max_skew_ms=disorder_ms,
                                         seed=disorder_seed)
    hist = LatencyHistogram()
    total = int(rate * duration_s)
    t0_holder = [None]
    cut_holder = [None]
    end_holder = [None]

    def sink(ev):
        now = clock.now()
        # window result event time is window_end - 1 (ms since t0)
        ideal = t0_holder[0] + (ev.ts + 1) / 1000.0
        # drop warmup and the end-of-stream flush (windows emitted early
        # when the finite source completes have ideal times in the future)
        if cut_holder[0] <= now and ideal <= end_holder[0]:
            hist.record((now - ideal) * 1e6)

    p = queries.q5(
        lambda: PacedGeneratorSource(gen, rate=rate, max_events=total,
                                     wm_lag=disorder_ms,
                                     block_size=block_size),
        lambda: _SinkAdapter(sink), window_ms=window_ms, slide_ms=slide_ms,
        placement=placement, device=device)
    # submit BEFORE anchoring t0: processor init (incl. the device
    # vertex's one-time XLA compile) must not count against event latency
    # — the paced source anchors its own schedule on its first slice,
    # which happens after init, so t0 and the schedule stay aligned
    job = cluster.submit(p.to_dag(), JobConfig())
    t0_holder[0] = clock.now()
    cut_holder[0] = t0_holder[0] + warmup_s
    end_holder[0] = t0_holder[0] + total / rate
    deadline = time.monotonic() + duration_s * 3 + 10
    t_start = time.monotonic()
    while job.status != JOB_COMPLETED and time.monotonic() < deadline:
        cluster.step()
    wall = time.monotonic() - t_start
    stats = job.execution.stats()
    engine = {k: stats[k] for k in ("items_in", "items_out", "calls",
                                    "idle_calls")}
    # sampled per-tasklet timing, aggregated per vertex: where the
    # remaining host-tier time goes (feeds the next perf PR)
    engine["per_vertex_time_share"] = cluster.vertex_time_share()
    return {
        "tier": "host" if placement == "host" else "host_to_device",
        "query": "q5", "rate": rate,
        "window_ms": window_ms, "slide_ms": slide_ms,
        "disorder_ms": disorder_ms,
        "events_per_sec_per_core": round(total / wall, 0),
        "latency_ms": hist.summary_ms(),
        "engine": engine,
    }


def host_q5_saturation(n_events: int = 800_000, threads: int = 2,
                       probe_rate: float = 2_000_000,
                       block_size: Optional[int] = None,
                       backend: str = "inproc") -> float:
    """Max sustained events/s/core: pace far beyond capacity (every event
    is always due) and measure the wall time to drain a fixed stream.

    ``block_size=0`` forces the scalar per-event datapath (the A/B
    baseline for the columnar EventBlock path); the default auto-enables
    columnar blocks.  ``backend="mp"`` runs the same fixed stream across
    ``threads`` real worker processes over shared-memory rings (the
    coordinator loop stays on this thread)."""
    from repro.core import (JetCluster, PacedGeneratorSource, WallClock)
    from repro.core.engine import JOB_COMPLETED
    from repro.nexmark import NexmarkGenerator, queries
    from .common import _SinkAdapter

    cluster = JetCluster(n_nodes=1, cooperative_threads=threads,
                         clock=WallClock(), backend=backend)
    gen = NexmarkGenerator(rate=probe_rate, n_keys=100)
    p = queries.q5(
        lambda: PacedGeneratorSource(gen, rate=probe_rate,
                                     max_events=n_events,
                                     block_size=block_size),
        lambda: _SinkAdapter(lambda ev: None), window_ms=1000, slide_ms=20)
    try:
        job = cluster.submit(p.to_dag())
        t0 = time.monotonic()
        deadline = t0 + 120
        while job.status != JOB_COMPLETED and time.monotonic() < deadline:
            cluster.step()
        wall = time.monotonic() - t0
    finally:
        cluster.shutdown()
    return n_events / wall


def host_q5_saturation_ab(n_events: int = 600_000, threads: int = 2,
                          rounds: int = 2) -> Dict[str, float]:
    """Interleaved A/B saturation: scalar datapath vs columnar EventBlock
    datapath, alternated on the same machine in the same process (the
    PR 2 methodology), reporting the best round of each arm."""
    scalar, blocked = [], []
    for _ in range(rounds):
        scalar.append(host_q5_saturation(n_events, threads, block_size=0))
        blocked.append(host_q5_saturation(n_events, threads))
    return {
        "saturation_events_per_sec_per_core": round(max(blocked), 0),
        "saturation_scalar_events_per_sec_per_core": round(max(scalar), 0),
        "saturation_block_speedup": round(max(blocked) / max(scalar), 2),
        "saturation_rounds": rounds,
    }


# ---------------------------------------------------------------------------
# Multiprocess backend: same host-tier Q5 across real worker processes
# ---------------------------------------------------------------------------


def mp_q5_latency(rate: float = 20_000, duration_s: float = 4.0,
                  workers: int = 2, window_ms: int = 1000,
                  slide_ms: int = 20, n_keys: int = 100,
                  warmup_s: float = 1.0,
                  block_size: Optional[int] = None) -> Dict:
    """Paced Q5 on the multiprocess backend: ``workers`` real OS processes
    exchanging EventBlocks over shared-memory rings, coordinator on this
    thread.

    The in-process harness can close over a parent-side sink; here the
    sink runs inside a forked worker, so the latency clock is rebuilt from
    shipped data instead: ``CollectorSink(with_time=True)`` stamps each
    result with the child's wall clock at emission (same machine, same
    clock domain), results ship to the coordinator incrementally, and t0
    is the paced source's schedule anchor reported back with the worker's
    final stats (``MultiprocessBackend.source_start``)."""
    from repro.core import (CollectorSink, JetCluster, JobConfig,
                            PacedGeneratorSource, WallClock)
    from repro.core.engine import JOB_COMPLETED
    from repro.nexmark import NexmarkGenerator, queries

    cluster = JetCluster(n_nodes=1, cooperative_threads=workers,
                         clock=WallClock(), backend="mp")
    gen = NexmarkGenerator(rate=rate, n_keys=n_keys)
    total = int(rate * duration_s)
    out: list = []
    p = queries.q5(
        lambda: PacedGeneratorSource(gen, rate=rate, max_events=total,
                                     block_size=block_size),
        lambda: CollectorSink(out, with_time=True),
        window_ms=window_ms, slide_ms=slide_ms)
    try:
        job = cluster.submit(p.to_dag(), JobConfig())
        deadline = time.monotonic() + duration_s * 3 + 10
        t_start = time.monotonic()
        while job.status != JOB_COMPLETED and time.monotonic() < deadline:
            cluster.step()
        wall = time.monotonic() - t_start
        t0 = cluster.backend.source_start(job.execution)
    finally:
        cluster.shutdown()
    hist = LatencyHistogram()
    if t0 is not None:
        cut = t0 + warmup_s
        end = t0 + total / rate
        for t_arr, ev in out:
            ideal = t0 + (ev.ts + 1) / 1000.0
            # same filters as the in-process harness: drop warmup and the
            # end-of-stream flush (ideal times in the future)
            if cut <= t_arr and ideal <= end:
                hist.record((t_arr - ideal) * 1e6)
    return {
        "tier": "host_mp", "backend": "mp", "query": "q5", "rate": rate,
        "workers": workers, "window_ms": window_ms, "slide_ms": slide_ms,
        "events_per_sec": round(total / wall, 0),
        "latency_ms": hist.summary_ms(),
    }


def mp_saturation_curve(n_events: int = 200_000,
                        workers=(1, 2, 4)) -> Dict:
    """Blocked-Q5 saturation at each worker-process count — the scaling
    shape of the shared-memory substrate.  The host's core count is
    recorded alongside: on a single-core box the curve can only show the
    coordination overhead of extra processes, not parallel speedup, and
    the record must say so."""
    import os
    curve = {}
    for w in workers:
        curve[str(w)] = round(host_q5_saturation(
            n_events=n_events, threads=w, backend="mp"), 0)
    return {
        "figure": "mp_saturation_curve", "backend": "mp",
        "cpus": os.cpu_count(), "n_events": n_events,
        "saturation_events_per_sec_by_workers": curve,
    }


# ---------------------------------------------------------------------------
# Device tier: vectorized Q5 through the compiled StreamExecutor
# ---------------------------------------------------------------------------


def device_q5_latency(steps: int = 2000, batch: int = 4096,
                      n_keys: int = 4096, warmup: int = 50) -> Dict:
    """Per-step event->emission latency of the compiled datapath.

    Each step ingests 10 ms of event time; the latency clock starts when
    the batch exists on the host (its events' generation instant) and
    stops when the emitted window results are materialized host-side —
    staging, compute and readback all show up in the number.  Throughput
    is measured separately over the *pipelined* path (``run_stream``-style
    prefetching, no per-step sync).
    """
    import jax
    from repro.streaming import (StreamExecutor, StreamJobConfig,
                                 VectorWindowSpec)

    spec = VectorWindowSpec(size_ms=1000, slide_ms=10, n_key_buckets=n_keys,
                            max_windows_per_step=2, ring_margin=8)
    ex = StreamExecutor(StreamJobConfig(window=spec, batch_size=batch))
    rng = np.random.RandomState(0)

    def make_batch(i):
        ts = i * 10 + np.sort(rng.randint(0, 10, batch)).astype(np.int32)
        return {"ts": ts,
                "key": rng.randint(0, n_keys, batch).astype(np.int32),
                "value": np.ones((batch,), np.float32),
                "valid": np.ones((batch,), bool),
                "wm": np.asarray(-1, np.int32)}

    hist = LatencyHistogram()
    state = ex.init_state()
    # compile + warmup
    for i in range(warmup):
        staged, cnt = ex.stage_batch(make_batch(i))
        state, out = ex.step(state, staged, valid_count=cnt)
    jax.block_until_ready(state["panes"])

    # latency mode: one batch at a time, synced at the sink
    for i in range(warmup, warmup + steps):
        b = make_batch(i)
        t_gen = time.perf_counter()
        staged, cnt = ex.stage_batch(b)
        state, out = ex.step(state, staged, valid_count=cnt)
        valid = np.asarray(out["valid"])        # sink materialization
        if valid.any():
            np.asarray(out["results"])
        t_emit = time.perf_counter()
        hist.record((t_emit - t_gen) * 1e6)

    # throughput mode: pipelined ingestion, no per-step sync
    n_tp = max(steps // 2, 100)
    batches = [make_batch(warmup + steps + i) for i in range(n_tp)]
    t0 = time.perf_counter()
    nxt = ex.stage_batch(batches[0])
    for i in range(n_tp):
        staged, cnt = nxt
        if i + 1 < n_tp:
            nxt = ex.stage_batch(batches[i + 1])
        state, out = ex.step(state, staged, valid_count=cnt)
    jax.block_until_ready(state["panes"])
    dt = time.perf_counter() - t0
    return {
        "tier": "device", "query": "q5-vectorized", "batch": batch,
        "keys": n_keys, "steps": steps,
        "events_per_sec_per_core": round(n_tp * batch / dt, 0),
        "latency_ms": hist.summary_ms(),
    }


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def run(quick: bool = True, disorder_ms: int = 100,
        backend: str = "inproc", workers: Optional[int] = None) -> Dict:
    host_rate = 20_000
    duration = 4.0 if quick else 10.0
    threads = workers or 2
    if backend == "mp":
        # the knob swaps the substrate under the paced host run itself
        host = mp_q5_latency(rate=host_rate, duration_s=duration,
                             workers=threads)
    else:
        host = host_q5_latency(rate=host_rate, duration_s=duration,
                               threads=threads)
        host.update(host_q5_saturation_ab(
            n_events=600_000 if quick else 2_000_000))
    result = {
        "meta": {
            "metric": "event-time -> emission latency (ms), "
                      "HdrHistogram-style recording",
            "pcts": list(REPORT_PCTS),
            "host_config": {"query": "q5", "rate": host_rate,
                            "window_ms": 1000, "slide_ms": 20},
            "quick": quick,
            "backend": backend,
            "workers": threads,
        },
        "host": host,
    }
    # multiprocess substrate, always measured so the trajectory tracks it:
    # paced percentiles at the default worker count plus the saturation
    # curve across 1/2/4 worker processes
    if backend != "mp":
        result["host_mp"] = mp_q5_latency(rate=host_rate,
                                          duration_s=duration,
                                          workers=threads)
    result["mp_saturation"] = mp_saturation_curve(
        n_events=200_000 if quick else 600_000)
    if disorder_ms > 0:
        # the paper's "handles out-of-order streams" claim, measured: same
        # query under bounded skew with a matching watermark lag
        result["host_disordered"] = host_q5_latency(
            rate=host_rate, duration_s=4.0 if quick else 10.0,
            disorder_ms=disorder_ms)
    # the host->device bridge: the same paced Q5 but the window vertex
    # offloaded to the device tier (EventBlocks -> padded device batches
    # -> StreamExecutor -> WindowResult events), so the bridge's
    # throughput and p99.99 trend alongside the pure host/device numbers
    result["host_to_device"] = host_q5_latency(
        rate=host_rate, duration_s=4.0 if quick else 10.0,
        placement="device",
        device={"n_key_buckets": 128, "batch_size": 1024})
    # >= 10k steps even in quick mode: at millions of events/s this stays
    # well under a minute and makes the headline p99.99 a real measurement
    # (1k steps used to report it null+warning in CI)
    result["device"] = device_q5_latency(steps=10_000)
    return result


def write_report(result: Dict,
                 path: Optional[pathlib.Path] = None) -> pathlib.Path:
    if path is None:
        path = pathlib.Path(__file__).resolve().parents[1] / \
            "BENCH_latency.json"
    # merge over the existing report: sections other harnesses own (e.g.
    # the "chaos" section from benchmarks/bench_chaos.py) must survive a
    # latency-only refresh
    try:
        merged = json.loads(path.read_text())
        if not isinstance(merged, dict):
            merged = {}
    except (FileNotFoundError, ValueError):
        merged = {}
    merged.update(result)
    path.write_text(json.dumps(merged, indent=1, default=float) + "\n")
    return path


def rows(quick: bool = True, disorder_ms: int = 100,
         backend: str = "inproc",
         workers: Optional[int] = None) -> List[Dict]:
    """CSV-row shaped output for benchmarks.run."""
    result = run(quick, disorder_ms=disorder_ms, backend=backend,
                 workers=workers)
    write_report(result)
    append_trajectory(result)
    out = []
    for tier in ("host", "host_mp", "host_disordered", "host_to_device",
                 "device"):
        r = result.get(tier)
        if r is None:
            continue
        lat = r["latency_ms"]
        row = {"figure": f"latency_{tier}",
               "events_per_sec_per_core":
                   r.get("events_per_sec_per_core", r.get("events_per_sec")),
               **{k: lat[k] for k in ("p50", "p99", "p99.9", "p99.99")},
               "samples": lat["samples"]}
        if r.get("backend"):
            row["backend"] = r["backend"]
        if r.get("workers"):
            row["workers"] = r["workers"]
        if lat.get("warning"):
            row["warning"] = lat["warning"]
        if r.get("disorder_ms"):
            row["disorder_ms"] = r["disorder_ms"]
        for k in ("saturation_events_per_sec_per_core",
                  "saturation_scalar_events_per_sec_per_core",
                  "saturation_block_speedup"):
            if k in r:
                row[k] = r[k]
        out.append(row)
    sat = result.get("mp_saturation")
    if sat:
        row = {"figure": "mp_saturation_curve", "cpus": sat["cpus"],
               "n_events": sat["n_events"]}
        for w, v in sat["saturation_events_per_sec_by_workers"].items():
            row[f"workers_{w}_events_per_sec"] = v
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Cross-PR perf trajectory
# ---------------------------------------------------------------------------


def append_trajectory(result: Dict,
                      path: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Append one per-run record (git SHA, saturation A/B, paced and device
    percentiles) to the cumulative ``BENCH_trajectory.json`` so perf
    regressions across PRs are visible at a glance."""
    import subprocess
    if path is None:
        path = pathlib.Path(__file__).resolve().parents[1] / \
            "BENCH_trajectory.json"
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=path.parent, capture_output=True, text=True,
            timeout=10).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    host = result.get("host", {})
    lat = host.get("latency_ms", {})
    device = result.get("device", {})
    bridge = result.get("host_to_device", {})
    record = {
        "sha": sha,
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "quick": result.get("meta", {}).get("quick"),
        "host_saturation_events_per_sec_per_core":
            host.get("saturation_events_per_sec_per_core"),
        "host_saturation_scalar_events_per_sec_per_core":
            host.get("saturation_scalar_events_per_sec_per_core"),
        "host_paced_rate": host.get("rate"),
        "host_p50_ms": lat.get("p50"),
        "host_p99_ms": lat.get("p99"),
        "host_p99.99_ms": lat.get("p99.99"),
        "device_events_per_sec_per_core":
            device.get("events_per_sec_per_core"),
        "device_p99.99_ms": device.get("latency_ms", {}).get("p99.99"),
        "host_to_device_events_per_sec_per_core":
            bridge.get("events_per_sec_per_core"),
        "host_to_device_p50_ms":
            bridge.get("latency_ms", {}).get("p50"),
        "host_to_device_p99.99_ms":
            bridge.get("latency_ms", {}).get("p99.99"),
    }
    # multiprocess substrate: paced percentiles + per-worker-count
    # saturation curve (dict keyed by worker-process count), with the
    # host's core count so single-core records are not misread as
    # failed scaling
    mp = result.get("host_mp") or (
        host if host.get("backend") == "mp" else {})
    sat = result.get("mp_saturation", {})
    record.update({
        "mp_workers": mp.get("workers"),
        "mp_paced_events_per_sec": mp.get("events_per_sec"),
        "mp_paced_p50_ms": mp.get("latency_ms", {}).get("p50"),
        "mp_paced_p99.99_ms": mp.get("latency_ms", {}).get("p99.99"),
        "mp_saturation_events_per_sec_by_workers":
            sat.get("saturation_events_per_sec_by_workers"),
        "cpus": sat.get("cpus"),
    })
    try:
        records = json.loads(path.read_text())
        if not isinstance(records, list):
            records = []
    except (FileNotFoundError, ValueError):
        records = []
    records.append(record)
    path.write_text(json.dumps(records, indent=1, default=float) + "\n")
    return path


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--disorder", type=int, default=100, metavar="SKEW_MS",
                    help="bounded-shuffle skew for the disordered host run "
                         "(0 disables it)")
    ap.add_argument("--backend", choices=("inproc", "mp"), default="inproc",
                    help="substrate for the paced host run (the mp "
                         "saturation curve is measured either way)")
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="cooperative threads (inproc) / worker processes "
                         "(mp) for the paced host run; default 2")
    args = ap.parse_args()
    result = run(quick=not args.full, disorder_ms=args.disorder,
                 backend=args.backend, workers=args.workers)
    p = write_report(result)
    t = append_trajectory(result)
    print(json.dumps(result, indent=1, default=float))
    print(f"# wrote {p}")
    print(f"# appended {t}")
