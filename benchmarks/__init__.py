"""Benchmarks: one per paper table/figure + device tier + roofline."""
