"""NEXMark Q5 live: paced stream, real wall-clock latency percentiles,
exactly-once snapshots, and a mid-stream node failure — the paper's §7
experience in one script.

    PYTHONPATH=src python examples/nexmark_streaming.py
"""

import time

import numpy as np

from repro.core import (GUARANTEE_EXACTLY_ONCE, JetCluster, JobConfig,
                        PacedGeneratorSource, WallClock)
from repro.core.engine import JOB_COMPLETED
from repro.core.processor import SinkProcessor
from repro.nexmark import NexmarkGenerator, queries

RATE = 4000          # events/s (Python host tier; the device tier does ~40M)
DURATION = 6.0

clock = WallClock()
cluster = JetCluster(n_nodes=3, cooperative_threads=2, clock=clock)
gen = NexmarkGenerator(rate=RATE, n_keys=100)
samples = []
t0 = [None]


def sink_consumer(ev):
    samples.append((clock.now(), ev))


p = queries.q5(
    lambda: PacedGeneratorSource(gen, rate=RATE,
                                 max_events=int(RATE * DURATION)),
    lambda: SinkProcessor(sink_consumer),
    window_ms=1000, slide_ms=50)

t0[0] = clock.now()
job = cluster.submit(p.to_dag(),
                     JobConfig(processing_guarantee=GUARANTEE_EXACTLY_ONCE,
                               snapshot_interval_s=1.0))
killed = False
deadline = time.monotonic() + DURATION * 3 + 10
while job.status != JOB_COMPLETED and time.monotonic() < deadline:
    cluster.step()
    if not killed and clock.now() - t0[0] > DURATION / 2 \
            and job.snapshots_taken >= 1:
        print(f"[{clock.now() - t0[0]:5.2f}s] killing node 2 "
              f"(snapshots taken: {job.snapshots_taken})")
        cluster.kill_node(2)
        killed = True

lat = [(t - (t0[0] + (ev.ts + 1) / 1000.0)) * 1000.0 for t, ev in samples]
lat = lat[len(lat) // 5:]
print(f"survived node kill: {killed}, restarts: {job.restarts}, "
      f"snapshots: {job.snapshots_taken}")
print(f"{len(samples)} window results; latency ms: "
      f"p50={np.percentile(lat, 50):.2f} p99={np.percentile(lat, 99):.2f} "
      f"p99.99={np.percentile(lat, 99.99):.2f}")
print("nexmark_streaming OK")
