"""End-to-end driver (the paper's kind: a serving/streaming system):
serve a small LM with batched requests through the continuous-batching
server — requests are events, slots are credit-based admission, decode
steps are the fused whole-DAG-per-chip tasklet.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchedLMServer
from repro.models import lm

ARCH = "qwen2-1.5b"
N_REQUESTS = 24
MAX_NEW = 24
SLOTS = 8

cfg = get_config(ARCH).reduced()
print(f"serving {ARCH} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
      f"with {SLOTS} slots, {N_REQUESTS} requests x {MAX_NEW} new tokens")
params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
server = BatchedLMServer(cfg, params, batch_slots=SLOTS,
                         max_seq=8 + MAX_NEW + N_REQUESTS * 6 + 16)

rng = np.random.RandomState(0)
pending = [(i, rng.randint(0, cfg.vocab_size, 8).tolist())
           for i in range(N_REQUESTS)]
t0 = time.time()
steps = 0
admitted = 0
while pending or server.active:
    while pending and server.submit(*pending[0], MAX_NEW):
        pending.pop(0)
        admitted += 1
    server.step()
    steps += 1
dt = time.time() - t0
n_tok = sum(len(r["out"]) for r in server.completed)
assert len(server.completed) == N_REQUESTS
assert all(len(r["out"]) == MAX_NEW for r in server.completed)
print(f"served {len(server.completed)} requests / {n_tok} tokens in "
      f"{dt:.2f}s ({n_tok / dt:.0f} tok/s, {steps} decode steps, "
      f"max concurrency {SLOTS})")
print("serve_lm OK")
