"""Train a small LM end-to-end for a few hundred steps with
checkpoint/restart — loss must fall, and a resume from the mid-run
checkpoint must reproduce the straight run exactly (replayable-source
semantics, paper §4.5 applied to the data pipeline).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full-100m]
"""

import argparse
import tempfile

from repro.launch.train import main as train_main


def run(steps: int, full_100m: bool):
    with tempfile.TemporaryDirectory() as d:
        common = (["--arch", "olmo-1b"]
                  + ([] if full_100m else ["--reduced"])
                  + ["--batch", "8",
                     "--seq", "512" if full_100m else "128",
                     "--log-every", "20",
                     "--schedule-steps", str(steps)])
        # straight run
        losses = train_main(common + ["--steps", str(steps)])
        assert losses[-1] < losses[0], "loss did not fall"
        print(f"loss fell {losses[0]:.3f} -> {losses[-1]:.3f}")
        # crash at the half-way checkpoint, then resume to the end
        half = steps // 2
        train_main(common + ["--steps", str(half), "--ckpt-dir", d,
                             "--ckpt-every", str(half)])
        resumed = train_main(common + ["--steps", str(steps),
                                       "--ckpt-dir", d, "--ckpt-every",
                                       str(10 * steps), "--resume"])
        print(f"resume reproduced final loss: {resumed[-1]:.4f} "
              f"(straight: {losses[-1]:.4f})")
        assert abs(resumed[-1] - losses[-1]) / abs(losses[-1]) < 1e-3
    print("train_lm OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true",
                    help="train the real ~100M-param config (slow on CPU)")
    args = ap.parse_args()
    run(args.steps, args.full_100m)
