"""Quickstart: the paper's Listing 1 (word count) on the Pipeline API,
then a 5-line streaming windowed aggregate.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (CollectorSink, JetCluster, Journal, JournalSource,
                        ListSource, Pipeline, VirtualClock, counting,
                        group_aggregate, sliding)

# --- Listing 1: word count ---------------------------------------------------

TEXT = [
    "hazelcast jet is a distributed stream processor",
    "jet keeps latency at the ninety nine point ninety nine percentile low",
    "the jet execution engine runs tasklets on cooperative threads",
]

cluster = JetCluster(n_nodes=2, cooperative_threads=2, clock=VirtualClock())
out = []
p = Pipeline.create()
(p.read_from(lambda: ListSource(TEXT), name="book-lines")
   .flat_map(lambda line: line.split())
   .with_key(lambda w: w)                       # groupingKey(wholeItem)
   .custom_transform("count", group_aggregate(counting()),
                     partitioned=True, distributed=True)
   .write_to(lambda: CollectorSink(out)))
job = cluster.submit(p.to_dag())
cluster.run_until_complete(job)

counts = {ev.key: ev.value for ev in out}
print("word count:", dict(sorted(counts.items(), key=lambda kv: -kv[1])[:5]))
assert counts["jet"] == 3

# --- streaming: windowed aggregate over a keyed event journal -----------------

journal = Journal(n_partitions=8)
for t in range(300):
    journal.append(t, t % 3, (t % 3, 1))        # (ts, key, value)

out2 = []
p2 = Pipeline.create()
(p2.read_from(lambda: JournalSource(journal), name="sensor")
    .with_key(lambda v: v[0])
    .window(sliding(100, 20))                   # 100ms window, 20ms slide
    .aggregate(counting())
    .write_to(lambda: CollectorSink(out2)))
job2 = cluster.submit(p2.to_dag())
cluster.run_until_complete(job2)
print(f"windowed results: {len(out2)} window x key counts, e.g.",
      [(ev.value.window_end, ev.value.key, ev.value.value)
       for ev in out2[:3]])
print("quickstart OK")
