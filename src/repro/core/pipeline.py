"""The Pipeline API: fluent, composable stage graph that the planner lowers
onto the Core DAG (paper §2.1–2.2, Listing 1/2).

The planner performs Jet's two signature optimizations:

* **operator fusion** — maximal chains of stateless stages (map / filter /
  flat_map / re-key) with a single consumer collapse into ONE vertex running
  a :class:`FusedFunctionProcessor` (one Python call per event for the whole
  chain), connected by ISOLATED edges so data stays on its core;
* **two-stage aggregation** — ``window().aggregate()`` lowers into a *local*
  partitioned accumulate vertex followed by a *distributed* partitioned
  combine vertex, so only closed frames travel across nodes.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .dag import DAG, Edge, Routing
from .events import Event, EventBlock, LateEvent
from .processor import (FusedFunctionProcessor, Inbox, Processor,
                        SinkProcessor)
from .window import (AccumulateByFrameProcessor, AggregateOperation,
                     CombineFramesProcessor, SessionWindowDef,
                     SessionWindowProcessor, SlidingWindowDef)


# ---------------------------------------------------------------------------
# Logical stages
# ---------------------------------------------------------------------------


class _Stage:
    _ids = itertools.count()

    def __init__(self, pipeline: "Pipeline", kind: str, name: str,
                 upstreams: List["_Stage"], params: Dict[str, Any]):
        self.pipeline = pipeline
        self.kind = kind
        self.name = f"{name}-{next(_Stage._ids)}"
        self.upstreams = upstreams
        self.params = params
        self.downstream_count = 0
        for up in upstreams:
            up.downstream_count += 1
        pipeline.stages.append(self)


class GeneralStage:
    """User-facing handle over a logical stage."""

    def __init__(self, pipeline: "Pipeline", stage: _Stage):
        self.pipeline = pipeline
        self.stage = stage

    # -- stateless transforms (fusable) -----------------------------------------
    def map(self, fn: Callable[[Any], Any]) -> "GeneralStage":
        return self._compute("map", fn)

    def filter(self, pred: Callable[[Any], bool]) -> "GeneralStage":
        return self._compute("filter", pred)

    def flat_map(self, fn: Callable[[Any], Iterable[Any]]) -> "GeneralStage":
        return self._compute("flat_map", fn)

    def rekey(self, key_fn: Callable[[Any], Any]) -> "GeneralStage":
        """Assign the grouping key (Jet's groupingKey)."""
        return self._compute("rekey", key_fn)

    def _compute(self, op: str, fn) -> "GeneralStage":
        st = _Stage(self.pipeline, "compute", op, [self.stage],
                    {"op": op, "fn": fn})
        return GeneralStage(self.pipeline, st)

    # -- keyed / windowed ---------------------------------------------------------
    def with_key(self, key_fn: Callable[[Any], Any]) -> "KeyedStage":
        return KeyedStage(self.pipeline, self.rekey(key_fn).stage)

    # -- joins ----------------------------------------------------------------------
    def hash_join(self, build: "GeneralStage",
                  probe_key_fn: Callable[[Any], Any],
                  build_key_fn: Callable[[Any], Any],
                  combine_fn: Callable[[Any, Any], Any],
                  inner: bool = True) -> "GeneralStage":
        """Join this (probe, streaming) stage against a batch build stage
        (Listing 2).  The build side is broadcast and fully consumed before
        probing starts."""
        st = _Stage(self.pipeline, "hash_join", "hash_join",
                    [self.stage, build.stage],
                    {"probe_key_fn": probe_key_fn, "build_key_fn": build_key_fn,
                     "combine_fn": combine_fn, "inner": inner})
        return GeneralStage(self.pipeline, st)

    # -- sinks ----------------------------------------------------------------------
    def write_to(self, sink_supplier: Callable[[], Processor]) -> None:
        _Stage(self.pipeline, "sink", "sink", [self.stage],
               {"supplier": sink_supplier})

    def custom_transform(self, name: str,
                         supplier: Callable[[], Processor],
                         partitioned: bool = False,
                         distributed: bool = False) -> "GeneralStage":
        st = _Stage(self.pipeline, "custom", name, [self.stage],
                    {"supplier": supplier, "partitioned": partitioned,
                     "distributed": distributed})
        return GeneralStage(self.pipeline, st)


class KeyedStage(GeneralStage):
    """A stage with a grouping key assigned; adds windowing on top of the
    general transforms (a keyed custom_transform routes by the key)."""

    def window(self, wdef) -> "WindowedStage":
        """``wdef``: a :class:`SlidingWindowDef` or :class:`SessionWindowDef`."""
        return WindowedStage(self.pipeline, self.stage, wdef)


class WindowedStage:
    def __init__(self, pipeline: "Pipeline", stage: _Stage, wdef):
        self.pipeline = pipeline
        self.stage = stage
        self.wdef = wdef
        self._lateness = 0
        self._late_sink: Optional[Callable[[], Processor]] = None

    def allowed_lateness(self, lateness: int) -> "WindowedStage":
        """Keep windows re-firable for ``lateness`` event-time past the
        watermark: admissible late events update already-emitted results;
        anything later is dropped (and counted / side-routed)."""
        if lateness < 0:
            raise ValueError("allowed_lateness must be >= 0")
        self._lateness = lateness
        return self

    def late_sink(self, sink_supplier: Callable[[], Processor]
                  ) -> "WindowedStage":
        """Route events later than the allowed lateness to this sink (as
        :class:`~repro.core.events.LateEvent`) instead of dropping them."""
        self._late_sink = sink_supplier
        return self

    def aggregate(self, op: AggregateOperation, placement: str = "host",
                  device: Optional[Dict[str, Any]] = None) -> GeneralStage:
        """``placement="device"`` offloads the aggregation to the compiled
        device tier: ONE block-aware vertex drives a
        :class:`~repro.core.device_window.DeviceWindowProcessor`
        (StreamExecutor step per padded batch) instead of the host
        two-stage accumulate/combine plan.  ``device`` forwards keyword
        overrides (``n_key_buckets``, ``batch_size``, ...) to the
        processor.  Sessions and allowed lateness stay host-only."""
        if placement == "device":
            if isinstance(self.wdef, SessionWindowDef):
                raise ValueError("session windows run on the host")
            if self._lateness or self._late_sink is not None:
                raise ValueError(
                    "allowed_lateness/late_sink are host-only features")
            st = _Stage(self.pipeline, "window_agg_device", "win_agg_dev",
                        [self.stage],
                        {"wdef": self.wdef, "op": op,
                         "device": device or {}})
            return GeneralStage(self.pipeline, st)
        if placement != "host":
            raise ValueError(f"unknown placement {placement!r}")
        st = _Stage(self.pipeline, "window_agg", "win_agg", [self.stage],
                    {"wdef": self.wdef, "op": op,
                     "lateness": self._lateness,
                     "late_sink": self._late_sink})
        return GeneralStage(self.pipeline, st)

    def aggregate2(self, other: KeyedStage,
                   op: AggregateOperation) -> GeneralStage:
        """Two-input windowed co-aggregation (windowed join substrate,
        NEXMark Q8)."""
        if isinstance(self.wdef, SessionWindowDef):
            raise ValueError("session windows are single-input")
        st = _Stage(self.pipeline, "window_agg2", "win_agg2",
                    [self.stage, other.stage],
                    {"wdef": self.wdef, "op": op,
                     "lateness": self._lateness,
                     "late_sink": self._late_sink})
        return GeneralStage(self.pipeline, st)


class Pipeline:
    def __init__(self):
        self.stages: List[_Stage] = []

    @staticmethod
    def create() -> "Pipeline":
        return Pipeline()

    def read_from(self, source_supplier: Callable[[], Processor],
                  name: str = "source",
                  local_parallelism: int = -1) -> GeneralStage:
        st = _Stage(self, "source", name, [],
                    {"supplier": source_supplier, "lp": local_parallelism})
        return GeneralStage(self, st)

    # ------------------------------------------------------------------ planner --
    def to_dag(self) -> DAG:
        return _Planner(self).plan()


# ---------------------------------------------------------------------------
# Source fusion: run a stateless chain at the source's own outbox
# ---------------------------------------------------------------------------


class _ChainOutbox:
    """Outbox facade that applies a fused stateless chain at emission time.

    Data events run through the chain before landing in the real outbox;
    control items (watermarks) pass straight through.  This is what lets
    the planner collapse ``source -> fused-chain`` into ONE vertex: the
    whole queue hop between them disappears.

    EventBlocks take the vectorized chain (``chain_blk``) when every step
    declared a block form; otherwise they explode here and run the scalar
    chain per event — the source boundary is where per-event semantics are
    restored for black-box chains.  Like the scalar fan-out case, an
    exploded block may overshoot the outbox batch limit (by up to the
    block size); the engine avoids the path entirely for auto-mode
    sources by downgrading them to scalar emission when their chain
    cannot vectorize (see ExecutionContext._build).
    """

    __slots__ = ("_target", "_chain", "_chain1", "_chain_blk")

    def __init__(self, target, chain, chain1=None, chain_blk=None):
        self._target = target
        self._chain = chain
        #: scalar in-place variant (Event -> Event | None); preferred when
        #: the chain has no flat_map — no per-event tuple/Event churn
        self._chain1 = chain1
        #: vectorized variant (EventBlock -> EventBlock | None)
        self._chain_blk = chain_blk

    def _chain_block(self, blk):
        """Block through the chain -> list of result items (0 or 1 block,
        or the exploded per-event results for a scalar-only chain)."""
        chain_blk = self._chain_blk
        if chain_blk is not None:
            out = chain_blk(blk)
            return () if out is None or not len(out) else (out,)
        chain1 = self._chain1
        if chain1 is not None:
            out = []
            append = out.append
            for ev in blk.to_events():
                ev = chain1(ev)
                if ev is not None:
                    append(ev)
            return out
        chain = self._chain
        out = []
        for ev in blk.to_events():
            out.extend(chain(ev))
        return out

    def offer(self, item) -> bool:
        t = self._target
        cls = item.__class__
        if cls is EventBlock:
            outs = self._chain_block(item)
            if not outs:
                return True
            if t.space() <= 0:
                return False
            t.extend(outs)
            return True
        if cls is Event or isinstance(item, Event):
            chain1 = self._chain1
            if chain1 is not None:
                ev = chain1(item)
                return True if ev is None else t.offer(ev)
            outs = self._chain(item)
            if not outs:
                return True         # filtered out: item is consumed
            if t.space() <= 0:
                return False
            t.extend(outs)          # may overshoot by the chain fan-out - 1
            return True
        return t.offer(item)

    def space(self) -> int:
        return self._target.space()

    def extend(self, items) -> None:
        chain1 = self._chain1
        out: List[Any] = []
        append = out.append
        extend = out.extend
        if chain1 is not None:
            for item in items:
                cls = item.__class__
                if cls is EventBlock:
                    extend(self._chain_block(item))
                elif cls is Event or isinstance(item, Event):
                    ev = chain1(item)
                    if ev is not None:
                        append(ev)
                else:
                    append(item)
        else:
            chain = self._chain
            for item in items:
                cls = item.__class__
                if cls is EventBlock:
                    extend(self._chain_block(item))
                elif cls is Event or isinstance(item, Event):
                    extend(chain(item))
                else:
                    append(item)
        self._target.extend(out)

    def offer_to_snapshot(self, key, value) -> bool:
        return self._target.offer_to_snapshot(key, value)

    @property
    def snapshot_queue(self):
        return self._target.snapshot_queue

    def drain(self):
        return self._target.drain()

    def __len__(self):
        return len(self._target)


class ChainedSourceProcessor(Processor):
    """Wraps a source processor so a fused stateless chain runs at its
    outbox (operator fusion extended through the source boundary, §3.1)."""

    def __init__(self, inner: Processor, chain, chain1=None, chain_blk=None):
        self.inner = inner
        self._chain = chain
        self._chain1 = chain1
        self._chain_blk = chain_blk
        self.is_cooperative = inner.is_cooperative
        # optional hooks the engine discovers via getattr
        if hasattr(inner, "snapshot_partition"):
            self.snapshot_partition = inner.snapshot_partition
        if hasattr(inner, "on_snapshot_committed"):
            self.on_snapshot_committed = inner.on_snapshot_committed

    def init(self, outbox, ctx) -> None:
        super().init(outbox, ctx)
        self.inner.init(_ChainOutbox(outbox, self._chain, self._chain1,
                                     self._chain_blk), ctx)

    def process(self, ordinal: int, inbox: Inbox) -> None:
        self.inner.process(ordinal, inbox)

    def try_process_watermark(self, wm) -> bool:
        return self.inner.try_process_watermark(wm)

    def complete_edge(self, ordinal: int) -> bool:
        return self.inner.complete_edge(ordinal)

    def complete(self) -> bool:
        return self.inner.complete()

    def save_to_snapshot(self) -> bool:
        return self.inner.save_to_snapshot()

    def restore_from_snapshot(self, items) -> None:
        self.inner.restore_from_snapshot(items)

    def finish_snapshot_restore(self) -> None:
        self.inner.finish_snapshot_restore()

    def close(self) -> None:
        self.inner.close()


# ---------------------------------------------------------------------------
# Late-event side output
# ---------------------------------------------------------------------------


class LateSplitProcessor(Processor):
    """Feeds only :class:`LateEvent` items to the wrapped sink processor.

    A window vertex with a late side output emits LateEvents interleaved
    with its regular output; the tasklet fan-out broadcasts every item to
    every out-edge, so each endpoint filters for its half: this wrapper on
    the late edge, the combiner / a drop-filter on the main edge.
    """

    #: _pending is pre-barrier input in flight: save_to_snapshot refuses
    #: to finish until _drain_pending() emptied it into the inner sink,
    #: so it is empty in every committed snapshot by construction
    EPHEMERAL_STATE = frozenset({"_pending"})

    def __init__(self, inner: Processor):
        self.inner = inner
        self.is_cooperative = inner.is_cooperative
        #: LateEvents the wrapped sink deferred under backpressure — kept
        #: (not dropped) per the processor contract and re-offered later
        self._pending = Inbox()
        # expose the inner sink's snapshot hooks (transactional/idempotent
        # late sinks), mirroring ChainedSourceProcessor
        if hasattr(inner, "snapshot_partition"):
            self.snapshot_partition = inner.snapshot_partition
        if hasattr(inner, "on_snapshot_committed"):
            self.on_snapshot_committed = inner.on_snapshot_committed

    def init(self, outbox, ctx) -> None:
        super().init(outbox, ctx)
        self.inner.init(outbox, ctx)

    def process(self, ordinal: int, inbox: Inbox) -> None:
        pend = self._pending
        for ev in inbox:
            if isinstance(ev, LateEvent):
                # jetlint: disable=hot-path-unbounded-growth -- the wrapped sink drains _pending on every process() call and before every barrier; it only holds one slice's deferred LateEvents
                pend.add(ev)
        inbox.clear()
        if len(pend):
            self.inner.process(ordinal, pend)

    def complete(self) -> bool:
        if not self._drain_pending():
            return False
        return self.inner.complete()

    def _drain_pending(self) -> bool:
        if len(self._pending):
            self.inner.process(0, self._pending)
        return not len(self._pending)

    # -- snapshots: deferred LateEvents are pre-barrier input and must be
    # consumed (or the save retried) before the barrier, else a restore
    # loses them — replay resumes after the barrier and never re-delivers
    def save_to_snapshot(self) -> bool:
        if not self._drain_pending():
            return False
        return self.inner.save_to_snapshot()

    def restore_from_snapshot(self, items) -> None:
        self.inner.restore_from_snapshot(items)

    def finish_snapshot_restore(self) -> None:
        self.inner.finish_snapshot_restore()

    def close(self) -> None:
        self.inner.close()


def _drop_late_chain(ev):
    """Fused-chain step: drop LateEvents on the main output path."""
    return () if isinstance(ev, LateEvent) else (ev,)


# ---------------------------------------------------------------------------
# Join / batch-aggregate processors used by the planner
# ---------------------------------------------------------------------------


class HashJoinProcessor(Processor):
    """Ordinal 1 = build (batch, priority 0), ordinal 0 = probe."""

    #: edge-exhaustion flag; a restored job replays the (batch) build
    #: edge from its source and re-derives it — only ``table`` is state
    EPHEMERAL_STATE = frozenset({"build_done"})

    def __init__(self, probe_key_fn, build_key_fn, combine_fn, inner=True):
        self.probe_key_fn = probe_key_fn
        self.build_key_fn = build_key_fn
        self.combine_fn = combine_fn
        self.inner = inner
        self.table: Dict[Any, Any] = {}
        self.build_done = False

    def process(self, ordinal: int, inbox: Inbox) -> None:
        if ordinal == 1:
            while True:
                ev = inbox.poll()
                if ev is None:
                    return
                self.table[self.build_key_fn(ev.value)] = ev.value
            return
        offer = self.outbox.offer
        while True:
            ev = inbox.peek()
            if ev is None:
                return
            k = self.probe_key_fn(ev.value)
            match = self.table.get(k)
            if match is not None or not self.inner:
                if not offer(ev.with_value((ev.value, match))):
                    return
            inbox.remove()

    def complete_edge(self, ordinal: int) -> bool:
        if ordinal == 1:
            self.build_done = True
        return True

    def save_to_snapshot(self) -> bool:
        for k, v in self.table.items():
            self.outbox.offer_to_snapshot(("ht", k), v)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (tag, k), v in items:
            if tag == "ht":
                self.table[k] = v


class GroupAggregateProcessor(Processor):
    """Batch keyed aggregation: accumulate everything, emit on complete."""

    #: _emit is the complete()-phase emission stage, rebuilt from the
    #: snapshotted ``accs`` on replay (complete() re-runs after restore)
    EPHEMERAL_STATE = frozenset({"_emit"})

    def __init__(self, op: AggregateOperation):
        self.op = op
        self.accs: Dict[Any, Any] = {}
        self._emit: Optional[List] = None

    def process(self, ordinal: int, inbox: Inbox) -> None:
        op, accs = self.op, self.accs
        acc_fn = op.accumulate_fns[min(ordinal, len(op.accumulate_fns) - 1)]
        while True:
            ev = inbox.poll()
            if ev is None:
                return
            acc = accs.get(ev.key)
            if acc is None:
                acc = op.create()
            accs[ev.key] = acc_fn(acc, ev)

    def complete(self) -> bool:
        if self._emit is None:
            self._emit = [Event(0, k, self.op.export(a))
                          for k, a in self.accs.items()]
        while self._emit:
            if not self.outbox.offer(self._emit[-1]):
                return False
            self._emit.pop()
        return True

    def save_to_snapshot(self) -> bool:
        for k, acc in self.accs.items():
            self.outbox.offer_to_snapshot(("acc", k), acc)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (tag, k), acc in items:
            if tag == "acc":
                cur = self.accs.get(k)
                self.accs[k] = acc if cur is None else self.op.combine(cur, acc)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


#: scalar-op dispatch codes shared by both chain compilers
_MAP, _FILTER, _REKEY = 0, 1, 2
_SCALAR_KINDS = {"map": _MAP, "filter": _FILTER, "rekey": _REKEY}


def _scalar_steps(ops: List[Tuple[str, Callable]]):
    """(kind, fn) steps for an all-scalar chain (every stage yields 0 or 1
    events), or None if any stage can fan out (flat_map)."""
    if not all(op in _SCALAR_KINDS for op, _ in ops):
        return None
    return tuple((_SCALAR_KINDS[op], fn) for op, fn in ops)


def _compile_chain_inplace(ops: List[Tuple[str, Callable]]):
    """Scalar-chain variant that mutates the event in place.

    Only safe where the caller OWNS the event — i.e. source fusion, where
    the event was just created by the source and has not entered any queue
    yet.  Returns None for non-scalar chains (flat_map)."""
    scalar = _scalar_steps(ops)
    if scalar is None:
        return None

    def chain_inplace(ev, _steps=scalar):
        """Event -> Event | None (no per-event tuple)."""
        for kind, f in _steps:
            if kind == 1:
                if not f(ev.value):
                    return None
            elif kind == 0:
                ev.value = f(ev.value)
            else:
                ev.key = f(ev.value)
        return ev

    return chain_inplace


def _compile_chain_block(ops: List[Tuple[str, Callable]]):
    """Vectorized chain variant: EventBlock -> EventBlock | None.

    Compiles only when the chain is all-scalar (no flat_map) AND every
    stage function carries a block form (see
    :func:`~repro.core.events.block_form`); otherwise returns None and
    blocks explode to events at the chain boundary.
    """
    scalar = _scalar_steps(ops)
    if scalar is None:
        return None
    if not all(hasattr(fn, "__block_form__") for _, fn in ops):
        return None
    steps = tuple((kind, fn.__block_form__)
                  for (kind, _), (_, fn) in zip(scalar, ops))

    def chain_block(blk, _steps=steps):
        """EventBlock -> EventBlock | None (None == fully filtered)."""
        for kind, f in _steps:
            if kind == 1:
                mask = f(blk)
                if not mask.all():
                    blk = blk.compress(mask)
                    if not len(blk):
                        return None
            elif kind == 0:
                blk = blk.with_value_col(f(blk))
            else:
                blk = blk.with_key_col(f(blk))
        return blk

    return chain_block


def _compile_chain(ops: List[Tuple[str, Callable]]):
    """Compose a fused op chain into one Event -> tuple(Event) closure."""
    steps = []
    for op, fn in ops:
        if op == "map":
            steps.append(lambda ev, f=fn: (ev.with_value(f(ev.value)),))
        elif op == "filter":
            steps.append(lambda ev, f=fn: (ev,) if f(ev.value) else ())
        elif op == "flat_map":
            steps.append(lambda ev, f=fn: tuple(
                ev.with_value(v) for v in f(ev.value)))
        elif op == "rekey":
            steps.append(lambda ev, f=fn: (ev.with_key(f(ev.value)),))
        else:  # pragma: no cover
            raise ValueError(op)
    if len(steps) == 1:
        return steps[0]

    scalar = _scalar_steps(ops)
    if scalar is not None:
        # scalar chain: every stage yields 0 or 1 events, so the whole
        # chain runs as a straight-line loop over the event — no per-stage
        # tuple/list churn (this is the shape the fusion planner produces
        # for nearly every stateless pipeline segment)

        def chain_scalar(ev, _steps=scalar):
            for kind, f in _steps:
                if kind == 1:
                    if not f(ev.value):
                        return ()
                elif kind == 0:
                    ev = ev.with_value(f(ev.value))
                else:
                    ev = ev.with_key(f(ev.value))
            return (ev,)

        return chain_scalar

    def chain(ev, _steps=tuple(steps)):
        evs = (ev,)
        for s in _steps:
            out: List[Event] = []
            for e in evs:
                out.extend(s(e))
            if not out:
                return ()
            evs = out
        return tuple(evs)

    return chain


class _Planner:
    def __init__(self, pipeline: Pipeline):
        self.p = pipeline
        self.dag = DAG()
        #: logical stage -> (dag vertex name, preferred out-routing hints)
        self.vertex_of: Dict[_Stage, str] = {}
        self._out_ordinals: Dict[str, int] = {}

    def plan(self) -> DAG:
        consumed: set = set()
        for st in self.p.stages:
            if st in consumed:
                continue
            if st.kind == "source":
                self.dag.vertex(st.name, st.params["supplier"],
                                st.params.get("lp", -1))
                self.vertex_of[st] = st.name
            elif st.kind == "compute":
                chain, last = self._collect_chain(st, consumed)
                fused = _compile_chain([(s.params["op"], s.params["fn"])
                                        for s in chain])
                up = chain[0].upstreams[0]
                chain_ops = [(s.params["op"], s.params["fn"]) for s in chain]
                blocked = _compile_chain_block(chain_ops)
                if up.kind == "source" and up.downstream_count == 1:
                    # source fusion: the chain runs inside the source
                    # vertex itself — no intermediate vertex, no queue hop.
                    # The source owns each event until it enters a queue,
                    # so a scalar chain may rewrite it in place.
                    inplace = _compile_chain_inplace(chain_ops)
                    src_name = self.vertex_of[up]
                    vertex = self.dag.vertices[src_name]
                    supplier = vertex.supplier
                    vertex.supplier = (
                        lambda s=supplier, c=fused, c1=inplace, cb=blocked:
                        ChainedSourceProcessor(s(), c, c1, cb))
                    # rename so telemetry (straggler reports) attributes
                    # the chain's cost to it; no edges reference the
                    # source yet, so only the vertex table changes
                    new_name = f"{src_name}+{last.name}"
                    vertex.name = new_name
                    self.dag.vertices = {
                        (new_name if k == src_name else k): v
                        for k, v in self.dag.vertices.items()}
                    self.vertex_of[up] = new_name
                    for s in chain:
                        self.vertex_of[s] = new_name
                    continue
                name = last.name
                self.dag.vertex(
                    name, (lambda c=fused, cb=blocked:
                           FusedFunctionProcessor(c, cb)))
                self.vertex_of[last] = name
                for s in chain:
                    self.vertex_of[s] = name
                self._connect(up, name,
                              Edge(self._vname(up), name,
                                   routing=Routing.ISOLATED))
            elif st.kind in ("window_agg", "window_agg2"):
                self._plan_window_agg(st)
            elif st.kind == "window_agg_device":
                self._plan_window_agg_device(st)
            elif st.kind == "hash_join":
                self._plan_hash_join(st)
            elif st.kind == "sink":
                self.dag.vertex(st.name, st.params["supplier"])
                self.vertex_of[st] = st.name
                self._connect(st.upstreams[0], st.name,
                              Edge(self._vname(st.upstreams[0]), st.name,
                                   routing=Routing.ISOLATED))
            elif st.kind == "custom":
                self.dag.vertex(st.name, st.params["supplier"])
                self.vertex_of[st] = st.name
                routing = (Routing.PARTITIONED if st.params["partitioned"]
                           else Routing.ISOLATED)
                e = Edge(self._vname(st.upstreams[0]), st.name, routing=routing,
                         distributed=st.params["distributed"])
                self._connect(st.upstreams[0], st.name, e)
            elif st.kind == "custom2":
                # keyed two-input processor (incremental joins): both sides
                # partition+distribute so equal keys colocate
                self.dag.vertex(st.name, st.params["supplier"])
                self.vertex_of[st] = st.name
                for i, up in enumerate(st.upstreams):
                    e = Edge(self._vname(up), st.name, dst_ordinal=i,
                             routing=Routing.PARTITIONED, distributed=True)
                    self._connect_up(up, e)
            else:  # pragma: no cover
                raise ValueError(st.kind)
        self.dag.validate()
        return self.dag

    # -- helpers -------------------------------------------------------------
    def _vname(self, stage: _Stage) -> str:
        return self.vertex_of[stage]

    def _collect_chain(self, st: _Stage, consumed: set):
        """Greedy maximal fusion of a stateless chain starting at ``st``."""
        chain = [st]
        consumed.add(st)
        idx = self.p.stages.index(st)
        cur = st
        for nxt in self.p.stages[idx + 1:]:
            if (nxt.kind == "compute" and nxt.upstreams == [cur]
                    and cur.downstream_count == 1):
                chain.append(nxt)
                consumed.add(nxt)
                cur = nxt
            elif nxt.upstreams and cur in nxt.upstreams:
                break
        return chain, cur

    def _next_ordinal(self, vertex: str, side: str) -> int:
        key = f"{side}:{vertex}"
        n = self._out_ordinals.get(key, 0)
        self._out_ordinals[key] = n + 1
        return n

    def _connect(self, up_stage: _Stage, dst: str, edge: Edge) -> None:
        src = self._vname(up_stage)
        edge.src_ordinal = self._next_ordinal(src, "out")
        if edge.dst_ordinal == 0:
            edge.dst_ordinal = self._next_ordinal(dst, "in")
        self.dag.edge(edge)

    def _plan_window_agg(self, st: _Stage) -> None:
        wdef = st.params["wdef"]
        op: AggregateOperation = st.params["op"]
        lateness: int = st.params.get("lateness", 0)
        late_sink = st.params.get("late_sink")
        if isinstance(wdef, SessionWindowDef):
            self._plan_session_agg(st, wdef, op, lateness, late_sink)
            return
        two_input = st.kind == "window_agg2"
        acc_name = st.name + ".accumulate"
        cmb_name = st.name + ".combine"
        ordinal_map = {0: 0, 1: 1} if two_input else None
        has_late = late_sink is not None
        self.dag.vertex(acc_name,
                        lambda w=wdef, o=op, m=ordinal_map:
                        AccumulateByFrameProcessor(
                            w, o, m, allowed_lateness=lateness,
                            late_output=has_late))
        self.dag.vertex(cmb_name,
                        lambda w=wdef, o=op: CombineFramesProcessor(
                            w, o, allowed_lateness=lateness,
                            skip_late=has_late))
        # local partitioned edge(s) into the accumulator
        for i, up in enumerate(st.upstreams):
            e = Edge(self._vname(up), acc_name, dst_ordinal=i,
                     routing=Routing.PARTITIONED)
            self._connect_up(up, e)
        # distributed partitioned edge to the combiner
        e2 = Edge(acc_name, cmb_name, routing=Routing.PARTITIONED,
                  distributed=True)
        e2.src_ordinal = self._next_ordinal(acc_name, "out")
        self.dag.edge(e2)
        if has_late:
            self._wire_late_sink(st.name, acc_name, late_sink)
        self.vertex_of[st] = cmb_name

    def _plan_window_agg_device(self, st: _Stage) -> None:
        """Device placement: a block-aware vertex on a distributed
        partitioned edge, so EventBlocks route vectorized straight into
        the device packer.  Each parallel instance owns a StreamExecutor
        over its key-partition subset — partitioning of device state
        follows partitioning of compute, like the host two-stage plan."""
        from .device_window import DeviceWindowProcessor
        name = st.name + ".device"
        self.dag.vertex(
            name,
            (lambda w=st.params["wdef"], o=st.params["op"],
                    kw=st.params["device"]:
             DeviceWindowProcessor(w, o, **kw)))
        e = Edge(self._vname(st.upstreams[0]), name,
                 routing=Routing.PARTITIONED, distributed=True)
        self._connect_up(st.upstreams[0], e)
        self.vertex_of[st] = name

    def _plan_session_agg(self, st: _Stage, wdef: SessionWindowDef,
                          op: AggregateOperation, lateness: int,
                          late_sink) -> None:
        """Sessions run as ONE keyed vertex on a distributed partitioned
        edge — merging is key-local and the frame grid is data-dependent,
        so there is no two-stage split."""
        name = st.name + ".session"
        has_late = late_sink is not None
        self.dag.vertex(name,
                        lambda w=wdef, o=op: SessionWindowProcessor(
                            w, o, allowed_lateness=lateness,
                            late_output=has_late))
        e = Edge(self._vname(st.upstreams[0]), name,
                 routing=Routing.PARTITIONED, distributed=True)
        self._connect_up(st.upstreams[0], e)
        if has_late:
            self._wire_late_sink(st.name, name, late_sink)
            # the session vertex now interleaves LateEvents with results on
            # every out-edge: shield the main path with a drop filter
            flt_name = st.name + ".drop-late"
            self.dag.vertex(flt_name,
                            lambda: FusedFunctionProcessor(_drop_late_chain))
            ef = Edge(name, flt_name, routing=Routing.ISOLATED)
            ef.src_ordinal = self._next_ordinal(name, "out")
            self.dag.edge(ef)
            name = flt_name
        self.vertex_of[st] = name

    def _wire_late_sink(self, stage_name: str, src_vertex: str,
                        late_sink) -> None:
        late_name = stage_name + ".late"
        self.dag.vertex(late_name,
                        lambda s=late_sink: LateSplitProcessor(s()))
        e = Edge(src_vertex, late_name, routing=Routing.ISOLATED)
        e.src_ordinal = self._next_ordinal(src_vertex, "out")
        self.dag.edge(e)

    def _connect_up(self, up: _Stage, edge: Edge) -> None:
        src = self._vname(up)
        edge.src_ordinal = self._next_ordinal(src, "out")
        self.dag.edge(edge)

    def _plan_hash_join(self, st: _Stage) -> None:
        p = st.params
        name = st.name
        self.dag.vertex(
            name, lambda: HashJoinProcessor(
                p["probe_key_fn"], p["build_key_fn"], p["combine_fn"],
                p["inner"]))
        probe, build = st.upstreams
        # build side: broadcast + distributed, higher drain priority (0)
        eb = Edge(self._vname(build), name, dst_ordinal=1,
                  routing=Routing.BROADCAST, distributed=True, priority=0)
        self._connect_up(build, eb)
        ep = Edge(self._vname(probe), name, dst_ordinal=0,
                  routing=Routing.ISOLATED, priority=1)
        self._connect_up(probe, ep)
        self.vertex_of[st] = name


def group_aggregate(op: AggregateOperation) -> Callable[[], Processor]:
    """Supplier for a batch keyed aggregation vertex (use with
    ``custom_transform(partitioned=True, distributed=True)``)."""
    return lambda: GroupAggregateProcessor(op)
