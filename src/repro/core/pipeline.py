"""The Pipeline API: fluent, composable stage graph that the planner lowers
onto the Core DAG (paper §2.1–2.2, Listing 1/2).

The planner performs Jet's two signature optimizations:

* **operator fusion** — maximal chains of stateless stages (map / filter /
  flat_map / re-key) with a single consumer collapse into ONE vertex running
  a :class:`FusedFunctionProcessor` (one Python call per event for the whole
  chain), connected by ISOLATED edges so data stays on its core;
* **two-stage aggregation** — ``window().aggregate()`` lowers into a *local*
  partitioned accumulate vertex followed by a *distributed* partitioned
  combine vertex, so only closed frames travel across nodes.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .dag import DAG, Edge, Routing
from .events import Event
from .processor import (FusedFunctionProcessor, Inbox, Processor,
                        SinkProcessor)
from .window import (AccumulateByFrameProcessor, AggregateOperation,
                     CombineFramesProcessor, SlidingWindowDef)


# ---------------------------------------------------------------------------
# Logical stages
# ---------------------------------------------------------------------------


class _Stage:
    _ids = itertools.count()

    def __init__(self, pipeline: "Pipeline", kind: str, name: str,
                 upstreams: List["_Stage"], params: Dict[str, Any]):
        self.pipeline = pipeline
        self.kind = kind
        self.name = f"{name}-{next(_Stage._ids)}"
        self.upstreams = upstreams
        self.params = params
        self.downstream_count = 0
        for up in upstreams:
            up.downstream_count += 1
        pipeline.stages.append(self)


class GeneralStage:
    """User-facing handle over a logical stage."""

    def __init__(self, pipeline: "Pipeline", stage: _Stage):
        self.pipeline = pipeline
        self.stage = stage

    # -- stateless transforms (fusable) -----------------------------------------
    def map(self, fn: Callable[[Any], Any]) -> "GeneralStage":
        return self._compute("map", fn)

    def filter(self, pred: Callable[[Any], bool]) -> "GeneralStage":
        return self._compute("filter", pred)

    def flat_map(self, fn: Callable[[Any], Iterable[Any]]) -> "GeneralStage":
        return self._compute("flat_map", fn)

    def rekey(self, key_fn: Callable[[Any], Any]) -> "GeneralStage":
        """Assign the grouping key (Jet's groupingKey)."""
        return self._compute("rekey", key_fn)

    def _compute(self, op: str, fn) -> "GeneralStage":
        st = _Stage(self.pipeline, "compute", op, [self.stage],
                    {"op": op, "fn": fn})
        return GeneralStage(self.pipeline, st)

    # -- keyed / windowed ---------------------------------------------------------
    def with_key(self, key_fn: Callable[[Any], Any]) -> "KeyedStage":
        return KeyedStage(self.pipeline, self.rekey(key_fn).stage)

    # -- joins ----------------------------------------------------------------------
    def hash_join(self, build: "GeneralStage",
                  probe_key_fn: Callable[[Any], Any],
                  build_key_fn: Callable[[Any], Any],
                  combine_fn: Callable[[Any, Any], Any],
                  inner: bool = True) -> "GeneralStage":
        """Join this (probe, streaming) stage against a batch build stage
        (Listing 2).  The build side is broadcast and fully consumed before
        probing starts."""
        st = _Stage(self.pipeline, "hash_join", "hash_join",
                    [self.stage, build.stage],
                    {"probe_key_fn": probe_key_fn, "build_key_fn": build_key_fn,
                     "combine_fn": combine_fn, "inner": inner})
        return GeneralStage(self.pipeline, st)

    # -- sinks ----------------------------------------------------------------------
    def write_to(self, sink_supplier: Callable[[], Processor]) -> None:
        _Stage(self.pipeline, "sink", "sink", [self.stage],
               {"supplier": sink_supplier})

    def custom_transform(self, name: str,
                         supplier: Callable[[], Processor],
                         partitioned: bool = False,
                         distributed: bool = False) -> "GeneralStage":
        st = _Stage(self.pipeline, "custom", name, [self.stage],
                    {"supplier": supplier, "partitioned": partitioned,
                     "distributed": distributed})
        return GeneralStage(self.pipeline, st)


class KeyedStage(GeneralStage):
    """A stage with a grouping key assigned; adds windowing on top of the
    general transforms (a keyed custom_transform routes by the key)."""

    def window(self, wdef: SlidingWindowDef) -> "WindowedStage":
        return WindowedStage(self.pipeline, self.stage, wdef)


class WindowedStage:
    def __init__(self, pipeline: "Pipeline", stage: _Stage,
                 wdef: SlidingWindowDef):
        self.pipeline = pipeline
        self.stage = stage
        self.wdef = wdef

    def aggregate(self, op: AggregateOperation) -> GeneralStage:
        st = _Stage(self.pipeline, "window_agg", "win_agg", [self.stage],
                    {"wdef": self.wdef, "op": op})
        return GeneralStage(self.pipeline, st)

    def aggregate2(self, other: KeyedStage,
                   op: AggregateOperation) -> GeneralStage:
        """Two-input windowed co-aggregation (windowed join substrate,
        NEXMark Q8)."""
        st = _Stage(self.pipeline, "window_agg2", "win_agg2",
                    [self.stage, other.stage], {"wdef": self.wdef, "op": op})
        return GeneralStage(self.pipeline, st)


class Pipeline:
    def __init__(self):
        self.stages: List[_Stage] = []

    @staticmethod
    def create() -> "Pipeline":
        return Pipeline()

    def read_from(self, source_supplier: Callable[[], Processor],
                  name: str = "source",
                  local_parallelism: int = -1) -> GeneralStage:
        st = _Stage(self, "source", name, [],
                    {"supplier": source_supplier, "lp": local_parallelism})
        return GeneralStage(self, st)

    # ------------------------------------------------------------------ planner --
    def to_dag(self) -> DAG:
        return _Planner(self).plan()


# ---------------------------------------------------------------------------
# Join / batch-aggregate processors used by the planner
# ---------------------------------------------------------------------------


class HashJoinProcessor(Processor):
    """Ordinal 1 = build (batch, priority 0), ordinal 0 = probe."""

    def __init__(self, probe_key_fn, build_key_fn, combine_fn, inner=True):
        self.probe_key_fn = probe_key_fn
        self.build_key_fn = build_key_fn
        self.combine_fn = combine_fn
        self.inner = inner
        self.table: Dict[Any, Any] = {}
        self.build_done = False

    def process(self, ordinal: int, inbox: Inbox) -> None:
        if ordinal == 1:
            while True:
                ev = inbox.poll()
                if ev is None:
                    return
                self.table[self.build_key_fn(ev.value)] = ev.value
            return
        offer = self.outbox.offer
        while True:
            ev = inbox.peek()
            if ev is None:
                return
            k = self.probe_key_fn(ev.value)
            match = self.table.get(k)
            if match is not None or not self.inner:
                if not offer(ev.with_value((ev.value, match))):
                    return
            inbox.remove()

    def complete_edge(self, ordinal: int) -> bool:
        if ordinal == 1:
            self.build_done = True
        return True

    def save_to_snapshot(self) -> bool:
        for k, v in self.table.items():
            self.outbox.offer_to_snapshot(("ht", k), v)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (tag, k), v in items:
            if tag == "ht":
                self.table[k] = v


class GroupAggregateProcessor(Processor):
    """Batch keyed aggregation: accumulate everything, emit on complete."""

    def __init__(self, op: AggregateOperation):
        self.op = op
        self.accs: Dict[Any, Any] = {}
        self._emit: Optional[List] = None

    def process(self, ordinal: int, inbox: Inbox) -> None:
        op, accs = self.op, self.accs
        acc_fn = op.accumulate_fns[min(ordinal, len(op.accumulate_fns) - 1)]
        while True:
            ev = inbox.poll()
            if ev is None:
                return
            acc = accs.get(ev.key)
            if acc is None:
                acc = op.create()
            accs[ev.key] = acc_fn(acc, ev)

    def complete(self) -> bool:
        if self._emit is None:
            self._emit = [Event(0, k, self.op.export(a))
                          for k, a in self.accs.items()]
        while self._emit:
            if not self.outbox.offer(self._emit[-1]):
                return False
            self._emit.pop()
        return True

    def save_to_snapshot(self) -> bool:
        for k, acc in self.accs.items():
            self.outbox.offer_to_snapshot(("acc", k), acc)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (tag, k), acc in items:
            if tag == "acc":
                cur = self.accs.get(k)
                self.accs[k] = acc if cur is None else self.op.combine(cur, acc)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def _compile_chain(ops: List[Tuple[str, Callable]]):
    """Compose a fused op chain into one Event -> tuple(Event) closure."""
    steps = []
    for op, fn in ops:
        if op == "map":
            steps.append(lambda ev, f=fn: (ev.with_value(f(ev.value)),))
        elif op == "filter":
            steps.append(lambda ev, f=fn: (ev,) if f(ev.value) else ())
        elif op == "flat_map":
            steps.append(lambda ev, f=fn: tuple(
                ev.with_value(v) for v in f(ev.value)))
        elif op == "rekey":
            steps.append(lambda ev, f=fn: (ev.with_key(f(ev.value)),))
        else:  # pragma: no cover
            raise ValueError(op)
    if len(steps) == 1:
        return steps[0]

    def chain(ev, _steps=tuple(steps)):
        evs = (ev,)
        for s in _steps:
            out: List[Event] = []
            for e in evs:
                out.extend(s(e))
            if not out:
                return ()
            evs = out
        return tuple(evs)

    return chain


class _Planner:
    def __init__(self, pipeline: Pipeline):
        self.p = pipeline
        self.dag = DAG()
        #: logical stage -> (dag vertex name, preferred out-routing hints)
        self.vertex_of: Dict[_Stage, str] = {}
        self._out_ordinals: Dict[str, int] = {}

    def plan(self) -> DAG:
        consumed: set = set()
        for st in self.p.stages:
            if st in consumed:
                continue
            if st.kind == "source":
                self.dag.vertex(st.name, st.params["supplier"],
                                st.params.get("lp", -1))
                self.vertex_of[st] = st.name
            elif st.kind == "compute":
                chain, last = self._collect_chain(st, consumed)
                name = last.name
                fused = _compile_chain([(s.params["op"], s.params["fn"])
                                        for s in chain])
                self.dag.vertex(
                    name, (lambda c=fused: FusedFunctionProcessor(c)))
                self.vertex_of[last] = name
                for s in chain:
                    self.vertex_of[s] = name
                self._connect(chain[0].upstreams[0], name,
                              Edge(self._vname(chain[0].upstreams[0]), name,
                                   routing=Routing.ISOLATED))
            elif st.kind in ("window_agg", "window_agg2"):
                self._plan_window_agg(st)
            elif st.kind == "hash_join":
                self._plan_hash_join(st)
            elif st.kind == "sink":
                self.dag.vertex(st.name, st.params["supplier"])
                self.vertex_of[st] = st.name
                self._connect(st.upstreams[0], st.name,
                              Edge(self._vname(st.upstreams[0]), st.name,
                                   routing=Routing.ISOLATED))
            elif st.kind == "custom":
                self.dag.vertex(st.name, st.params["supplier"])
                self.vertex_of[st] = st.name
                routing = (Routing.PARTITIONED if st.params["partitioned"]
                           else Routing.ISOLATED)
                e = Edge(self._vname(st.upstreams[0]), st.name, routing=routing,
                         distributed=st.params["distributed"])
                self._connect(st.upstreams[0], st.name, e)
            elif st.kind == "custom2":
                # keyed two-input processor (incremental joins): both sides
                # partition+distribute so equal keys colocate
                self.dag.vertex(st.name, st.params["supplier"])
                self.vertex_of[st] = st.name
                for i, up in enumerate(st.upstreams):
                    e = Edge(self._vname(up), st.name, dst_ordinal=i,
                             routing=Routing.PARTITIONED, distributed=True)
                    self._connect_up(up, e)
            else:  # pragma: no cover
                raise ValueError(st.kind)
        self.dag.validate()
        return self.dag

    # -- helpers -------------------------------------------------------------
    def _vname(self, stage: _Stage) -> str:
        return self.vertex_of[stage]

    def _collect_chain(self, st: _Stage, consumed: set):
        """Greedy maximal fusion of a stateless chain starting at ``st``."""
        chain = [st]
        consumed.add(st)
        idx = self.p.stages.index(st)
        cur = st
        for nxt in self.p.stages[idx + 1:]:
            if (nxt.kind == "compute" and nxt.upstreams == [cur]
                    and cur.downstream_count == 1):
                chain.append(nxt)
                consumed.add(nxt)
                cur = nxt
            elif nxt.upstreams and cur in nxt.upstreams:
                break
        return chain, cur

    def _next_ordinal(self, vertex: str, side: str) -> int:
        key = f"{side}:{vertex}"
        n = self._out_ordinals.get(key, 0)
        self._out_ordinals[key] = n + 1
        return n

    def _connect(self, up_stage: _Stage, dst: str, edge: Edge) -> None:
        src = self._vname(up_stage)
        edge.src_ordinal = self._next_ordinal(src, "out")
        if edge.dst_ordinal == 0:
            edge.dst_ordinal = self._next_ordinal(dst, "in")
        self.dag.edge(edge)

    def _plan_window_agg(self, st: _Stage) -> None:
        wdef: SlidingWindowDef = st.params["wdef"]
        op: AggregateOperation = st.params["op"]
        two_input = st.kind == "window_agg2"
        acc_name = st.name + ".accumulate"
        cmb_name = st.name + ".combine"
        ordinal_map = {0: 0, 1: 1} if two_input else None
        self.dag.vertex(acc_name,
                        lambda w=wdef, o=op, m=ordinal_map:
                        AccumulateByFrameProcessor(w, o, m))
        self.dag.vertex(cmb_name,
                        lambda w=wdef, o=op: CombineFramesProcessor(w, o))
        # local partitioned edge(s) into the accumulator
        for i, up in enumerate(st.upstreams):
            e = Edge(self._vname(up), acc_name, dst_ordinal=i,
                     routing=Routing.PARTITIONED)
            self._connect_up(up, e)
        # distributed partitioned edge to the combiner
        e2 = Edge(acc_name, cmb_name, routing=Routing.PARTITIONED,
                  distributed=True)
        e2.src_ordinal = self._next_ordinal(acc_name, "out")
        self.dag.edge(e2)
        self.vertex_of[st] = cmb_name

    def _connect_up(self, up: _Stage, edge: Edge) -> None:
        src = self._vname(up)
        edge.src_ordinal = self._next_ordinal(src, "out")
        self.dag.edge(edge)

    def _plan_hash_join(self, st: _Stage) -> None:
        p = st.params
        name = st.name
        self.dag.vertex(
            name, lambda: HashJoinProcessor(
                p["probe_key_fn"], p["build_key_fn"], p["combine_fn"],
                p["inner"]))
        probe, build = st.upstreams
        # build side: broadcast + distributed, higher drain priority (0)
        eb = Edge(self._vname(build), name, dst_ordinal=1,
                  routing=Routing.BROADCAST, distributed=True, priority=0)
        self._connect_up(build, eb)
        ep = Edge(self._vname(probe), name, dst_ordinal=0,
                  routing=Routing.ISOLATED, priority=1)
        self._connect_up(probe, ep)
        self.vertex_of[st] = name


def group_aggregate(op: AggregateOperation) -> Callable[[], Processor]:
    """Supplier for a batch keyed aggregation vertex (use with
    ``custom_transform(partitioned=True, distributed=True)``)."""
    return lambda: GroupAggregateProcessor(op)
