"""Core DAG: vertices, edges, routing policies.

Mirrors Jet's Core API (`com.hazelcast.jet.core.DAG`): a vertex names a
processor supplier and a local parallelism; an edge carries a routing policy
(isolated / round-robin / partitioned / broadcast), a locality (local vs
distributed) and a bounded queue size.  The planner in ``pipeline.py``
lowers the fluent Pipeline API onto this representation; the engine in
``engine.py`` instantiates it as tasklets.
"""

from __future__ import annotations

import graphlib
from typing import Callable, Dict, List, Optional

import numpy as np

DEFAULT_QUEUE_SIZE = 1024
#: Number of key partitions in the cluster; Hazelcast's default is 271.
PARTITION_COUNT = 271

#: CPython's hash modulus for int (sys.hash_info.modulus, 2**61 - 1)
_PYHASH_MODULUS = (1 << 61) - 1


def partition_for_key(key, partition_count: int = PARTITION_COUNT) -> int:
    """Key -> partition id.  Stable across the cluster (and across tiers:
    the device tier uses the same function vectorized)."""
    return hash(key) % partition_count


def partitions_for_keys(keys, partition_count: int = PARTITION_COUNT):
    """Vectorized :func:`partition_for_key` over an int64 key column.

    Bit-identical to ``hash(int(k)) % partition_count`` for every int64
    key (CPython int hash is the value mod 2**61-1, sign-preserving, with
    -1 mapped to -2; Python ``%`` then yields the non-negative residue).
    """
    k = np.asarray(keys, dtype=np.int64)
    h = k % _PYHASH_MODULUS
    neg = k < 0
    if neg.any():
        # hash(-n) == -hash(n); int64 min would overflow on negation, but
        # its hash is the constant -(2**63 % modulus) == -4
        imin = k == np.iinfo(np.int64).min
        safe = np.nonzero(neg & ~imin)[0]
        h[safe] = -((-k[safe]) % _PYHASH_MODULUS)
        h[imin] = -4
        h[h == -1] = -2
    return h % partition_count


class Routing:
    ISOLATED = "isolated"        # 1:1 between parallel instances
    ROUND_ROBIN = "round_robin"  # load-balance across consumers
    PARTITIONED = "partitioned"  # by key partition (two-stage aggregation)
    BROADCAST = "broadcast"      # every consumer gets every item


class Vertex:
    def __init__(self, name: str, supplier: Callable[[], "Processor"],
                 local_parallelism: int = -1):
        self.name = name
        self.supplier = supplier
        #: -1 = use the node's cooperative thread count (whole-DAG-per-core)
        self.local_parallelism = local_parallelism

    def __repr__(self):  # pragma: no cover
        return f"Vertex({self.name!r}, lp={self.local_parallelism})"


class Edge:
    def __init__(self, src: str, dst: str, *, src_ordinal: int = 0,
                 dst_ordinal: int = 0, routing: str = Routing.ROUND_ROBIN,
                 distributed: bool = False,
                 key_fn: Optional[Callable] = None,
                 queue_size: int = DEFAULT_QUEUE_SIZE,
                 priority: int = 0):
        self.src = src
        self.dst = dst
        self.src_ordinal = src_ordinal
        self.dst_ordinal = dst_ordinal
        self.routing = routing
        #: distributed edges cross node boundaries through exchange tasklets
        self.distributed = distributed
        #: key extractor for PARTITIONED routing (defaults to Event.key)
        self.key_fn = key_fn
        self.queue_size = queue_size
        #: lower value = consumed first (Jet uses priorities for hash-join
        #: build sides: the batch side drains fully before the probe side)
        self.priority = priority

    def partitioned(self, key_fn: Optional[Callable] = None) -> "Edge":
        self.routing = Routing.PARTITIONED
        self.key_fn = key_fn
        return self

    def all_to_one(self) -> "Edge":
        """Route everything to a single processor instance (global stage)."""
        self.routing = Routing.PARTITIONED
        self.key_fn = lambda ev: 0
        return self

    def broadcast(self) -> "Edge":
        self.routing = Routing.BROADCAST
        return self

    def isolated(self) -> "Edge":
        self.routing = Routing.ISOLATED
        return self

    def set_distributed(self, flag: bool = True) -> "Edge":
        self.distributed = flag
        return self

    def __repr__(self):  # pragma: no cover
        loc = "dist" if self.distributed else "local"
        return (f"Edge({self.src}:{self.src_ordinal} -> "
                f"{self.dst}:{self.dst_ordinal}, {self.routing}, {loc})")


class DAG:
    def __init__(self):
        self.vertices: Dict[str, Vertex] = {}
        self.edges: List[Edge] = []

    def vertex(self, name: str, supplier, local_parallelism: int = -1) -> Vertex:
        if name in self.vertices:
            raise ValueError(f"duplicate vertex {name!r}")
        v = Vertex(name, supplier, local_parallelism)
        self.vertices[name] = v
        return v

    def edge(self, edge: Edge) -> Edge:
        if edge.src not in self.vertices or edge.dst not in self.vertices:
            raise ValueError(f"edge references unknown vertex: {edge}")
        for e in self.edges:
            if (e.src, e.src_ordinal) == (edge.src, edge.src_ordinal):
                raise ValueError(
                    f"source ordinal {edge.src}:{edge.src_ordinal} already used")
            if (e.dst, e.dst_ordinal) == (edge.dst, edge.dst_ordinal):
                raise ValueError(
                    f"dest ordinal {edge.dst}:{edge.dst_ordinal} already used")
        self.edges.append(edge)
        return edge

    # -- structure queries ---------------------------------------------------
    def in_edges(self, name: str) -> List[Edge]:
        return sorted((e for e in self.edges if e.dst == name),
                      key=lambda e: (e.priority, e.dst_ordinal))

    def out_edges(self, name: str) -> List[Edge]:
        return sorted((e for e in self.edges if e.src == name),
                      key=lambda e: e.src_ordinal)

    def sources(self) -> List[str]:
        return [n for n in self.vertices if not self.in_edges(n)]

    def sinks(self) -> List[str]:
        return [n for n in self.vertices if not self.out_edges(n)]

    def topological_order(self) -> List[str]:
        """Vertex names in topological order; raises on cycles."""
        ts = graphlib.TopologicalSorter(
            {n: [e.src for e in self.in_edges(n)] for n in self.vertices})
        return list(ts.static_order())

    def validate(self) -> None:
        self.topological_order()  # raises CycleError on a cycle
        if not self.vertices:
            raise ValueError("empty DAG")
