"""The Jet cluster engine: execution planning, cooperative scheduling,
snapshot coordination, failure recovery and elasticity.

Execution planning follows the paper exactly (§3.1, Fig. 3): every vertex is
instantiated ``local_parallelism`` times on **every** node, with the default
parallelism equal to the node's cooperative thread count so that *each worker
runs the complete DAG*.  Edges become SPSC queues locally and
:class:`~repro.core.backpressure.NetworkLink`s across nodes.  Keyed edges
route by ``hash(key) % PARTITION_COUNT``; the partition table that assigns
those partitions to nodes is the *same* table the IMap state backend uses —
Jet's "partitioning of IMDG aligns with partitioning of the execution
engine" invariant.

How the planned execution actually runs is delegated to a pluggable
:class:`~repro.core.backend.ExecutionBackend` (see that module for the
contract).  The default ``backend="inproc"`` drives the whole cluster
cooperatively from :meth:`JetCluster.step` on the calling thread — the
paper's model with every simulated core multiplexed onto one real one.
``backend="mp"`` runs each (node, cooperative-thread) pair as a real OS
process with shared-memory EventBlock rings between them
(:mod:`repro.runtime.worker_proc`), so the cooperative model maps onto as
many cores as the machine offers.
"""

from __future__ import annotations

import itertools
import time as _time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..state import DurableSnapshotStore, IMapService, SnapshotStore
from .backend import ExecutionBackend, InProcessBackend, make_backend
from .backpressure import NetworkLink
from .clock import Clock, VirtualClock, WallClock
from .dag import DAG, Edge, PARTITION_COUNT, Routing, Vertex
from .events import MAX_TIME
from .processor import ProcessorContext
from .tasklet import (CooperativeWorker, EdgeCollector, InQueue,
                      GUARANTEE_EXACTLY_ONCE, GUARANTEE_NONE,
                      ProcessorTasklet, SnapshotContext)

JOB_RUNNING = "running"
JOB_COMPLETED = "completed"
JOB_FAILED = "failed"
JOB_RESTARTING = "restarting"


class JobFailedError(RuntimeError):
    """A job reached the terminal FAILED status (restart budget exhausted,
    or a detected failure with no snapshot guarantee to restore from)."""

    def __init__(self, job):
        self.job = job
        self.failures = list(job.failures)
        last = self.failures[-1] if self.failures else None
        super().__init__(
            f"job {job.id} FAILED after {job.auto_restarts} automatic "
            f"restart(s); last failure: {last!r}")


class RestartPolicy:
    """Bounded self-healing for *detected* failures (paper §4.4 recovery,
    made automatic): each detected worker death/hang/error triggers
    teardown -> restore-from-committed-snapshot -> restart, delayed by
    exponential backoff, at most ``max_restarts`` times before the job
    transitions to the terminal FAILED status.  Cooperative restarts
    (``kill_node`` / ``add_node``) do not consume this budget — the
    operator asked for those."""

    def __init__(self, max_restarts: int = 5, backoff_base_s: float = 0.05,
                 backoff_max_s: float = 2.0,
                 fingerprint_threshold: int = 2):
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        #: a failure fingerprint (vertex, exception type, restored
        #: snapshot id) recurring this many times marks the crash
        #: deterministic and escalates (snapshot-chain fallback /
        #: poison-record quarantine) instead of replaying it identically
        self.fingerprint_threshold = max(1, fingerprint_threshold)

    def delay_for(self, attempt: int) -> float:
        """Backoff before restart ``attempt`` (1-based): base * 2^(n-1),
        capped."""
        return min(self.backoff_base_s * (2 ** max(attempt - 1, 0)),
                   self.backoff_max_s)

# progressive idle backoff (paper §3.2: spin -> yield -> park).  An idle
# scheduler first busy-spins (lowest wake-up latency), then yields its
# timeslice, then parks in escalating naps so an idle job stops burning
# the core.  The park ceiling bounds the extra latency a waking event can
# observe, keeping the tail budget in check.
IDLE_SPIN_ITERS = 64
IDLE_YIELD_ITERS = 192
IDLE_PARK_MIN_S = 0.00005
IDLE_PARK_MAX_S = 0.0002


class JobConfig:
    def __init__(self, name: str = "job",
                 processing_guarantee: str = GUARANTEE_NONE,
                 snapshot_interval_s: float = 1.0,
                 restart_policy: Optional[RestartPolicy] = None,
                 barrier_timeout_s: float = 5.0):
        self.name = name
        self.processing_guarantee = processing_guarantee
        self.snapshot_interval_s = snapshot_interval_s
        self.restart_policy = restart_policy or RestartPolicy()
        #: a snapshot whose barrier acks have not all arrived within this
        #: deadline is ABORTED (entries discarded, last committed snapshot
        #: stays authoritative) instead of stalling the job forever; only
        #: meaningful on substrates whose acks can actually be lost (mp)
        self.barrier_timeout_s = barrier_timeout_s


class DeadLetterQueue:
    """Coordinator-side dead-letter sink with exactly-once accounting.

    A record lands here at most once (identity-deduplicated per vertex)
    when the escalation ladder proves it poison: the same vertex raised
    the same exception from the same restored snapshot
    ``RestartPolicy.fingerprint_threshold`` times, and a pinpoint replay
    stamped the exact in-flight record onto the failure.  After
    quarantine, every execution attempt filters the record out before
    the processor sees it (``ProcessorTasklet._drop_quarantined``), so
    the surviving stream keeps its zero-dup/zero-loss guarantee while
    the poison record is accounted for exactly once — here."""

    def __init__(self):
        #: chronological quarantine records
        #: ({vertex, identity, record, reason})
        self.records: List[Dict[str, Any]] = []
        self._by_vertex: Dict[str, set] = {}

    def quarantine(self, vertex: str, identity, record: str,
                   reason: str = "") -> bool:
        """Add one record; False when it was already quarantined."""
        ids = self._by_vertex.setdefault(vertex, set())
        if identity in ids:
            return False
        ids.add(identity)
        self.records.append({"vertex": vertex, "identity": identity,
                             "record": record, "reason": reason})
        return True

    def identities_for(self, vertex: str):
        return self._by_vertex.get(vertex)

    def __len__(self):
        return len(self.records)

    def summary(self) -> List[Dict[str, str]]:
        return [{"vertex": r["vertex"], "record": r["record"],
                 "reason": r["reason"]} for r in self.records]


class _Instance:
    """One deployed processor instance (vertex x node x local index)."""

    __slots__ = ("vertex", "node", "local_index", "global_index", "tasklet")

    def __init__(self, vertex: str, node: int, local_index: int,
                 global_index: int):
        self.vertex = vertex
        self.node = node
        self.local_index = local_index
        self.global_index = global_index
        self.tasklet: Optional[ProcessorTasklet] = None


class ExecutionContext:
    """One execution attempt of a job on a concrete topology."""

    def __init__(self, job: "Job", cluster: "JetCluster"):
        self.job = job
        self.cluster = cluster
        self.instances: Dict[str, List[_Instance]] = {}
        self.tasklets: List[ProcessorTasklet] = []
        self.links: List[NetworkLink] = []
        self.ssctx: Optional[SnapshotContext] = None
        #: backend-private per-execution state (worker plans, ring registry,
        #: control pipes, ... — opaque to the engine core)
        self.backend_data: Dict[str, Any] = {}
        self._build()

    # ------------------------------------------------------------------ build --
    def _build(self) -> None:
        cluster, job = self.cluster, self.job
        dag = job.dag
        dag.validate()
        nodes = sorted(cluster.node_ids)
        n_nodes = len(nodes)
        table = cluster.imap_service.table

        self.ssctx = cluster.backend.create_snapshot_context(job)

        # 1. instantiate vertices
        lp_of: Dict[str, int] = {}
        for name, v in dag.vertices.items():
            lp = v.local_parallelism if v.local_parallelism > 0 \
                else cluster.cooperative_threads
            lp_of[name] = lp
            insts = []
            for ni, node in enumerate(nodes):
                for li in range(lp):
                    insts.append(_Instance(name, node, li, ni * lp + li))
            self.instances[name] = insts

        # 2. create queues per edge: consumer-side InQueues and
        #    producer-side collectors
        in_queues: Dict[Tuple[str, int, int], List[InQueue]] = {}
        collectors: Dict[Tuple[str, int, int], List[EdgeCollector]] = {}
        for key in itertools.chain.from_iterable(
                ((v, inst.node, inst.local_index) for inst in insts)
                for v, insts in self.instances.items()):
            in_queues[key] = []
            collectors[key] = []

        for edge in dag.edges:
            self._wire_edge(edge, lp_of, nodes, table, in_queues, collectors)

        # 3. build tasklets and assign to workers
        snapshot_interval_ok = job.config.processing_guarantee != GUARANTEE_NONE
        for name, insts in self.instances.items():
            vertex = dag.vertices[name]
            lp = lp_of[name]
            in_edges = dag.in_edges(name)
            for inst in insts:
                processor = vertex.supplier()
                owned = tuple(
                    p for p in range(table.partition_count)
                    if table.owner(p) == inst.node and p % lp == inst.local_index)
                ctx = ProcessorContext(
                    vertex_name=name, global_index=inst.global_index,
                    local_index=inst.local_index,
                    total_parallelism=lp * n_nodes, node_id=inst.node,
                    node_count=n_nodes, partition_ids=owned,
                    partition_count=table.partition_count,
                    clock=cluster.clock)
                key = (name, inst.node, inst.local_index)
                spf = getattr(processor, "snapshot_partition", None)
                tasklet = ProcessorTasklet(
                    name=f"{name}#{inst.global_index}", processor=processor,
                    in_queues=in_queues[key], collectors=collectors[key],
                    ssctx=self.ssctx, vertex_name=name,
                    global_index=inst.global_index,
                    snapshot_pid_fn=spf,
                    is_source=not in_edges,
                    # dead-letter filtering + pinpoint replay for vertices
                    # the escalation ladder flagged (see DeadLetterQueue)
                    poison_ids=job.dead_letters.identities_for(name),
                    pinpoint=name in job.suspect_vertices)
                processor.init(tasklet.outbox, ctx)
                inst.tasklet = tasklet
                self.tasklets.append(tasklet)
                cluster.backend.assign_tasklet(self, inst, tasklet)
        self.ssctx.tasklets = self.tasklets
        self.ssctx.on_complete = self.job._on_snapshot_complete

        # columnar emission is only a win when blocks survive past the
        # source: a fused chain without a vectorized form, or immediate
        # consumers none of whom accept blocks, would explode every block
        # straight back to events — paying vectorized generation PLUS the
        # per-row scalar materialization.  Downgrade auto-mode sources on
        # such topologies to the scalar path (an EXPLICIT block_size is
        # honored as given).
        for name, insts in self.instances.items():
            if dag.in_edges(name) or not dag.out_edges(name):
                continue
            dst_accepts = any(
                getattr(self.instances[e.dst][0].tasklet.processor,
                        "accepts_blocks", False)
                for e in dag.out_edges(name))
            for inst in insts:
                p = inst.tasklet.processor
                inner = getattr(p, "inner", p)
                if getattr(inner, "block_size", 0) is not None:
                    continue        # scalar-forced, explicit, or no knob
                chain_explodes = (hasattr(p, "_chain_blk")
                                  and p._chain_blk is None)
                if chain_explodes or not dst_accepts:
                    inner.block_size = 0

    def _wire_edge(self, edge: Edge, lp_of: Dict[str, int],
                   nodes: List[int], table,
                   in_queues, collectors) -> None:
        lp_src, lp_dst = lp_of[edge.src], lp_of[edge.dst]
        consumers: List[Tuple[int, int]] = []   # (node, local_index)
        if edge.routing == Routing.ISOLATED and not edge.distributed:
            if lp_src != lp_dst:
                raise ValueError(
                    f"isolated edge {edge} needs equal parallelism")
        # producer instance -> its queue targets
        for src_inst in self.instances[edge.src]:
            queues = []
            dests: List[Tuple[int, int]] = []
            if edge.routing == Routing.ISOLATED and not edge.distributed:
                dests = [(src_inst.node, src_inst.local_index)]
            elif edge.distributed:
                dests = [(n, li) for n in nodes for li in range(lp_dst)]
            else:
                dests = [(src_inst.node, li) for li in range(lp_dst)]
            threads = self.cluster.cooperative_threads
            src_loc = (src_inst.node, src_inst.local_index % threads)
            for (n, li) in dests:
                q = self.cluster.backend.make_transport(
                    self, edge, src_loc, (n, li % threads))
                queues.append(q)
                in_queues[(edge.dst, n, li)].append(
                    InQueue(q, edge.dst_ordinal, priority=edge.priority))
            p2q = None
            if edge.routing == Routing.PARTITIONED:
                p2q = [0] * PARTITION_COUNT
                for pid in range(PARTITION_COUNT):
                    if edge.distributed:
                        owner = table.owner(pid % table.partition_count)
                        dest = (owner, pid % lp_dst)
                    else:
                        dest = (src_inst.node, pid % lp_dst)
                    p2q[pid] = dests.index(dest)
            collectors[(edge.src, src_inst.node, src_inst.local_index)].append(
                EdgeCollector(queues, edge.routing, edge.key_fn, p2q))

    # -------------------------------------------------------------- restore --
    def restore_from_snapshot(self, snapshot_id: int) -> int:
        """Load processor state from a committed snapshot. Returns the
        number of restored entries."""
        store = self.cluster.snapshot_store
        table = self.cluster.imap_service.table
        count = 0
        # group entries by (vertex, owning instance under the new topology)
        for name, insts in self.instances.items():
            lp = max(1, len(insts) // max(1, len(self.cluster.node_ids)))
            by_instance: Dict[Tuple[int, int], List[Tuple[Any, Any]]] = {}
            for pid in range(table.partition_count):
                entries = store.entries_for_partition(self.job.id, snapshot_id,
                                                      pid)
                for vertex, key, value in entries:
                    if vertex != name:
                        continue
                    dest = (table.owner(pid), pid % lp)
                    by_instance.setdefault(dest, []).append((key, value))
                    count += 1
            for inst in insts:
                items = by_instance.get((inst.node, inst.local_index))
                if items:
                    inst.tasklet.processor.restore_from_snapshot(items)
            for inst in insts:
                inst.tasklet.processor.finish_snapshot_restore()
                inst.tasklet.last_snapshot_id = snapshot_id
        self.ssctx.requested_id = snapshot_id
        self.ssctx.completed_id = snapshot_id
        return count

    @property
    def all_done(self) -> bool:
        return self.cluster.backend.execution_done(self)

    def stats(self) -> Dict[str, Any]:
        return {
            "tasklets": len(self.tasklets),
            "links": len(self.links),
            "items_in": sum(t.items_in for t in self.tasklets),
            "items_out": sum(t.items_out for t in self.tasklets),
            "calls": sum(t.calls for t in self.tasklets),
            "idle_calls": sum(t.idle_calls for t in self.tasklets),
        }


class Job:
    _ids = itertools.count()

    def __init__(self, cluster: "JetCluster", dag: DAG, config: JobConfig,
                 job_id: Optional[str] = None):
        self.cluster = cluster
        self.dag = dag
        self.config = config
        # an explicit id is the cold-start adoption path
        # (JetCluster.recover_job): the job must keep the identity under
        # which its durable snapshot chain was written
        self.id = job_id or f"{config.name}-{next(Job._ids)}"
        self.status = JOB_RUNNING
        self.execution: Optional[ExecutionContext] = None
        self._next_snapshot_id = 1
        self._last_snapshot_at = cluster.clock.now()
        self.snapshots_taken = 0
        self.restarts = 0
        #: automatic restarts consumed by DETECTED failures (bounded by
        #: ``config.restart_policy``; cooperative restarts not included)
        self.auto_restarts = 0
        #: detected-failure history (WorkerFailure records)
        self.failures: List[Any] = []
        #: cluster-clock instant the pending self-heal restart is due
        self._restart_due_at: Optional[float] = None
        #: aborted-snapshot tally of already-discarded executions
        self._aborted_before = 0
        # -- crash-loop escalation state (see _note_failures) ------------
        #: quarantined poison records, exactly-once accounting
        self.dead_letters = DeadLetterQueue()
        #: vertices with an attributed failure whose poison record is not
        #: yet known; rebuilt executions run them in pinpoint mode
        self.suspect_vertices: set = set()
        #: failure fingerprint -> recurrence count
        self._fp_counts: Dict[Any, int] = {}
        #: chain entries to skip ahead of verification (bumped on
        #: fingerprint recurrence: the newest snapshots replay a
        #: deterministic crash); reset when a fresh snapshot commits
        self._fallback_depth = 0
        #: snapshot id the current execution was restored from (None for
        #: a fresh build) — the epoch component of failure fingerprints
        self._restored_sid: Optional[int] = None
        #: chronological restore/escalation record, the recovery
        #: diagnostic surfaced in job stats and bench_chaos reports
        self.recovery_log: List[Dict[str, Any]] = []

    # -- snapshot coordination ----------------------------------------------------
    def tick(self, now: float) -> None:
        if (self.status != JOB_RUNNING
                or self.config.processing_guarantee == GUARANTEE_NONE):
            return
        ssctx = self.execution.ssctx
        if ssctx.check_timeout():
            # in-flight snapshot aborted (overdue barrier acks): give the
            # next attempt a full interval rather than retrying instantly
            self._last_snapshot_at = now
            return
        if (now - self._last_snapshot_at >= self.config.snapshot_interval_s
                and ssctx.completed_id == ssctx.requested_id):
            ssctx.begin(self._next_snapshot_id)
            self._next_snapshot_id += 1
            self._last_snapshot_at = now

    @property
    def snapshots_aborted(self) -> int:
        """Snapshots abandoned without commit across all execution
        attempts of this job (ack timeouts, worker death mid-barrier)."""
        aborted = self._aborted_before
        if self.execution is not None and self.execution.ssctx is not None:
            aborted += self.execution.ssctx.aborted_count
        return aborted

    # -- detected failures / self-healing -----------------------------------------
    def on_detected_failure(self, failures) -> None:
        """Route detected (uncooperative) failures into the restart
        policy: tear the half-dead execution down, then either schedule a
        backoff restart from the last committed snapshot or transition to
        the terminal FAILED status."""
        if self.status in (JOB_COMPLETED, JOB_FAILED):
            return
        self.failures.extend(failures)
        self._note_failures(failures)
        if self.execution is not None:
            # stop the attempt NOW: surviving workers must not keep
            # producing into a topology that is about to be discarded
            self.cluster.backend.stop_execution(self.execution)
            if self.execution.ssctx is not None:
                # retire the storage of any snapshot caught mid-barrier:
                # it can never commit and would otherwise leak its IMap
                self.execution.ssctx.retire_aborted()
        policy = self.config.restart_policy
        if self.config.processing_guarantee == GUARANTEE_NONE:
            # nothing committed to restore from — a restart would replay
            # the stream into sinks that already saw it
            self.status = JOB_FAILED
            return
        if self.auto_restarts >= policy.max_restarts:
            self.status = JOB_FAILED
            return
        self.auto_restarts += 1
        self.status = JOB_RESTARTING
        self._restart_due_at = (self.cluster.clock.now()
                                + policy.delay_for(self.auto_restarts))

    def _note_failures(self, failures) -> None:
        """Failure fingerprinting + crash-loop escalation ladder.

        Rung 1 — any attributed failure marks its vertex *suspect*: the
        next execution runs it in pinpoint mode (one record per
        ``process`` call), so a deterministic raise identifies the exact
        in-flight record.  Rung 2 — a fingerprint (vertex, exception
        type, restored snapshot id) recurring ``fingerprint_threshold``
        times is a deterministic crash: fall back one entry down the
        snapshot chain, and when the recurrence carries a pinpointed
        poison record, quarantine it to the dead-letter queue so the
        next attempt drops it instead of dying on it."""
        from ..runtime.supervisor import failure_fingerprint
        policy = self.config.restart_policy
        for f in failures:
            vertex = getattr(f, "vertex", None)
            if vertex:
                self.suspect_vertices.add(vertex)
            fp = failure_fingerprint(f, self._restored_sid)
            count = self._fp_counts[fp] = self._fp_counts.get(fp, 0) + 1
            if count < policy.fingerprint_threshold:
                continue
            self._fp_counts[fp] = 0
            chain = self.cluster.snapshot_store.recovery_chain(self.id)
            if len(chain) > 1:
                self._fallback_depth = min(self._fallback_depth + 1,
                                           len(chain) - 1)
            quarantined = None
            poison = getattr(f, "poison", None)
            if poison is not None and poison.get("exact"):
                if self.dead_letters.quarantine(
                        poison["vertex"], poison["identity"],
                        poison["record"],
                        reason=(f"fingerprint {fp!r} recurred "
                                f"{count}x")):
                    quarantined = poison["record"]
                # the culprit is known; no need to keep replaying the
                # vertex one record at a time
                self.suspect_vertices.discard(poison["vertex"])
            self.recovery_log.append({
                "event": "escalation", "fingerprint": repr(fp),
                "recurrences": count,
                "fallback_depth": self._fallback_depth,
                "quarantined": quarantined})

    def _select_restore_snapshot(self):
        """Walk the store's recovery chain (newest first) to the newest
        usable snapshot: entries within the current escalation fallback
        depth are skipped outright, then each candidate must pass the
        store's integrity verification and load.  Returns
        ``(snapshot_id | None, skipped)`` where ``skipped`` records every
        rejected id with its reason."""
        store = self.cluster.snapshot_store
        skipped: List[Dict[str, Any]] = []
        for depth, sid in enumerate(store.recovery_chain(self.id)):
            if depth < self._fallback_depth:
                skipped.append({"snapshot_id": sid,
                                "reason": "escalation fallback "
                                          "(deterministic crash replayed "
                                          "from this epoch)"})
                continue
            ok, reason = store.verify(self.id, sid)
            if not ok:
                skipped.append({"snapshot_id": sid,
                                "reason": f"verification failed: {reason}"})
                continue
            ok, reason = store.prepare_restore(self.id, sid)
            if not ok:
                skipped.append({"snapshot_id": sid,
                                "reason": f"restore load failed: {reason}"})
                continue
            return sid, skipped
        return None, skipped

    def recovery_diagnostics(self) -> Dict[str, Any]:
        """Everything the recovery path decided, for job stats, the
        chaos bench report and the CI artifact: restores with their
        skipped snapshot ids + reasons, escalations with fingerprints
        and fallback depths, and the dead-letter accounting."""
        return {
            "auto_restarts": self.auto_restarts,
            "snapshots_aborted": self.snapshots_aborted,
            "fallback_depth": self._fallback_depth,
            "suspect_vertices": sorted(self.suspect_vertices),
            "recovery_log": list(self.recovery_log),
            "dead_letters": self.dead_letters.summary(),
            "failures": [repr(f) for f in self.failures],
        }

    def maybe_heal(self, now: float) -> None:
        """Run the pending self-heal restart once its backoff elapsed."""
        if (self.status == JOB_RESTARTING
                and self._restart_due_at is not None
                and now >= self._restart_due_at):
            self._restart_due_at = None
            self.restart()

    def _on_snapshot_complete(self, snapshot_id: int) -> None:
        store = self.cluster.snapshot_store
        # job-level replay meta rides the durable manifest so a cold
        # start (recover_job) can adopt the job's config from disk alone
        store.set_meta(self.id, snapshot_id, "job", {
            "name": self.config.name,
            "guarantee": self.config.processing_guarantee,
            "snapshot_interval_s": self.config.snapshot_interval_s,
        })
        store.commit(self.id, snapshot_id)
        self.snapshots_taken += 1
        # a freshly committed snapshot is a trusted chain head again: it
        # includes the progress made after any escalated fallback
        self._fallback_depth = 0
        # phase-2 release for transactional sinks (paper §4.5), delivered
        # wherever the processors actually live (this thread or a worker
        # process)
        self.cluster.backend.notify_snapshot_committed(self.execution,
                                                       snapshot_id)

    # -- lifecycle -------------------------------------------------------------------
    def start(self) -> None:
        self.execution = ExecutionContext(self, self.cluster)
        self.cluster.backend.start_execution(self.execution)

    def restart(self) -> None:
        """Rebuild the execution on the current topology and restore the
        newest *usable* snapshot (paper §4.4 recovery protocol, hardened:
        the chain is walked with verification + escalation fallback, see
        :meth:`_select_restore_snapshot`)."""
        self.restarts += 1
        self.status = JOB_RESTARTING
        # drop the old execution (its tasklets/queues/processes die with it)
        old = self.execution
        if old is not None:
            self.cluster.backend.stop_execution(old)
            if old.ssctx is not None:
                self._aborted_before += old.ssctx.aborted_count
                old.ssctx.retire_aborted()
        self.execution = ExecutionContext(self, self.cluster)
        sid, skipped = self._select_restore_snapshot()
        restored_entries = 0
        if sid is not None:
            restored_entries = self.execution.restore_from_snapshot(sid)
        self._restored_sid = sid
        if skipped or sid is not None:
            self.recovery_log.append({
                "event": "restore", "restart": self.restarts,
                "restored_snapshot": sid, "entries": restored_entries,
                "skipped": skipped,
                "fallback_depth": self._fallback_depth})
        self._last_snapshot_at = self.cluster.clock.now()
        # start AFTER the restore: a forking backend must hand workers the
        # restored state
        self.cluster.backend.start_execution(self.execution)
        self.status = JOB_RUNNING


class JetNode:
    def __init__(self, node_id: int, cooperative_threads: int):
        self.node_id = node_id
        self.workers = [CooperativeWorker(f"n{node_id}-w{i}")
                        for i in range(cooperative_threads)]


class JetCluster:
    """A Jet cluster; execution substrate selected by ``backend``
    (``"inproc"`` — cooperative simulation on this thread, ``"mp"`` — one
    OS process per (node, cooperative thread), or a custom
    :class:`~repro.core.backend.ExecutionBackend` instance)."""

    def __init__(self, n_nodes: int = 1, cooperative_threads: int = 2,
                 clock: Optional[Clock] = None,
                 partition_count: int = PARTITION_COUNT,
                 backup_count: int = 1,
                 link_latency_s: float = 0.0005,
                 idle_backoff: bool = True,
                 backend="inproc",
                 snapshot_dir=None,
                 snapshot_retain: int = 3):
        self.clock = clock or WallClock()
        self.backend: ExecutionBackend = make_backend(backend)
        if not self.backend.clock_supported(self.clock):
            raise ValueError(
                f"backend {self.backend.name!r} does not support "
                f"{type(self.clock).__name__} (worker processes cannot "
                "observe a driver-stepped virtual clock)")
        self.cooperative_threads = cooperative_threads
        self.link_latency_s = link_latency_s
        #: progressive spin->yield->park when a wall-clock driver is idle
        self.idle_backoff = idle_backoff
        self._idle_streak = 0
        self.node_ids = list(range(n_nodes))
        self.nodes: Dict[int, JetNode] = {
            i: JetNode(i, cooperative_threads) for i in self.node_ids}
        self.imap_service = IMapService(self.node_ids,
                                        partition_count=partition_count,
                                        backup_count=backup_count)
        # ``snapshot_dir`` upgrades snapshot storage to the durable tier:
        # committed snapshots spill to disk as a verified retention chain
        # of the last ``snapshot_retain`` epochs (state/durable_store.py),
        # surviving coordinator death (see recover_job) and detecting
        # corrupt snapshots at restore time
        if snapshot_dir is not None:
            self.snapshot_store: SnapshotStore = DurableSnapshotStore(
                self.imap_service, snapshot_dir, retain=snapshot_retain)
        else:
            self.snapshot_store = SnapshotStore(self.imap_service)
        self.jobs: List[Job] = []
        self._next_node_id = n_nodes
        self.backend.bind(self)

    # -- job control ---------------------------------------------------------------
    def submit(self, dag: DAG, config: Optional[JobConfig] = None) -> Job:
        job = Job(self, dag, config or JobConfig())
        job.start()
        self.jobs.append(job)
        return job

    def recover_job(self, dag: DAG, job_id: Optional[str] = None,
                    config: Optional[JobConfig] = None) -> Job:
        """Cold-start adoption: rebuild a job from the durable snapshot
        chain alone — nothing from the coordinator that wrote it
        survives.  ``dag`` must be the job's pipeline rebuilt by the
        caller (processor code is not serialized, matching Jet's
        resubmit-the-job model); ``job_id`` may be omitted when the
        store holds exactly one job.  The job's processing guarantee and
        snapshot cadence are adopted from the newest readable manifest
        when ``config`` is not given, snapshot ids continue after the
        chain head, and the usual verified chain walk picks the restore
        point — so a corrupt head falls back exactly as it would in a
        live restart."""
        store = self.snapshot_store
        jobs = [j for j in store.discover_jobs() if store.recovery_chain(j)]
        if job_id is None:
            if len(jobs) != 1:
                raise ValueError(
                    f"recover_job needs an explicit job_id: store holds "
                    f"{jobs!r}")
            job_id = jobs[0]
        chain = store.recovery_chain(job_id)
        if not chain:
            raise ValueError(f"no durable snapshots for job {job_id!r}")
        if config is None:
            meta: Dict[str, Any] = {}
            for sid in chain:       # newest readable manifest wins
                manifest = getattr(store, "manifest", lambda *a: None)(
                    job_id, sid)
                if manifest and manifest.get("meta", {}).get("job"):
                    meta = manifest["meta"]["job"]
                    break
            config = JobConfig(
                name=meta.get("name", job_id),
                processing_guarantee=meta.get("guarantee",
                                              GUARANTEE_EXACTLY_ONCE),
                snapshot_interval_s=meta.get("snapshot_interval_s", 1.0))
        job = Job(self, dag, config, job_id=job_id)
        job._next_snapshot_id = chain[0] + 1
        job.execution = ExecutionContext(job, self)
        sid, skipped = job._select_restore_snapshot()
        restored_entries = 0
        if sid is not None:
            restored_entries = job.execution.restore_from_snapshot(sid)
        job._restored_sid = sid
        job.recovery_log.append({
            "event": "cold_start", "restored_snapshot": sid,
            "entries": restored_entries, "skipped": skipped,
            "chain": chain})
        self.backend.start_execution(job.execution)
        self.jobs.append(job)
        return job

    # -- driver ---------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration across the whole cluster."""
        progress = self.backend.step(self.jobs)
        for job in self.jobs:
            # detected (uncooperative) failures first: a job whose workers
            # died must not be ticked for snapshots or marked completed
            failures = self.backend.take_failures(job.execution)
            if failures:
                job.on_detected_failure(failures)
                progress = True
            job.maybe_heal(self.clock.now())
            job.tick(self.clock.now())
            if (job.status == JOB_RUNNING
                    and self.backend.execution_done(job.execution)):
                job.status = JOB_COMPLETED
                # release substrate resources (worker processes, shm rings)
                # the moment the data plane finished
                self.backend.stop_execution(job.execution)
        if progress:
            self._idle_streak = 0
        elif isinstance(self.clock, VirtualClock):
            self.clock.advance(self.clock.auto_step)
        elif self.idle_backoff:
            self._idle_streak = streak = self._idle_streak + 1
            if streak > IDLE_YIELD_ITERS:
                park = IDLE_PARK_MIN_S * (1 << min(streak - IDLE_YIELD_ITERS,
                                                   8))
                _time.sleep(min(park, IDLE_PARK_MAX_S))
            elif streak > IDLE_SPIN_ITERS:
                _time.sleep(0)      # yield the timeslice
        return progress

    def run_until_complete(self, job: Job, max_steps: int = 2_000_000) -> None:
        for _ in range(max_steps):
            if job.status == JOB_COMPLETED:
                return
            if job.status == JOB_FAILED:
                raise JobFailedError(job)
            self.step()
        raise TimeoutError(
            f"job {job.id} did not complete in {max_steps} steps "
            f"(stats: {job.execution.stats()})")

    def run_steps(self, n: int) -> None:
        for _ in range(n):
            self.step()

    def shutdown(self) -> None:
        """Tear down substrate resources of every execution (terminate
        worker processes, unlink shared memory).  Idempotent; a no-op for
        the in-process backend beyond unhooking tasklets."""
        for job in self.jobs:
            if job.execution is not None:
                self.backend.stop_execution(job.execution)
        self.backend.shutdown()

    # -- telemetry -------------------------------------------------------------
    def vertex_time_share(self) -> Dict[str, float]:
        """Fraction of sampled worker time spent in each vertex.

        Aggregates the cooperative workers' sampled per-tasklet timing
        (see :class:`CooperativeWorker`) across all nodes, summed per
        vertex (tasklet names are ``vertex#globalIndex``), normalized to
        shares.  This is where the next perf PR should look first.
        """
        time_in: Dict[str, float] = {}
        for node in self.nodes.values():
            for worker in node.workers:
                for name, secs in worker._time_in.items():
                    vertex = name.rsplit("#", 1)[0]
                    time_in[vertex] = time_in.get(vertex, 0.0) + secs
        total = sum(time_in.values())
        if total <= 0:
            return {}
        return {v: round(s / total, 4)
                for v, s in sorted(time_in.items(), key=lambda kv: -kv[1])}

    # -- membership -----------------------------------------------------------------
    def kill_node(self, node_id: int) -> None:
        """Fail a member: IMap promotes backups; running jobs restart from
        their latest committed snapshot on the surviving members."""
        if len(self.node_ids) == 1:
            raise ValueError("cannot kill the last node")
        self.node_ids.remove(node_id)
        del self.nodes[node_id]
        self.imap_service.kill_member(node_id)
        for job in self.jobs:
            if job.status in (JOB_RUNNING, JOB_RESTARTING):
                job.restart()

    def add_node(self) -> int:
        """Elastic scale-out: join a member, rebalance partitions, restart
        jobs so the new member takes its share of the work (§4.3)."""
        node_id = self._next_node_id
        self._next_node_id += 1
        self.node_ids.append(node_id)
        self.nodes[node_id] = JetNode(node_id, self.cooperative_threads)
        self.imap_service.add_member(node_id)
        for job in self.jobs:
            if job.status in (JOB_RUNNING, JOB_RESTARTING):
                job.restart()
        return node_id
