"""Stream items flowing along Jet DAG edges.

Four kinds of items travel through queues, mirroring Hazelcast Jet:

* data events  — ``(timestamp, key, value)`` triples, represented by
  :class:`Event` (``__slots__`` for footprint; the scalar datapath
  allocates one object per event, nothing else),
* event blocks — :class:`EventBlock`, a struct-of-arrays record batch of
  many events travelling as ONE queue item (the columnar hot path),
* watermarks   — :class:`Watermark`, monotone event-time progress markers,
* barriers     — :class:`Barrier`, Chandy-Lamport snapshot markers,
* end-of-data  — :class:`DoneItem`, closes a batch edge.

Jet's wire format is binary; here the "wire" is an in-process queue so the
items themselves are the format.

The EventBlock contract
=======================

An :class:`EventBlock` is a batch of events in **stream order** stored as
NumPy columns — ``ts: int64[n]``, ``key: int64[n]``, ``value:
float64[n]`` — plus optional extras:

* ``payload`` — a per-row list of arbitrary Python values.  When present
  it IS the event value; the ``value`` column is then a scalar projection
  (or zeros) kept for vectorized aggregation.
* ``payload_fn(block, i)`` — a lazy row materializer.  Blocks whose
  values are cheap to *re-derive* (e.g. NEXMark model objects, a pure
  function of the stored ``seq`` column) carry this instead of a payload
  list, so the object-per-event cost is only ever paid on the explode
  fallback path, never on the columnar fast path.
* ``cols`` — named auxiliary int/float columns (e.g. ``kind``, ``seq``)
  that vectorized stage functions may read.  Auxiliary columns stay
  row-aligned through every slice/take/compress.

Semantics relative to the scalar path:

* A block is **observably equivalent** to its exploded event sequence:
  any processor that does not declare ``accepts_blocks = True`` receives
  the exploded :class:`Event` run instead (the tasklet's explode shim),
  so black-box processors keep exact per-event semantics.
* Blocks **never contain control items**.  Watermarks, barriers and DONE
  travel between blocks: a source splits its output at every watermark
  emission point, and barriers are only ever injected at block
  boundaries (the tasklet flushes pending data before snapshotting), so
  "blocks split at barrier boundaries" holds by construction.
* Blocks are **immutable once enqueued**.  In-place column mutation is
  allowed only while the producer still owns the block (source fusion),
  exactly like the scalar in-place chain rule.  A broadcast edge hands
  the SAME block object to every consumer.
* On a partitioned edge a block is routed by hashing the key column once
  and counting-sorting rows by destination queue; each destination
  receives one sub-block with its rows in stream order — the same
  per-queue sequence the per-event protocol produces.  Sub-block
  delivery is all-or-nothing per block (retried under backpressure), so
  no queue can observe a post-block item before the block's own rows.
* Float-valued aggregations may associate differently over a block than
  over single events (per-group partial sums combine once per block);
  integer aggregates (counting, integer sums) are bit-identical.
"""

from __future__ import annotations

import pickle
import struct
from typing import Any, Callable, Dict, List, Optional

import numpy as np

MIN_TIME = -(2**62)
MAX_TIME = 2**62

# -- EventBlock wire format (shared-memory transport) ------------------------
#
#   [u32 n][u8 flags]
#   ts  : n * int64   (raw little-endian slab)
#   key : n * int64
#   value : n * float64            (flags bit 0)
#   aux cols (flags bit 1): [u8 ncols] then per column
#       [u8 namelen][name ascii][u8 dlen][dtype.str ascii][raw bytes]
#   extras (flags bit 2): [u32 plen][pickle((payload, payload_fn))]
#
# The three primary columns cross process boundaries as raw byte slabs —
# deserialization is one ``np.frombuffer(...).copy()`` per column, no
# per-row work.  Only ``payload``/``payload_fn`` (arbitrary Python) ride
# through pickle; a block whose ``payload_fn`` itself cannot pickle is
# materialized into a payload list instead, so the wire form is always
# observably equivalent to the original block.

_BLK_HAS_VALUE = 1
_BLK_HAS_COLS = 2
_BLK_HAS_EXTRAS = 4
_BLK_HDR = struct.Struct("<IB")
_U32 = struct.Struct("<I")


class Event:
    """A timestamped, keyed data record."""

    __slots__ = ("ts", "key", "value")

    def __init__(self, ts: int, key, value):
        self.ts = ts
        self.key = key
        self.value = value

    def with_value(self, value) -> "Event":
        return Event(self.ts, self.key, value)

    def with_key(self, key) -> "Event":
        return Event(self.ts, key, self.value)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"Event(ts={self.ts}, key={self.key!r}, value={self.value!r})"


class LateEvent(Event):
    """A data event that arrived behind the watermark by more than the
    window's allowed lateness.

    Window processors emit the original (ts, key, value) wrapped in this
    type onto their out-edges; a ``late_sink`` attached via the Pipeline
    API receives exactly these, while the regular downstream ignores them.
    Being an :class:`Event` subclass it routes like any data item
    (partitioned edges read ``.key``)."""

    __slots__ = ()

    def __repr__(self):  # pragma: no cover
        return (f"LateEvent(ts={self.ts}, key={self.key!r}, "
                f"value={self.value!r})")


class EventBlock:
    """A struct-of-arrays batch of events travelling as one queue item.

    See the module docstring for the full contract.  Rows are in stream
    order; columns are NumPy arrays of one shared length.
    """

    __slots__ = ("ts", "key", "value", "payload", "payload_fn", "cols")

    def __init__(self, ts, key, value=None, payload: Optional[List] = None,
                 payload_fn: Optional[Callable] = None,
                 cols: Optional[Dict[str, Any]] = None):
        self.ts = ts
        self.key = key
        self.value = value
        self.payload = payload
        self.payload_fn = payload_fn
        self.cols = cols

    def __len__(self) -> int:
        return len(self.ts)

    # -- row value materialization (explode fallback path) -------------------
    def values(self) -> List:
        """Per-row Python values; materializes (and caches) ``payload_fn``."""
        if self.payload is None:
            if self.payload_fn is not None:
                fn = self.payload_fn
                self.payload = [fn(self, i) for i in range(len(self.ts))]
            else:
                v = self.value
                if v is None:
                    self.payload = [None] * len(self.ts)
                else:
                    self.payload = [x.item() for x in v]
        return self.payload

    def value_at(self, i: int):
        if self.payload is not None:
            return self.payload[i]
        if self.payload_fn is not None:
            return self.payload_fn(self, i)
        return None if self.value is None else self.value[i].item()

    def to_events(self) -> List["Event"]:
        """Explode into the equivalent per-event run (stream order).

        Timestamps and keys come out as plain Python ints so downstream
        scalar processors see exactly what a scalar producer would emit.
        """
        vals = self.values()
        return [Event(t, k, v) for t, k, v in
                zip(self.ts.tolist(), self.key.tolist(), vals)]

    # -- row selection (all preserve stream order among kept rows) -----------
    def _rebuild(self, sel) -> "EventBlock":
        payload = self.payload
        if payload is not None:
            payload = [payload[i] for i in sel.tolist()]
        cols = self.cols
        if cols is not None:
            cols = {name: c[sel] for name, c in cols.items()}
        return EventBlock(self.ts[sel], self.key[sel],
                          None if self.value is None else self.value[sel],
                          payload, None if payload is not None
                          else self.payload_fn, cols)

    def slice(self, lo: int, hi: int) -> "EventBlock":
        """Contiguous row range [lo, hi) (columns are views, not copies)."""
        sl = np.s_[lo:hi]
        payload = self.payload
        if payload is not None:
            payload = payload[lo:hi]
        cols = self.cols
        if cols is not None:
            cols = {name: c[sl] for name, c in cols.items()}
        return EventBlock(self.ts[sl], self.key[sl],
                          None if self.value is None else self.value[sl],
                          payload, None if payload is not None
                          else self.payload_fn, cols)

    def take(self, idx) -> "EventBlock":
        """Rows at ``idx`` (an integer index array), in that order."""
        return self._rebuild(np.asarray(idx))

    def compress(self, mask) -> "EventBlock":
        """Rows where the boolean ``mask`` holds (vectorized filter)."""
        return self._rebuild(np.nonzero(mask)[0])

    # -- column replacement (vectorized map / rekey) --------------------------
    def with_value_col(self, value) -> "EventBlock":
        """New value column; drops payload/payload_fn (the old objects no
        longer describe the mapped values)."""
        return EventBlock(self.ts, self.key,
                          np.asarray(value, dtype=np.float64),
                          None, None, self.cols)

    def with_key_col(self, key) -> "EventBlock":
        return EventBlock(self.ts, np.asarray(key, dtype=np.int64),
                          self.value, self.payload, self.payload_fn,
                          self.cols)

    # -- wire form (cross-process shared-memory rings) ------------------------
    def to_wire(self) -> bytes:
        """Serialize to the shm wire format (module docstring): the three
        primary columns as raw int64/float64 slabs, aux columns as tagged
        slabs, payload/payload_fn through pickle (with a materialize
        fallback when the row function itself cannot cross the wire)."""
        n = len(self.ts)
        flags = 0
        parts: List[bytes] = [b""]      # placeholder for the header
        parts.append(np.ascontiguousarray(self.ts, dtype="<i8").tobytes())
        parts.append(np.ascontiguousarray(self.key, dtype="<i8").tobytes())
        if self.value is not None:
            flags |= _BLK_HAS_VALUE
            parts.append(
                np.ascontiguousarray(self.value, dtype="<f8").tobytes())
        if self.cols:
            flags |= _BLK_HAS_COLS
            cparts = [bytes([len(self.cols)])]
            for name, col in self.cols.items():
                arr = np.ascontiguousarray(col)
                nb = name.encode("ascii")
                db = arr.dtype.str.encode("ascii")
                cparts.append(bytes([len(nb)]) + nb + bytes([len(db)]) + db
                              + arr.tobytes())
            parts.append(b"".join(cparts))
        extras = None
        if self.payload is not None or self.payload_fn is not None:
            try:
                extras = pickle.dumps((self.payload, self.payload_fn),
                                      protocol=pickle.HIGHEST_PROTOCOL)
            except Exception:
                # unpicklable materializer: ship concrete row values
                extras = pickle.dumps((self.values(), None),
                                      protocol=pickle.HIGHEST_PROTOCOL)
        if extras is not None:
            flags |= _BLK_HAS_EXTRAS
            parts.append(_U32.pack(len(extras)))
            parts.append(extras)
        parts[0] = _BLK_HDR.pack(n, flags)
        return b"".join(parts)

    @classmethod
    def from_wire(cls, buf) -> "EventBlock":
        """Rebuild a block from :meth:`to_wire` bytes (or a memoryview over
        a shm ring segment).  Columns are copied out of the buffer — ring
        memory is recycled once the consumer advances."""
        buf = memoryview(buf)
        n, flags = _BLK_HDR.unpack_from(buf, 0)
        off = _BLK_HDR.size
        ts = np.frombuffer(buf, "<i8", n, off).copy()
        off += 8 * n
        key = np.frombuffer(buf, "<i8", n, off).copy()
        off += 8 * n
        value = None
        if flags & _BLK_HAS_VALUE:
            value = np.frombuffer(buf, "<f8", n, off).copy()
            off += 8 * n
        cols = None
        if flags & _BLK_HAS_COLS:
            ncols = buf[off]
            off += 1
            cols = {}
            for _ in range(ncols):
                nlen = buf[off]
                off += 1
                name = bytes(buf[off:off + nlen]).decode("ascii")
                off += nlen
                dlen = buf[off]
                off += 1
                dt = np.dtype(bytes(buf[off:off + dlen]).decode("ascii"))
                off += dlen
                cols[name] = np.frombuffer(buf, dt, n, off).copy()
                off += dt.itemsize * n
        payload = payload_fn = None
        if flags & _BLK_HAS_EXTRAS:
            (plen,) = _U32.unpack_from(buf, off)
            off += _U32.size
            payload, payload_fn = pickle.loads(buf[off:off + plen])
        return cls(ts, key, value, payload, payload_fn, cols)

    @classmethod
    def from_events(cls, events) -> "EventBlock":
        """Build a block from an Event run (tests / adapters; keys and
        timestamps must be int64-coercible)."""
        ts = np.fromiter((ev.ts for ev in events), np.int64, len(events))
        key = np.fromiter((ev.key for ev in events), np.int64, len(events))
        vals = [ev.value for ev in events]
        if all(isinstance(v, (int, float)) and not isinstance(v, bool)
               for v in vals):
            return cls(ts, key, np.asarray(vals, np.float64), payload=vals)
        return cls(ts, key, None, payload=vals)

    def __repr__(self):  # pragma: no cover - debug aid
        n = len(self.ts)
        lo = self.ts[0] if n else "-"
        hi = self.ts[-1] if n else "-"
        return f"EventBlock(n={n}, ts=[{lo}..{hi}])"


def block_form(scalar_fn, block_fn):
    """Attach a vectorized form to a scalar stage function.

    ``block_fn`` contracts by stage kind: filter -> bool mask over the
    block's rows; map -> new value column (float64-coercible ndarray);
    rekey -> new key column (int64-coercible ndarray).  The fusion planner
    lowers a stateless chain to column ops only when EVERY step declares a
    block form; otherwise blocks explode to events at the chain boundary.
    """
    scalar_fn.__block_form__ = block_fn
    return scalar_fn


class Watermark:
    """Asserts that no event with ``ts < self.ts`` will arrive on this edge."""

    __slots__ = ("ts",)

    def __init__(self, ts: int):
        self.ts = ts

    def __repr__(self):  # pragma: no cover
        return f"Watermark({self.ts})"


class Barrier:
    """Chandy-Lamport snapshot barrier.

    ``snapshot_id`` increases monotonically per job.  ``terminal`` marks a
    snapshot taken for graceful job suspension (export-and-stop).
    """

    __slots__ = ("snapshot_id", "terminal")

    def __init__(self, snapshot_id: int, terminal: bool = False):
        self.snapshot_id = snapshot_id
        self.terminal = terminal

    def __repr__(self):  # pragma: no cover
        return f"Barrier({self.snapshot_id}{', terminal' if self.terminal else ''})"


class DoneItem:
    """End-of-stream marker for batch stages. A singleton per edge traversal."""

    __slots__ = ()

    def __repr__(self):  # pragma: no cover
        return "DONE"


DONE = DoneItem()


def is_special(item) -> bool:
    """True for control items (watermark / barrier / done)."""
    return isinstance(item, (Watermark, Barrier, DoneItem))
