"""Stream items flowing along Jet DAG edges.

Three kinds of items travel through queues, mirroring Hazelcast Jet:

* data events  — ``(timestamp, key, value)`` triples, represented by
  :class:`Event` (``__slots__`` for footprint; the datapath allocates one
  object per event, nothing else),
* watermarks   — :class:`Watermark`, monotone event-time progress markers,
* barriers     — :class:`Barrier`, Chandy-Lamport snapshot markers,
* end-of-data  — :class:`DoneItem`, closes a batch edge.

Jet's wire format is binary; here the "wire" is an in-process queue so the
items themselves are the format.
"""

from __future__ import annotations

MIN_TIME = -(2**62)
MAX_TIME = 2**62


class Event:
    """A timestamped, keyed data record."""

    __slots__ = ("ts", "key", "value")

    def __init__(self, ts: int, key, value):
        self.ts = ts
        self.key = key
        self.value = value

    def with_value(self, value) -> "Event":
        return Event(self.ts, self.key, value)

    def with_key(self, key) -> "Event":
        return Event(self.ts, key, self.value)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"Event(ts={self.ts}, key={self.key!r}, value={self.value!r})"


class LateEvent(Event):
    """A data event that arrived behind the watermark by more than the
    window's allowed lateness.

    Window processors emit the original (ts, key, value) wrapped in this
    type onto their out-edges; a ``late_sink`` attached via the Pipeline
    API receives exactly these, while the regular downstream ignores them.
    Being an :class:`Event` subclass it routes like any data item
    (partitioned edges read ``.key``)."""

    __slots__ = ()

    def __repr__(self):  # pragma: no cover
        return (f"LateEvent(ts={self.ts}, key={self.key!r}, "
                f"value={self.value!r})")


class Watermark:
    """Asserts that no event with ``ts < self.ts`` will arrive on this edge."""

    __slots__ = ("ts",)

    def __init__(self, ts: int):
        self.ts = ts

    def __repr__(self):  # pragma: no cover
        return f"Watermark({self.ts})"


class Barrier:
    """Chandy-Lamport snapshot barrier.

    ``snapshot_id`` increases monotonically per job.  ``terminal`` marks a
    snapshot taken for graceful job suspension (export-and-stop).
    """

    __slots__ = ("snapshot_id", "terminal")

    def __init__(self, snapshot_id: int, terminal: bool = False):
        self.snapshot_id = snapshot_id
        self.terminal = terminal

    def __repr__(self):  # pragma: no cover
        return f"Barrier({self.snapshot_id}{', terminal' if self.terminal else ''})"


class DoneItem:
    """End-of-stream marker for batch stages. A singleton per edge traversal."""

    __slots__ = ()

    def __repr__(self):  # pragma: no cover
        return "DONE"


DONE = DoneItem()


def is_special(item) -> bool:
    """True for control items (watermark / barrier / done)."""
    return isinstance(item, (Watermark, Barrier, DoneItem))
