"""Windowed aggregation: aggregate operations and the two-stage sliding
window processors.

Implements Jet's two-stage plan (paper §3.1): stage 1 runs on a *local*
partitioned edge and accumulates events into per-(key, frame) partial
accumulators; only closed frames travel over the *distributed* partitioned
edge to stage 2, which combines partial frames and emits window results.
Frames (panes) have the size of the window slide, so a sliding window is a
combine over ``size/slide`` frames — and with an invertible (``deduct``)
aggregate operation the running window result is maintained in O(1) per
frame, the low-latency sliding-window technique the paper references
[Tangwongsan et al., Traub et al.].

Snapshot keys are partitioned exactly like the data keys, so on restore
after a topology change each entry lands on the instance that now owns its
partition (Jet's partitioning-matches-state invariant, §4.1).  Window
emission progress is tracked *per key* so restores never duplicate or
corrupt already-emitted windows.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .events import (MAX_TIME, MIN_TIME, Event, EventBlock, LateEvent,
                     Watermark)
from .processor import Inbox, Processor


# ---------------------------------------------------------------------------
# Aggregate operations
# ---------------------------------------------------------------------------


class AggregateOperation:
    """create / accumulate / combine / (deduct) / export.

    ``accumulate_fns`` has one accumulate function per input ordinal
    (co-aggregation, Jet's AggregateOperation2/3).  ``deduct`` being present
    makes sliding windows O(1) per slide instead of O(size/slide).

    ``kind``/``block_get`` mark ops the columnar accumulate fast path can
    vectorize: ``kind='count'`` needs nothing else; ``kind='sum'``
    additionally needs ``block_get(block) -> ndarray`` — the vectorized
    form of the scalar getter (attached via
    :func:`~repro.core.events.block_form`).  Everything else accumulates
    through the scalar path (blocks explode at the vertex boundary).
    """

    __slots__ = ("create", "accumulate_fns", "combine", "deduct", "export",
                 "kind", "block_get")

    def __init__(self, create: Callable[[], Any],
                 accumulate_fns: Tuple[Callable[[Any, Event], Any], ...],
                 combine: Callable[[Any, Any], Any],
                 deduct: Optional[Callable[[Any, Any], Any]],
                 export: Callable[[Any], Any],
                 kind: Optional[str] = None,
                 block_get: Optional[Callable] = None):
        self.create = create
        self.accumulate_fns = accumulate_fns
        self.combine = combine
        self.deduct = deduct
        self.export = export
        self.kind = kind
        self.block_get = block_get

    @property
    def accumulate(self):
        return self.accumulate_fns[0]


def counting() -> AggregateOperation:
    return AggregateOperation(
        create=lambda: 0,
        accumulate_fns=(lambda acc, ev: acc + 1,),
        combine=lambda a, b: a + b,
        deduct=lambda a, b: a - b,
        export=lambda acc: acc,
        kind="count",
    )


def summing(get: Callable[[Event], float]) -> AggregateOperation:
    return AggregateOperation(
        create=lambda: 0,
        accumulate_fns=(lambda acc, ev: acc + get(ev),),
        combine=lambda a, b: a + b,
        deduct=lambda a, b: a - b,
        export=lambda acc: acc,
        kind="sum",
        block_get=getattr(get, "__block_form__", None),
    )


def averaging(get: Callable[[Event], float]) -> AggregateOperation:
    return AggregateOperation(
        create=lambda: (0, 0),
        accumulate_fns=(lambda acc, ev: (acc[0] + get(ev), acc[1] + 1),),
        combine=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        deduct=lambda a, b: (a[0] - b[0], a[1] - b[1]),
        export=lambda acc: acc[0] / acc[1] if acc[1] else 0.0,
    )


def max_by(get: Callable[[Event], Any]) -> AggregateOperation:
    """Keeps the event value maximizing ``get``. Not invertible."""
    def acc_fn(acc, ev):
        m = get(ev)
        if acc is None or m > acc[0]:
            return (m, ev.value)
        return acc

    return AggregateOperation(
        create=lambda: None,
        accumulate_fns=(acc_fn,),
        combine=lambda a, b: b if a is None else a if b is None else max(a, b),
        deduct=None,
        export=lambda acc: None if acc is None else acc[1],
    )


def to_list() -> AggregateOperation:
    return AggregateOperation(
        create=lambda: [],
        accumulate_fns=(lambda acc, ev: (acc.append(ev.value) or acc),),
        combine=lambda a, b: a + b,
        deduct=None,
        export=lambda acc: list(acc),
    )


def co_aggregate(left: Callable[[Event], Any] = lambda ev: ev.value,
                 right: Callable[[Event], Any] = lambda ev: ev.value
                 ) -> AggregateOperation:
    """Two-input aggregation collecting both sides (windowed join substrate)."""
    def acc0(acc, ev):
        acc[0].append(left(ev))
        return acc

    def acc1(acc, ev):
        acc[1].append(right(ev))
        return acc

    return AggregateOperation(
        create=lambda: ([], []),
        accumulate_fns=(acc0, acc1),
        combine=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        deduct=None,
        export=lambda acc: acc,
    )


# ---------------------------------------------------------------------------
# Window definitions
# ---------------------------------------------------------------------------


class SlidingWindowDef:
    """Window of ``size`` sliding by ``slide``; size % slide == 0.

    Frames are labelled by their *end* timestamp; an event with timestamp
    ``ts`` belongs to the frame ending at ``higher_frame_ts(ts)``.  The
    window ending at W covers frames (W - size, W].
    """

    __slots__ = ("size", "slide")

    def __init__(self, size: int, slide: int):
        if size <= 0 or slide <= 0 or size % slide:
            raise ValueError("need size > 0, slide > 0, size % slide == 0")
        self.size = size
        self.slide = slide

    def higher_frame_ts(self, ts: int) -> int:
        return (ts // self.slide + 1) * self.slide

    @property
    def frames_per_window(self) -> int:
        return self.size // self.slide


def tumbling(size: int) -> SlidingWindowDef:
    return SlidingWindowDef(size, size)


def sliding(size: int, slide: int) -> SlidingWindowDef:
    return SlidingWindowDef(size, slide)


class SessionWindowDef:
    """Gap-based session windows: events of one key closer than ``gap``
    belong to the same session; a session closes when the watermark passes
    its end (last event time + gap)."""

    __slots__ = ("gap",)

    def __init__(self, gap: int):
        if gap <= 0:
            raise ValueError("need gap > 0")
        self.gap = gap


def session(gap: int) -> SessionWindowDef:
    return SessionWindowDef(gap)


# ---------------------------------------------------------------------------
# Stage 1: accumulate events into per-(key, frame) partial accumulators
# ---------------------------------------------------------------------------


class AccumulateByFrameProcessor(Processor):
    """Local partial aggregation (first of the two stages).

    Emits ``Event(ts=frame_end - 1, key, (frame_end, partial_acc))`` for
    every frame closed by a watermark; open frames are retained and
    snapshotted.

    **Allowed lateness**: a frame stays admissible for ``allowed_lateness``
    event-time past the watermark.  Events landing in an already-closed but
    still-admissible frame accumulate into a fresh *delta* partial that is
    emitted at the next watermark — the combiner re-fires the affected
    windows with updated totals.  Events later than that are counted in
    ``late_dropped`` and, with ``late_output``, wrapped in
    :class:`~repro.core.events.LateEvent` for the late side output.
    """

    #: _last_wm is deliberately NOT snapshotted (see save_to_snapshot:
    #: a restored lateness horizon would drop replayed data); _emit_buf
    #: is flushed before every barrier by construction; late_dropped is
    #: telemetry, not replayable state
    EPHEMERAL_STATE = frozenset({"_last_wm", "_emit_buf", "late_dropped"})

    def __init__(self, wdef: SlidingWindowDef, op: AggregateOperation,
                 ordinal_map: Optional[Dict[int, int]] = None,
                 allowed_lateness: int = 0, late_output: bool = False):
        self.wdef = wdef
        self.op = op
        # input edge ordinal -> accumulate_fn index (for co-aggregation)
        self.ordinal_map = ordinal_map or {}
        # (key, frame_ts) -> acc
        self.frames: Dict[Tuple[Any, int], Any] = {}
        self._emit_buf: deque = deque()
        self.allowed_lateness = allowed_lateness
        self.late_output = late_output
        #: events that arrived too late to be admissible (deliberate drops)
        self.late_dropped = 0
        self._last_wm = MIN_TIME
        #: columnar fast path: counting (needs nothing) and summing (needs
        #: a vectorized getter) vectorize per block; co-aggregation keeps
        #: the scalar path (two accumulate fns, object accumulators)
        self.accepts_blocks = (
            not self.ordinal_map
            and (op.kind == "count"
                 or (op.kind == "sum" and op.block_get is not None)))

    def process(self, ordinal: int, inbox: Inbox) -> None:
        acc_fn = self.op.accumulate_fns[self.ordinal_map.get(ordinal, 0)]
        frames, slide = self.frames, self.wdef.slide
        create = self.op.create
        get = frames.get
        # frames at or below the horizon can no longer re-fire
        horizon = self._last_wm - self.allowed_lateness
        # accumulation never backpressures: consume the whole batch in one
        # pass over the inbox (only data events reach a processor's inbox);
        # higher_frame_ts is inlined — it runs once per event
        for ev in inbox:
            if ev.__class__ is EventBlock:
                self._accumulate_block(ev, horizon)
                continue
            fts = (ev.ts // slide + 1) * slide
            if fts <= horizon:
                # frame's lateness horizon passed: deliberate drop, not the
                # silent re-emission the pre-lateness code did
                self.late_dropped += 1
                if self.late_output:
                    le = LateEvent(ev.ts, ev.key, ev.value)
                    if not self.outbox.offer(le):
                        self._emit_buf.append(le)
                continue
            fkey = (ev.key, fts)
            acc = get(fkey)
            frames[fkey] = acc_fn(create() if acc is None else acc, ev)
        inbox.clear()

    def _accumulate_block(self, blk: EventBlock, horizon: int) -> None:
        """Columnar accumulate: frame assignment by floor-divide on the ts
        column, per-(key, frame) partial aggregation by a stable lexsort +
        segment reduce.  Within one (key, frame) group rows stay in stream
        order, so integer sums and counts are bit-identical to the scalar
        path; the only reassociation is the single ``combine`` of the
        block partial into the running accumulator."""
        op = self.op
        slide = self.wdef.slide
        ts, keys = blk.ts, blk.key
        if not len(ts):
            return
        fts = (ts // slide + 1) * slide
        weights = None
        if op.kind == "sum":
            weights = np.asarray(op.block_get(blk))
        late = fts <= horizon
        if late.any():
            late_idx = np.nonzero(late)[0]
            self.late_dropped += len(late_idx)
            if self.late_output:
                for i in late_idx.tolist():
                    le = LateEvent(int(ts[i]), int(keys[i]), blk.value_at(i))
                    if not self.outbox.offer(le):
                        self._emit_buf.append(le)
            keep = np.nonzero(~late)[0]
            if not len(keep):
                return
            keys, fts = keys[keep], fts[keep]
            if weights is not None:
                weights = weights[keep]
        order = np.lexsort((fts, keys))
        ks, fs = keys[order], fts[order]
        starts = np.nonzero(np.concatenate(
            ([True], (ks[1:] != ks[:-1]) | (fs[1:] != fs[:-1]))))[0]
        if weights is None:
            sums = np.diff(np.append(starts, len(ks)))
        else:
            sums = np.add.reduceat(weights[order], starts)
        frames = self.frames
        get = frames.get
        combine = op.combine
        gk, gf = ks[starts].tolist(), fs[starts].tolist()
        for i, part in enumerate(sums.tolist()):
            fkey = (gk[i], gf[i])
            cur = get(fkey)
            frames[fkey] = part if cur is None else combine(cur, part)

    def _flush(self) -> bool:
        buf = self._emit_buf
        while buf:
            if not self.outbox.offer(buf[0]):
                return False
            buf.popleft()
        return True

    def try_process_watermark(self, wm: Watermark) -> bool:
        # leftovers (backpressured LateEvents) go out first: the close work
        # below must still happen for THIS watermark afterwards, or the
        # forwarded watermark would overtake its closed frames
        if not self._flush():
            return False
        if wm.ts > self._last_wm:       # close exactly once per watermark
            self._last_wm = wm.ts
            buf = self._emit_buf
            closed = [(k, f) for (k, f) in self.frames if f <= wm.ts]
            closed.sort(key=lambda kf: kf[1])
            for key, fts in closed:
                buf.append(Event(fts - 1, key, (fts, self.frames.pop((key, fts)))))
        return self._flush()

    def complete(self) -> bool:
        if not self._flush():
            return False
        # batch semantics: flush every open frame
        for (key, fts), acc in sorted(self.frames.items(),
                                      key=lambda kv: kv[0][1]):
            if not self.outbox.offer(Event(fts - 1, key, (fts, acc))):
                return False
            del self.frames[(key, fts)]
        return True

    # -- snapshots ------------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        # pre-barrier outputs stuck in the emit buffer (backpressured
        # LateEvents) must leave before the barrier, or a restore loses them
        if not self._flush():
            return False
        for (key, fts), acc in self.frames.items():
            self.outbox.offer_to_snapshot((key, fts), acc)
        # _last_wm is deliberately NOT snapshotted: replay (at-least-once
        # especially) re-delivers events from behind the snapshot watermark
        # that must re-accumulate, so the lateness horizon rebuilds from
        # the replayed stream's own watermarks.  Transiently admitting a
        # borderline-late event only re-fires a window with a more complete
        # value; a restored horizon would DROP replayed data.
        return True

    def restore_from_snapshot(self, items) -> None:
        for (key, fts), acc in items:
            cur = self.frames.get((key, fts))
            self.frames[(key, fts)] = (acc if cur is None
                                       else self.op.combine(cur, acc))

    def snapshot_partition(self, skey):
        # partition by the event key so restore follows the data partitions
        from .dag import PARTITION_COUNT
        return hash(skey[0]) % PARTITION_COUNT


# ---------------------------------------------------------------------------
# Stage 2: combine partial frames, maintain sliding windows, emit results
# ---------------------------------------------------------------------------


class WindowResult:
    __slots__ = ("window_end", "key", "value")

    def __init__(self, window_end: int, key, value):
        self.window_end = window_end
        self.key = key
        self.value = value

    def __repr__(self):  # pragma: no cover
        return (f"WindowResult(end={self.window_end}, key={self.key!r}, "
                f"value={self.value!r})")


class _KeyState:
    __slots__ = ("max_frame", "last_emitted", "running", "ring")

    def __init__(self):
        self.max_frame = MIN_TIME
        self.last_emitted = MIN_TIME
        # deduct fast path: running window accumulator + in-window frame ring
        self.running = None
        self.ring: Optional[Dict[int, Any]] = None


class CombineFramesProcessor(Processor):
    """Global combine (second stage) + window emission.

    Receives ``(frame_ts, partial_acc)`` events over the distributed
    partitioned edge.  With a ``deduct``-capable op it keeps a running
    window accumulator per key: each slide adds the entering frames and
    deducts the leaving ones — O(1) amortized per (key, slide) instead of
    recombining ``size/slide`` frames.
    """

    #: next_win_end is re-derived by restore_from_snapshot from the
    #: restored frames/rings (min open frame + slide); _emit_buf is
    #: flushed before every barrier by construction
    EPHEMERAL_STATE = frozenset({"next_win_end", "_emit_buf"})

    def __init__(self, wdef: SlidingWindowDef, op: AggregateOperation,
                 use_deduct: Optional[bool] = None,
                 allowed_lateness: int = 0, skip_late: bool = False):
        self.wdef = wdef
        self.op = op
        #: lateness disables the O(1) deduct path: re-firing a window needs
        #: its full frame set recombined, so frames must be retained (not
        #: folded into a running accumulator) until the lateness horizon
        self.allowed_lateness = allowed_lateness
        self.use_deduct = (op.deduct is not None if use_deduct is None
                           else (use_deduct and op.deduct is not None))
        if allowed_lateness > 0:
            self.use_deduct = False
        #: drop LateEvents travelling on the shared accumulate->combine
        #: edge when a late side output is wired upstream
        self.skip_late = skip_late
        self.frames: Dict[Tuple[Any, int], Any] = {}   # (key, frame) -> acc
        self.key_state: Dict[Any, _KeyState] = {}
        self.next_win_end: Optional[int] = None        # next W to consider
        self._emit_buf: deque = deque()
        #: (key, window_end) pairs whose result must be re-emitted because a
        #: late delta partial arrived after the window fired
        self._refire: set = set()

    # -- ingest ----------------------------------------------------------------
    def process(self, ordinal: int, inbox: Inbox) -> None:
        frames, combine = self.frames, self.op.combine
        key_state = self.key_state
        lateness = self.allowed_lateness
        skip_late = self.skip_late
        size, slide = self.wdef.size, self.wdef.slide
        for ev in inbox:
            if skip_late and isinstance(ev, LateEvent):
                continue
            fts, acc = ev.value
            ks = key_state.get(ev.key)
            if ks is None:
                ks = key_state[ev.key] = _KeyState()
            fkey = (ev.key, fts)
            cur = frames.get(fkey)
            frames[fkey] = acc if cur is None else combine(cur, acc)
            if fts > ks.max_frame:
                ks.max_frame = fts
            if lateness and fts <= ks.last_emitted:
                # late delta: every window covering this frame whose
                # emission point already passed re-fires with the updated
                # total (including windows that fired empty — the emission
                # loop's last_emitted guard would otherwise skip them).
                # NOT rewinding next_win_end here: the refire set covers
                # the emitted range, and a rewind would make the next
                # emission pass re-walk every slide from here to the front
                w = fts
                last = min(ks.last_emitted, fts + size - slide)
                while w <= last:
                    self._refire.add((ev.key, w))
                    w += slide
            elif self.next_win_end is None or fts < self.next_win_end:
                # earliest window this frame participates in
                self.next_win_end = fts
        inbox.clear()

    # -- window emission --------------------------------------------------------
    def _window_value(self, key, ks: _KeyState, w_end: int):
        """Combined accumulator for (key, window ending at w_end) or None."""
        op, frames = self.op, self.frames
        size, slide = self.wdef.size, self.wdef.slide
        if self.use_deduct:
            # move entering frames into the ring / running acc
            if ks.ring is None:
                ks.ring = {}
            # frames entering the window since this key's last emission
            lo_new = max(ks.last_emitted, w_end - size)
            f = lo_new + slide
            while f <= w_end:
                part = frames.pop((key, f), None)
                if part is not None:
                    if f in ks.ring:
                        ks.ring[f] = op.combine(ks.ring[f], part)
                    else:
                        ks.ring[f] = part
                    ks.running = (part if ks.running is None
                                  else op.combine(ks.running, part))
                f += slide
            # deduct frames that left the window
            lo = w_end - size
            for fts in [t for t in ks.ring if t <= lo]:
                ks.running = op.deduct(ks.running, ks.ring.pop(fts))
            if not ks.ring:
                ks.running = None
                return None
            return ks.running
        # general path: recombine the size/slide frames
        acc = None
        f = w_end - size + slide
        while f <= w_end:
            part = frames.get((key, f))
            if part is not None:
                acc = part if acc is None else op.combine(acc, part)
            f += slide
        return acc

    def _emit_refires(self) -> None:
        """Re-emit updated results for windows hit by late delta frames."""
        if not self._refire:
            return
        op = self.op
        for key, w in sorted(self._refire, key=lambda kw: kw[1]):
            ks = self.key_state.get(key)
            if ks is None:
                continue
            acc = self._window_value(key, ks, w)
            if acc is not None:
                self._emit_buf.append(
                    Event(w - 1, key, WindowResult(w, key, op.export(acc))))
        self._refire.clear()

    def _emit_windows_up_to(self, up_to: int) -> None:
        self._emit_refires()
        if self.next_win_end is None:
            return
        slide, size = self.wdef.slide, self.wdef.size
        lateness = self.allowed_lateness
        op = self.op
        # align the first candidate window end to the slide grid
        w = -(-self.next_win_end // slide) * slide
        last_w = (up_to // slide) * slide
        # clamp to the last window any present frame participates in (an
        # idle source advertises a MAX_TIME watermark; without the clamp the
        # emission loop would walk to infinity)
        top = max((ks.max_frame for ks in self.key_state.values()),
                  default=None)
        if top is None:
            return
        last_w = min(last_w, top + size - slide)
        while w <= last_w:
            for key in list(self.key_state):
                ks = self.key_state[key]
                if ks.last_emitted >= w:
                    continue
                acc = self._window_value(key, ks, w)
                if acc is not None:
                    self._emit_buf.append(
                        Event(w - 1, key, WindowResult(w, key, op.export(acc))))
                ks.last_emitted = w
                if (not lateness and ks.max_frame <= w - size + slide
                        and (ks.ring is None or not ks.ring)):
                    # with lateness the key state must outlive the window:
                    # ``last_emitted`` decides whether a late frame re-fires
                    # or opens fresh windows (GC'd in the sweep below)
                    del self.key_state[key]
            if not self.use_deduct:
                # frames feed re-fires until every window covering them is
                # past the lateness horizon
                evict_to = w - size + slide - lateness
                for fkey in [fk for fk in self.frames if fk[1] <= evict_to]:
                    del self.frames[fkey]
            w += slide
            self.next_win_end = w
        if lateness:
            # GC keys whose frames are all evicted AND whose emission front
            # is old enough that any still-admissible frame (fts > wm -
            # lateness > last_emitted) would only open fresh windows
            evict_to = last_w - size + slide - lateness
            stale = [key for key, ks in self.key_state.items()
                     if ks.max_frame <= evict_to
                     and ks.last_emitted + lateness <= up_to]
            for key in stale:
                del self.key_state[key]

    def try_process_watermark(self, wm: Watermark) -> bool:
        # flush leftovers first, then close for THIS watermark (idempotent:
        # per-key last_emitted guards + next_win_end make a re-entry after
        # partial flush a no-op) — returning True without the close would
        # forward the watermark ahead of the windows it closes
        if not self._flush():
            return False
        self._emit_windows_up_to(wm.ts)
        return self._flush()

    def complete(self) -> bool:
        # no emptiness guard: emission is idempotent (per-key last_emitted,
        # refires clear as they queue), and gating it on a drained buffer
        # would drop the final windows when LateEvents sit buffered at DONE
        top = max((ks.max_frame for ks in self.key_state.values()),
                  default=None)
        if top is not None:
            self._emit_windows_up_to(top + self.wdef.size - self.wdef.slide)
        else:
            self._emit_refires()
        return self._flush()

    def _flush(self) -> bool:
        buf = self._emit_buf
        while buf:
            if not self.outbox.offer(buf[0]):
                return False
            buf.popleft()
        return True

    # -- snapshots ------------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        # backpressured window results must precede the barrier: the frames
        # that produced them are already evicted, so a restore that loses
        # the buffer can never regenerate them
        if not self._flush():
            return False
        for (key, fts), acc in self.frames.items():
            self.outbox.offer_to_snapshot(("f", key, fts), acc)
        for key, ks in self.key_state.items():
            # the ring must be copied: the processor keeps accumulating
            # into the live dict between this barrier and the job-wide
            # commit, and an aliased payload would smuggle post-barrier
            # events into the snapshot
            ring = None if ks.ring is None else dict(ks.ring)
            self.outbox.offer_to_snapshot(
                ("k", key), (ks.max_frame, ks.last_emitted, ring))
        for key, w in self._refire:
            self.outbox.offer_to_snapshot(("r", key, w), True)
        return True

    def restore_from_snapshot(self, items) -> None:
        combine = self.op.combine
        for skey, val in items:
            tag = skey[0]
            if tag == "r":
                self._refire.add((skey[1], skey[2]))
            elif tag == "f":
                _, key, fts = skey
                cur = self.frames.get((key, fts))
                self.frames[(key, fts)] = (val if cur is None
                                           else combine(cur, val))
                if self.next_win_end is None or fts < self.next_win_end:
                    self.next_win_end = fts
            else:
                _, key = skey
                max_frame, last_emitted, ring = val
                ks = self.key_state.get(key)
                if ks is None:
                    ks = self.key_state[key] = _KeyState()
                ks.max_frame = max(ks.max_frame, max_frame)
                ks.last_emitted = max(ks.last_emitted, last_emitted)
                if ring:
                    if ks.ring is None:
                        ks.ring = {}
                    for fts, acc in ring.items():
                        ks.ring[fts] = (combine(ks.ring[fts], acc)
                                        if fts in ks.ring else acc)
                        ks.running = (acc if ks.running is None
                                      else combine(ks.running, acc))
                    nxt = min(ring) + self.wdef.slide
                    if self.next_win_end is None or nxt < self.next_win_end:
                        self.next_win_end = min(self.next_win_end or nxt, nxt)

    def finish_snapshot_restore(self) -> None:
        # Emission restarts from the earliest restored frame's window; the
        # per-key ``last_emitted`` guards make re-considered windows no-ops,
        # so no global fast-forward is needed (and fast-forwarding could skip
        # windows of keys that were behind at snapshot time).
        pass

    def snapshot_partition(self, skey):
        # ("f", key, fts), ("k", key), ("r", key, w): partition by event key
        from .dag import PARTITION_COUNT
        return hash(skey[1]) % PARTITION_COUNT


# ---------------------------------------------------------------------------
# Session windows: gap-based, key-local merge, single stage
# ---------------------------------------------------------------------------


class SessionResult(WindowResult):
    """Window result of a session: additionally carries the session start."""

    __slots__ = ("window_start",)

    def __init__(self, window_start: int, window_end: int, key, value):
        super().__init__(window_end, key, value)
        self.window_start = window_start

    def __repr__(self):  # pragma: no cover
        return (f"SessionResult([{self.window_start}, {self.window_end}), "
                f"key={self.key!r}, value={self.value!r})")


class _Session:
    """One session interval [start, end) with its accumulator.

    ``end`` is the session close time: last event ts + gap.  ``emitted``
    marks a closed session whose result went out; a late admissible event
    merging into it clears the flag so the updated result re-fires.
    """

    __slots__ = ("start", "end", "acc", "emitted")

    def __init__(self, start: int, end: int, acc, emitted: bool = False):
        self.start = start
        self.end = end
        self.acc = acc
        self.emitted = emitted


class SessionWindowProcessor(Processor):
    """Gap-based session windows (NEXMark Q11's "bids per user session").

    Unlike the two-stage sliding plan, sessions run as ONE keyed vertex on a
    distributed partitioned edge: merging is key-local and a session's frame
    boundaries are data-dependent, so there is no fixed frame grid to split
    the aggregation over (Jet makes the same choice).

    Semantics:

    * an event opens the interval ``[ts, ts + gap)``; intervals of one key
      that touch are merged (accumulators combined via ``op.combine``);
    * a session closes when the watermark reaches its end, emitting
      ``Event(end - 1, key, SessionResult(start, end, key, export(acc)))``;
    * closed sessions are retained for ``allowed_lateness``: an admissible
      late event (``ts >= wm - allowed_lateness``) merges in and re-fires
      the updated result; anything later is counted in ``late_dropped`` and
      optionally forwarded as a :class:`LateEvent` (late side output);
    * state snapshots per key through the standard
      ``save_to_snapshot``/``restore_from_snapshot`` protocol, so sessions
      survive restarts and topology changes exactly-once.
    """

    #: same contract as AccumulateByFrameProcessor: the lateness horizon
    #: (_last_wm) rebuilds from the replayed stream's own watermarks, the
    #: emit buffer is flushed before every barrier, late_dropped is
    #: telemetry
    EPHEMERAL_STATE = frozenset({"_last_wm", "_emit_buf", "late_dropped"})

    def __init__(self, sdef: SessionWindowDef, op: AggregateOperation,
                 allowed_lateness: int = 0, late_output: bool = False):
        self.gap = sdef.gap
        self.op = op
        self.allowed_lateness = allowed_lateness
        self.late_output = late_output
        self.late_dropped = 0
        # key -> list of _Session sorted by start
        self.sessions: Dict[Any, List[_Session]] = {}
        self._emit_buf: deque = deque()
        self._last_wm = MIN_TIME

    # -- ingest ----------------------------------------------------------------
    def _merge_interval(self, sess: List[_Session], lo: int,
                        hi: int) -> Optional[_Session]:
        """Collapse every session strictly overlapping ``[lo, hi)`` into one
        (extended to cover [lo, hi)) and return it; None if none overlap.
        Strict overlap: events separated by exactly ``gap`` start a NEW
        session.  Per-key session counts are small (gap >> intra-burst
        spacing), a scan is fine.  The caller folds its own contribution
        into ``.acc``/``.emitted``."""
        touching = [s for s in sess if s.start < hi and lo < s.end]
        if not touching:
            return None
        merged = touching[0]
        for other in touching[1:]:
            merged.end = max(merged.end, other.end)
            merged.start = min(merged.start, other.start)
            merged.acc = self.op.combine(merged.acc, other.acc)
            merged.emitted = merged.emitted and other.emitted
            sess.remove(other)
        merged.start = min(merged.start, lo)
        merged.end = max(merged.end, hi)
        return merged

    def _merge_event(self, key, ts: int, ev: Event, acc_fn) -> None:
        lo, hi = ts, ts + self.gap
        sess = self.sessions.get(key)
        if sess is None:
            sess = self.sessions[key] = []
        merged = self._merge_interval(sess, lo, hi)
        if merged is None:
            sess.append(_Session(lo, hi, acc_fn(self.op.create(), ev)))
            sess.sort(key=lambda x: x.start)
            return
        merged.acc = acc_fn(merged.acc, ev)
        # any content change invalidates a previously emitted result
        merged.emitted = False

    def process(self, ordinal: int, inbox: Inbox) -> None:
        op = self.op
        acc_fn = op.accumulate_fns[min(ordinal, len(op.accumulate_fns) - 1)]
        horizon = self._last_wm - self.allowed_lateness
        for ev in inbox:
            ts = ev.ts
            if ts < horizon:
                self.late_dropped += 1
                if self.late_output:
                    le = LateEvent(ts, ev.key, ev.value)
                    if not self.outbox.offer(le):
                        self._emit_buf.append(le)
                continue
            self._merge_event(ev.key, ts, ev, acc_fn)
        inbox.clear()

    # -- emission ---------------------------------------------------------------
    def _close_up_to(self, wm_ts: int, retain: bool) -> None:
        """Emit every closed not-yet-emitted session; drop retained closed
        sessions whose lateness horizon passed (``retain=False`` drops at
        emission — batch completion)."""
        op = self.op
        ready: List[Tuple[int, Any, _Session]] = []
        for key, sess in self.sessions.items():
            for s in sess:
                if s.end <= wm_ts and not s.emitted:
                    ready.append((s.end, key, s))
        ready.sort(key=lambda x: (x[0], x[2].start))
        for end, key, s in ready:
            self._emit_buf.append(
                Event(end - 1, key,
                      SessionResult(s.start, end, key, op.export(s.acc))))
            s.emitted = True
        drop_before = (wm_ts - self.allowed_lateness if retain
                       else MAX_TIME)
        for key in list(self.sessions):
            kept = [s for s in self.sessions[key]
                    if not (s.emitted and s.end <= drop_before)]
            if kept:
                self.sessions[key] = kept
            else:
                del self.sessions[key]

    def try_process_watermark(self, wm: Watermark) -> bool:
        # leftovers (backpressured LateEvents) first — then the close work
        # must still run for THIS watermark, or it would be forwarded ahead
        # of the session results it closes
        if not self._flush():
            return False
        if wm.ts > self._last_wm:       # close exactly once per watermark
            self._last_wm = wm.ts
            self._close_up_to(wm.ts, retain=True)
        return self._flush()

    def complete(self) -> bool:
        # unconditional: closing is idempotent (sessions emit once and are
        # dropped), and gating on a drained buffer would lose every open
        # session when LateEvents sit buffered at DONE
        self._close_up_to(MAX_TIME, retain=False)
        return self._flush()

    def _flush(self) -> bool:
        buf = self._emit_buf
        while buf:
            if not self.outbox.offer(buf[0]):
                return False
            buf.popleft()
        return True

    # -- snapshots ------------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        # backpressured LateEvents are pre-barrier output: emit them before
        # the barrier or a restore loses them
        if not self._flush():
            return False
        for key, sess in self.sessions.items():
            self.outbox.offer_to_snapshot(
                ("s", key),
                [(s.start, s.end, s.acc, s.emitted) for s in sess])
        # _last_wm deliberately not snapshotted — same rationale as
        # AccumulateByFrameProcessor: the horizon rebuilds from replayed
        # watermarks; restoring it would drop replayed events
        return True

    def restore_from_snapshot(self, items) -> None:
        combine = self.op.combine
        for (tag, key), vals in items:
            if tag != "s":
                continue
            sess = self.sessions.get(key)
            if sess is None:
                self.sessions[key] = [
                    _Session(st, en, acc, em) for st, en, acc, em in vals]
                continue
            # merge the restored intervals with whatever is already there
            # (two snapshot shards of one key land on the same instance)
            for st, en, acc, em in vals:
                merged = self._merge_interval(sess, st, en)
                if merged is None:
                    sess.append(_Session(st, en, acc, em))
                    continue
                merged.acc = combine(merged.acc, acc)
                merged.emitted = merged.emitted and em
            sess.sort(key=lambda x: x.start)

    def snapshot_partition(self, skey):
        from .dag import PARTITION_COUNT
        return hash(skey[1]) % PARTITION_COUNT
