"""Windowed aggregation: aggregate operations and the two-stage sliding
window processors.

Implements Jet's two-stage plan (paper §3.1): stage 1 runs on a *local*
partitioned edge and accumulates events into per-(key, frame) partial
accumulators; only closed frames travel over the *distributed* partitioned
edge to stage 2, which combines partial frames and emits window results.
Frames (panes) have the size of the window slide, so a sliding window is a
combine over ``size/slide`` frames — and with an invertible (``deduct``)
aggregate operation the running window result is maintained in O(1) per
frame, the low-latency sliding-window technique the paper references
[Tangwongsan et al., Traub et al.].

Snapshot keys are partitioned exactly like the data keys, so on restore
after a topology change each entry lands on the instance that now owns its
partition (Jet's partitioning-matches-state invariant, §4.1).  Window
emission progress is tracked *per key* so restores never duplicate or
corrupt already-emitted windows.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from .events import MIN_TIME, Event, Watermark
from .processor import Inbox, Processor


# ---------------------------------------------------------------------------
# Aggregate operations
# ---------------------------------------------------------------------------


class AggregateOperation:
    """create / accumulate / combine / (deduct) / export.

    ``accumulate_fns`` has one accumulate function per input ordinal
    (co-aggregation, Jet's AggregateOperation2/3).  ``deduct`` being present
    makes sliding windows O(1) per slide instead of O(size/slide).
    """

    __slots__ = ("create", "accumulate_fns", "combine", "deduct", "export")

    def __init__(self, create: Callable[[], Any],
                 accumulate_fns: Tuple[Callable[[Any, Event], Any], ...],
                 combine: Callable[[Any, Any], Any],
                 deduct: Optional[Callable[[Any, Any], Any]],
                 export: Callable[[Any], Any]):
        self.create = create
        self.accumulate_fns = accumulate_fns
        self.combine = combine
        self.deduct = deduct
        self.export = export

    @property
    def accumulate(self):
        return self.accumulate_fns[0]


def counting() -> AggregateOperation:
    return AggregateOperation(
        create=lambda: 0,
        accumulate_fns=(lambda acc, ev: acc + 1,),
        combine=lambda a, b: a + b,
        deduct=lambda a, b: a - b,
        export=lambda acc: acc,
    )


def summing(get: Callable[[Event], float]) -> AggregateOperation:
    return AggregateOperation(
        create=lambda: 0,
        accumulate_fns=(lambda acc, ev: acc + get(ev),),
        combine=lambda a, b: a + b,
        deduct=lambda a, b: a - b,
        export=lambda acc: acc,
    )


def averaging(get: Callable[[Event], float]) -> AggregateOperation:
    return AggregateOperation(
        create=lambda: (0, 0),
        accumulate_fns=(lambda acc, ev: (acc[0] + get(ev), acc[1] + 1),),
        combine=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        deduct=lambda a, b: (a[0] - b[0], a[1] - b[1]),
        export=lambda acc: acc[0] / acc[1] if acc[1] else 0.0,
    )


def max_by(get: Callable[[Event], Any]) -> AggregateOperation:
    """Keeps the event value maximizing ``get``. Not invertible."""
    def acc_fn(acc, ev):
        m = get(ev)
        if acc is None or m > acc[0]:
            return (m, ev.value)
        return acc

    return AggregateOperation(
        create=lambda: None,
        accumulate_fns=(acc_fn,),
        combine=lambda a, b: b if a is None else a if b is None else max(a, b),
        deduct=None,
        export=lambda acc: None if acc is None else acc[1],
    )


def to_list() -> AggregateOperation:
    return AggregateOperation(
        create=lambda: [],
        accumulate_fns=(lambda acc, ev: (acc.append(ev.value) or acc),),
        combine=lambda a, b: a + b,
        deduct=None,
        export=lambda acc: list(acc),
    )


def co_aggregate(left: Callable[[Event], Any] = lambda ev: ev.value,
                 right: Callable[[Event], Any] = lambda ev: ev.value
                 ) -> AggregateOperation:
    """Two-input aggregation collecting both sides (windowed join substrate)."""
    def acc0(acc, ev):
        acc[0].append(left(ev))
        return acc

    def acc1(acc, ev):
        acc[1].append(right(ev))
        return acc

    return AggregateOperation(
        create=lambda: ([], []),
        accumulate_fns=(acc0, acc1),
        combine=lambda a, b: (a[0] + b[0], a[1] + b[1]),
        deduct=None,
        export=lambda acc: acc,
    )


# ---------------------------------------------------------------------------
# Window definitions
# ---------------------------------------------------------------------------


class SlidingWindowDef:
    """Window of ``size`` sliding by ``slide``; size % slide == 0.

    Frames are labelled by their *end* timestamp; an event with timestamp
    ``ts`` belongs to the frame ending at ``higher_frame_ts(ts)``.  The
    window ending at W covers frames (W - size, W].
    """

    __slots__ = ("size", "slide")

    def __init__(self, size: int, slide: int):
        if size <= 0 or slide <= 0 or size % slide:
            raise ValueError("need size > 0, slide > 0, size % slide == 0")
        self.size = size
        self.slide = slide

    def higher_frame_ts(self, ts: int) -> int:
        return (ts // self.slide + 1) * self.slide

    @property
    def frames_per_window(self) -> int:
        return self.size // self.slide


def tumbling(size: int) -> SlidingWindowDef:
    return SlidingWindowDef(size, size)


def sliding(size: int, slide: int) -> SlidingWindowDef:
    return SlidingWindowDef(size, slide)


# ---------------------------------------------------------------------------
# Stage 1: accumulate events into per-(key, frame) partial accumulators
# ---------------------------------------------------------------------------


class AccumulateByFrameProcessor(Processor):
    """Local partial aggregation (first of the two stages).

    Emits ``Event(ts=frame_end - 1, key, (frame_end, partial_acc))`` for
    every frame closed by a watermark; open frames are retained and
    snapshotted.
    """

    def __init__(self, wdef: SlidingWindowDef, op: AggregateOperation,
                 ordinal_map: Optional[Dict[int, int]] = None):
        self.wdef = wdef
        self.op = op
        # input edge ordinal -> accumulate_fn index (for co-aggregation)
        self.ordinal_map = ordinal_map or {}
        # (key, frame_ts) -> acc
        self.frames: Dict[Tuple[Any, int], Any] = {}
        self._emit_buf: deque = deque()

    def process(self, ordinal: int, inbox: Inbox) -> None:
        acc_fn = self.op.accumulate_fns[self.ordinal_map.get(ordinal, 0)]
        frames, slide = self.frames, self.wdef.slide
        create = self.op.create
        get = frames.get
        # accumulation never backpressures: consume the whole batch in one
        # pass over the inbox (only data events reach a processor's inbox);
        # higher_frame_ts is inlined — it runs once per event
        for ev in inbox:
            fkey = (ev.key, (ev.ts // slide + 1) * slide)
            acc = get(fkey)
            frames[fkey] = acc_fn(create() if acc is None else acc, ev)
        inbox.clear()

    def try_process_watermark(self, wm: Watermark) -> bool:
        buf = self._emit_buf
        if not buf:
            closed = [(k, f) for (k, f) in self.frames if f <= wm.ts]
            closed.sort(key=lambda kf: kf[1])
            for key, fts in closed:
                buf.append(Event(fts - 1, key, (fts, self.frames.pop((key, fts)))))
        while buf:
            if not self.outbox.offer(buf[0]):
                return False
            buf.popleft()
        return True

    def complete(self) -> bool:
        # batch semantics: flush every open frame
        for (key, fts), acc in sorted(self.frames.items(),
                                      key=lambda kv: kv[0][1]):
            if not self.outbox.offer(Event(fts - 1, key, (fts, acc))):
                return False
            del self.frames[(key, fts)]
        return True

    # -- snapshots ------------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        for (key, fts), acc in self.frames.items():
            self.outbox.offer_to_snapshot((key, fts), acc)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (key, fts), acc in items:
            cur = self.frames.get((key, fts))
            self.frames[(key, fts)] = (acc if cur is None
                                       else self.op.combine(cur, acc))

    def snapshot_partition(self, skey):
        # partition by the event key so restore follows the data partitions
        from .dag import PARTITION_COUNT
        return hash(skey[0]) % PARTITION_COUNT


# ---------------------------------------------------------------------------
# Stage 2: combine partial frames, maintain sliding windows, emit results
# ---------------------------------------------------------------------------


class WindowResult:
    __slots__ = ("window_end", "key", "value")

    def __init__(self, window_end: int, key, value):
        self.window_end = window_end
        self.key = key
        self.value = value

    def __repr__(self):  # pragma: no cover
        return (f"WindowResult(end={self.window_end}, key={self.key!r}, "
                f"value={self.value!r})")


class _KeyState:
    __slots__ = ("max_frame", "last_emitted", "running", "ring")

    def __init__(self):
        self.max_frame = MIN_TIME
        self.last_emitted = MIN_TIME
        # deduct fast path: running window accumulator + in-window frame ring
        self.running = None
        self.ring: Optional[Dict[int, Any]] = None


class CombineFramesProcessor(Processor):
    """Global combine (second stage) + window emission.

    Receives ``(frame_ts, partial_acc)`` events over the distributed
    partitioned edge.  With a ``deduct``-capable op it keeps a running
    window accumulator per key: each slide adds the entering frames and
    deducts the leaving ones — O(1) amortized per (key, slide) instead of
    recombining ``size/slide`` frames.
    """

    def __init__(self, wdef: SlidingWindowDef, op: AggregateOperation,
                 use_deduct: Optional[bool] = None):
        self.wdef = wdef
        self.op = op
        self.use_deduct = (op.deduct is not None if use_deduct is None
                           else (use_deduct and op.deduct is not None))
        self.frames: Dict[Tuple[Any, int], Any] = {}   # (key, frame) -> acc
        self.key_state: Dict[Any, _KeyState] = {}
        self.next_win_end: Optional[int] = None        # next W to consider
        self._emit_buf: deque = deque()

    # -- ingest ----------------------------------------------------------------
    def process(self, ordinal: int, inbox: Inbox) -> None:
        frames, combine = self.frames, self.op.combine
        key_state = self.key_state
        for ev in inbox:
            fts, acc = ev.value
            ks = key_state.get(ev.key)
            if ks is None:
                ks = key_state[ev.key] = _KeyState()
            fkey = (ev.key, fts)
            cur = frames.get(fkey)
            frames[fkey] = acc if cur is None else combine(cur, acc)
            if fts > ks.max_frame:
                ks.max_frame = fts
            if self.next_win_end is None or fts < self.next_win_end:
                # earliest window this frame participates in
                self.next_win_end = fts
        inbox.clear()

    # -- window emission --------------------------------------------------------
    def _window_value(self, key, ks: _KeyState, w_end: int):
        """Combined accumulator for (key, window ending at w_end) or None."""
        op, frames = self.op, self.frames
        size, slide = self.wdef.size, self.wdef.slide
        if self.use_deduct:
            # move entering frames into the ring / running acc
            if ks.ring is None:
                ks.ring = {}
            # frames entering the window since this key's last emission
            lo_new = max(ks.last_emitted, w_end - size)
            f = lo_new + slide
            while f <= w_end:
                part = frames.pop((key, f), None)
                if part is not None:
                    if f in ks.ring:
                        ks.ring[f] = op.combine(ks.ring[f], part)
                    else:
                        ks.ring[f] = part
                    ks.running = (part if ks.running is None
                                  else op.combine(ks.running, part))
                f += slide
            # deduct frames that left the window
            lo = w_end - size
            for fts in [t for t in ks.ring if t <= lo]:
                ks.running = op.deduct(ks.running, ks.ring.pop(fts))
            if not ks.ring:
                ks.running = None
                return None
            return ks.running
        # general path: recombine the size/slide frames
        acc = None
        f = w_end - size + slide
        while f <= w_end:
            part = frames.get((key, f))
            if part is not None:
                acc = part if acc is None else op.combine(acc, part)
            f += slide
        return acc

    def _emit_windows_up_to(self, up_to: int) -> None:
        if self.next_win_end is None:
            return
        slide, size = self.wdef.slide, self.wdef.size
        op = self.op
        # align the first candidate window end to the slide grid
        w = -(-self.next_win_end // slide) * slide
        last_w = (up_to // slide) * slide
        # clamp to the last window any present frame participates in (an
        # idle source advertises a MAX_TIME watermark; without the clamp the
        # emission loop would walk to infinity)
        top = max((ks.max_frame for ks in self.key_state.values()),
                  default=None)
        if top is None:
            return
        last_w = min(last_w, top + size - slide)
        while w <= last_w:
            for key in list(self.key_state):
                ks = self.key_state[key]
                if ks.last_emitted >= w:
                    continue
                acc = self._window_value(key, ks, w)
                if acc is not None:
                    self._emit_buf.append(
                        Event(w - 1, key, WindowResult(w, key, op.export(acc))))
                ks.last_emitted = w
                if ks.max_frame <= w - size + slide and (ks.ring is None
                                                         or not ks.ring):
                    del self.key_state[key]
            if not self.use_deduct:
                evict_to = w - size + slide
                for fkey in [fk for fk in self.frames if fk[1] <= evict_to]:
                    del self.frames[fkey]
            w += slide
            self.next_win_end = w

    def try_process_watermark(self, wm: Watermark) -> bool:
        if not self._emit_buf:
            self._emit_windows_up_to(wm.ts)
        return self._flush()

    def complete(self) -> bool:
        if not self._emit_buf:
            top = max((ks.max_frame for ks in self.key_state.values()),
                      default=None)
            if top is not None:
                self._emit_windows_up_to(top + self.wdef.size - self.wdef.slide)
        return self._flush()

    def _flush(self) -> bool:
        buf = self._emit_buf
        while buf:
            if not self.outbox.offer(buf[0]):
                return False
            buf.popleft()
        return True

    # -- snapshots ------------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        for (key, fts), acc in self.frames.items():
            self.outbox.offer_to_snapshot(("f", key, fts), acc)
        for key, ks in self.key_state.items():
            self.outbox.offer_to_snapshot(
                ("k", key), (ks.max_frame, ks.last_emitted, ks.ring))
        return True

    def restore_from_snapshot(self, items) -> None:
        combine = self.op.combine
        for skey, val in items:
            tag = skey[0]
            if tag == "f":
                _, key, fts = skey
                cur = self.frames.get((key, fts))
                self.frames[(key, fts)] = (val if cur is None
                                           else combine(cur, val))
                if self.next_win_end is None or fts < self.next_win_end:
                    self.next_win_end = fts
            else:
                _, key = skey
                max_frame, last_emitted, ring = val
                ks = self.key_state.get(key)
                if ks is None:
                    ks = self.key_state[key] = _KeyState()
                ks.max_frame = max(ks.max_frame, max_frame)
                ks.last_emitted = max(ks.last_emitted, last_emitted)
                if ring:
                    if ks.ring is None:
                        ks.ring = {}
                    for fts, acc in ring.items():
                        ks.ring[fts] = (combine(ks.ring[fts], acc)
                                        if fts in ks.ring else acc)
                        ks.running = (acc if ks.running is None
                                      else combine(ks.running, acc))
                    nxt = min(ring) + self.wdef.slide
                    if self.next_win_end is None or nxt < self.next_win_end:
                        self.next_win_end = min(self.next_win_end or nxt, nxt)

    def finish_snapshot_restore(self) -> None:
        # Emission restarts from the earliest restored frame's window; the
        # per-key ``last_emitted`` guards make re-considered windows no-ops,
        # so no global fast-forward is needed (and fast-forwarding could skip
        # windows of keys that were behind at snapshot time).
        pass

    def snapshot_partition(self, skey):
        # ("f", key, fts) and ("k", key) both partition by the event key
        from .dag import PARTITION_COUNT
        return hash(skey[1]) % PARTITION_COUNT
