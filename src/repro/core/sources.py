"""Sources and sinks.

* :class:`ListSource` — batch source over a fixed collection.
* :class:`PacedGeneratorSource` — streaming source that emits synthetic
  events on an ideal schedule (``rate`` events/second of *cluster clock*);
  any delay in actually emitting an event counts against the measured
  latency, exactly the paper's methodology (§7.1).  Deterministic in the
  sequence number, so it is replayable after restore on an unchanged
  topology.
* :class:`Journal` + :class:`JournalSource` — a partitioned, replayable,
  Kafka-like log.  Journal partitions are mapped onto the cluster's state
  partitions, so offsets snapshot/restore through the same consistent-hash
  routing as keyed state — sources stay aligned with the partition table
  across topology changes (node loss / elastic scale-out).
"""

from __future__ import annotations

from typing import Any, Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .events import Event, EventBlock, Watermark, MAX_TIME, MIN_TIME
from .processor import Inbox, Processor
from .watermark import EventTimePolicy

#: max events per EventBlock a block-mode source emits in one burst
SOURCE_BLOCK_EVENTS = 4096
#: bursts smaller than this run the scalar loop — numpy per-call overhead
#: beats object churn only once a burst has real width (a paced source at
#: a modest rate produces 1-2 due events per slice; the saturation /
#: catch-up path produces thousands)
SCALAR_BURST_CUTOFF = 48


class ListSource(Processor):
    """Batch source: instance *i* of *N* emits ``items[i::N]``."""

    #: batch cursor only — a finite ListSource is not replayable mid-run;
    #: a restarted batch job re-reads ``items`` from the beginning
    EPHEMERAL_STATE = frozenset({"_pos"})

    def __init__(self, items: Sequence, ts_fn: Optional[Callable] = None,
                 key_fn: Optional[Callable] = None):
        self.items = items
        self.ts_fn = ts_fn or (lambda v: 0)
        self.key_fn = key_fn or (lambda v: None)
        self._pos = None

    def complete(self) -> bool:
        if self._pos is None:
            self._pos = self.ctx.global_index
        items, step = self.items, self.ctx.total_parallelism
        while self._pos < len(items):
            v = items[self._pos]
            if not self.outbox.offer(Event(self.ts_fn(v), self.key_fn(v), v)):
                return False
            self._pos += step
        return True


class PacedGeneratorSource(Processor):
    """Streaming source paced against the cluster clock.

    ``gen_fn(seq) -> (ts_ms, key, value)`` must be deterministic; ``rate``
    is the aggregate events/second across all instances.  Event time starts
    at 0 (ms) and the paper's latency clock is ``emit_wall_time -
    ideal_time``; the engine exposes ``ideal_time`` via the event timestamp
    so sinks can compute end-to-end latency.
    """

    #: policy/_gen_block are rebuilt by _setup() after a restore; the
    #: frontier trio (_frontiers/_old_total/_replay_horizon) is DERIVED
    #: from restored ("gen", p) entries — a replay filter consumed as the
    #:  new topology passes the old horizon, never itself snapshotted.
    #: The durable cursor is (_seq, _start), saved replicated to every
    #: partition.
    EPHEMERAL_STATE = frozenset({
        "policy", "_gen_block", "_frontiers", "_old_total",
        "_replay_horizon",
    })

    def __init__(self, gen_fn: Callable[[int], Tuple[int, Any, Any]],
                 rate: float, max_events: Optional[int] = None,
                 wm_policy: Optional[Callable[[], EventTimePolicy]] = None,
                 wm_stride: int = 1, wm_lag: int = 0,
                 block_size: Optional[int] = None):
        self.gen_fn = gen_fn
        self.rate = rate
        self.max_events = max_events
        #: ``wm_lag``: shorthand for a bounded-out-of-orderness policy —
        #: REQUIRED ( >= max skew) when gen_fn emits disordered timestamps,
        #: or events behind the watermark get dropped as late downstream
        self.policy_factory = wm_policy or (
            lambda lag=wm_lag: EventTimePolicy(lag=lag))
        self.wm_stride = wm_stride
        #: columnar emission: ``None`` = auto (use ``gen_fn.gen_block``
        #: when available and the watermark policy is vectorizable),
        #: ``0`` = force the scalar per-event path, ``n`` = block cap
        self.block_size = block_size
        self._seq = None           # next seq for THIS instance
        self._start = None         # absolute schedule anchor (cluster clock)
        self.policy = None
        self._gen_block = None
        #: exact-replay filter after a restore that changed parallelism:
        #: old residue class -> first seq NOT yet emitted by the previous
        #: topology (events below their class frontier are skipped)
        self._frontiers = None
        self._old_total = 0
        self._replay_horizon = 0

    def _setup(self):
        if self._seq is None:      # a restore may have set the offset
            self._seq = self.ctx.global_index
        if self._start is None:    # a restore re-anchors to the ORIGINAL t0
            self._start = self.ctx.clock.now()
        self.policy = self.policy_factory()
        # columnar mode: the generator must offer a vectorized form and
        # the watermark policy must be the plain bounded-lag policy with
        # min_step == 1, whose per-event decisions ("emit on every strict
        # rise of the running-max timestamp") vectorize exactly
        if self.block_size != 0:
            gb = getattr(self.gen_fn, "gen_block", None)
            if gb is not None and type(self.policy) is EventTimePolicy \
                    and self.policy.min_step == 1:
                self._gen_block = gb

    def complete(self) -> bool:
        if self.policy is None:
            self._setup()
        if self._gen_block is not None:
            done = self._complete_block()
            if done is not None:
                return done
            # fall through: burst too small for the columnar path
        step = self.ctx.total_parallelism
        rate = self.rate
        clock, start = self.ctx.clock, self._start
        gen = self.gen_fn
        outbox = self.outbox
        observe = self.policy.observe
        max_events, wm_stride = self.max_events, self.wm_stride
        seq = self._seq
        try:
            while True:
                if max_events is not None and seq >= max_events:
                    return True
                # emit every event already due at this instant in one run —
                # one clock read and one outbox extend per burst instead of
                # one offer per event
                overdue = (clock.now() - start) * rate - seq
                if overdue < 0:
                    return False
                budget = int(overdue) // step + 1
                room = outbox.space()
                if room <= 0:
                    return False
                if budget > room:
                    budget = room
                if max_events is not None:
                    left = (max_events - seq + step - 1) // step
                    if budget > left:
                        budget = left
                buf = []
                append = buf.append
                unthrottled = wm_stride == 1
                last_ts = None
                frontiers = self._frontiers
                while budget > 0 and len(buf) < room:
                    budget -= 1
                    if frontiers is not None:
                        if seq >= self._replay_horizon:
                            frontiers = self._frontiers = None
                        else:
                            front = frontiers.get(seq % self._old_total)
                            if front is not None and seq < front:
                                # already emitted by the pre-restart
                                # topology: exact replay skips it
                                seq += step
                                continue
                    ts, key, value = gen(seq)
                    append(Event(ts, key, value))
                    seq += step
                    if ts != last_ts:
                        # observe() only reacts to a changed timestamp, so
                        # runs of equal-ts events skip the call entirely
                        last_ts = ts
                        wm = observe(ts)
                        if wm is not None and (
                                unthrottled
                                or (seq // step) % wm_stride == 0):
                            append(Watermark(wm))
                outbox.extend(buf)
                if max_events is not None and seq >= max_events:
                    return True
        finally:
            self._seq = seq

    def _complete_block(self) -> Optional[bool]:
        """One columnar burst: generate up to ``block_size`` due events as
        ONE EventBlock, split it at the exact positions the scalar loop
        would have emitted watermarks, and extend the outbox with
        ``[block, wm, block, ...]``.

        Returns True when the stream is exhausted, False when no further
        progress is possible this slice, or None to delegate a small burst
        (< SCALAR_BURST_CUTOFF events) to the scalar loop — tiny bursts
        are cheaper as plain Events than as 2-row numpy columns.
        """
        step = self.ctx.total_parallelism
        seq = self._seq
        max_events = self.max_events
        if max_events is not None and seq >= max_events:
            return True
        overdue = (self.ctx.clock.now() - self._start) * self.rate - seq
        if overdue < 0:
            return False
        room = self.outbox.space()
        if room <= 0:
            return False
        n = int(overdue) // step + 1
        cap = self.block_size or SOURCE_BLOCK_EVENTS
        if n > cap:
            n = cap
        if max_events is not None:
            left = (max_events - seq + step - 1) // step
            if n > left:
                n = left
        # an explicitly small block_size still gets blocks; only the auto
        # mode trades tiny bursts back to the scalar loop
        if n < min(SCALAR_BURST_CUTOFF, cap):
            return None
        seqs = seq + step * np.arange(n, dtype=np.int64)
        self._seq = seq + n * step
        if self._frontiers is not None:
            # exact replay after a parallelism change: drop seqs the old
            # topology already emitted (same rule as the scalar loop)
            if seq >= self._replay_horizon:
                self._frontiers = None
            else:
                fr = np.full(self._old_total, MIN_TIME, dtype=np.int64)
                for cls, front in self._frontiers.items():
                    fr[cls] = front
                seqs = seqs[seqs >= fr[seqs % self._old_total]]
                if not len(seqs):
                    return False
                n = len(seqs)
        blk = self._gen_block(seqs)
        ts = blk.ts
        pol = self.policy
        lag = pol.lag
        # watermark fire positions: every strict rise of the running-max
        # timestamp (EventTimePolicy with min_step == 1), optionally
        # throttled by wm_stride — identical to observe() per event.  The
        # running max is seeded with the policy's carried-over top so a
        # disordered burst starting below it cannot falsely fire
        prev_top = pol._top_ts
        top = np.maximum.accumulate(ts)
        if prev_top > MIN_TIME:
            np.maximum(top, prev_top, out=top)
        rising = np.empty(n, dtype=bool)
        rising[0] = int(ts[0]) > prev_top
        np.greater(top[1:], top[:-1], out=rising[1:])
        if self.wm_stride > 1:
            rising &= ((seqs // step + 1) % self.wm_stride) == 0
        pos = np.nonzero(rising)[0]
        # bound the ITEM count this burst appends (each fire position
        # costs one block slice + one watermark): when fires are dense,
        # cut the burst at the last watermark that fits the outbox room
        # and return the remainder to the schedule — the outbox batch
        # limit stays a real per-slice latency bound, as in scalar mode
        max_w = max(1, (room - 1) // 2)
        if len(pos) > max_w:
            cut = int(pos[max_w - 1]) + 1
            self._seq = int(seqs[cut])
            blk = blk.slice(0, cut)
            top = top[:cut]
            pos = pos[:max_w]
            n = cut
        # policy state advances regardless of stride throttling, exactly
        # like the scalar loop's unconditional observe()
        new_top = int(top[-1])
        if new_top > pol._top_ts:
            pol._top_ts = new_top
            pol._last_wm = new_top - lag
        if not len(pos):
            items: List[Any] = [blk]
        else:
            items = []
            append = items.append
            tops = top[pos].tolist()
            prev = 0
            for k, p in enumerate(pos.tolist()):
                if p + 1 > prev:
                    append(blk.slice(prev, p + 1))
                append(Watermark(tops[k] - lag))
                prev = p + 1
            if prev < n:
                append(blk.slice(prev, n))
        self.outbox.extend(items)
        if max_events is not None and self._seq >= max_events:
            return True
        return False

    # replay support: each instance's frontier entry is replicated to
    # EVERY state partition (the snapshot store keys entries by
    # (vertex, instance, key), so replicas from different instances
    # coexist on one partition).  Each entry records which residue class
    # (old global index / old total parallelism) the frontier belongs
    # to: after a restart that CHANGED parallelism, the new instances
    # skip exactly the seqs the old topology already emitted — exact
    # replay, not at-least-once residue-gap duplication.  Replicating to
    # all partitions (instead of only the owned ones) is what makes the
    # skip rule sound: a new instance owns only a slice of the
    # partitions, and under owned-only placement it could miss some old
    # instances' entries entirely — its ``base`` then started above an
    # unseen class's frontier and the seqs in between were silently
    # LOST.  With full replication every restored instance reconstructs
    # the complete frontier vector from any single partition it owns.
    def save_to_snapshot(self) -> bool:
        n = self.ctx.partition_count
        pids = range(n) if n else self.ctx.partition_ids
        for p in pids:
            self.outbox.offer_to_snapshot(
                ("gen", p),
                (self._seq, self._start, self.ctx.global_index,
                 self.ctx.total_parallelism))
        return True

    def snapshot_partition(self, skey):
        if isinstance(skey, tuple) and skey[0] == "gen":
            return skey[1]
        return None

    def restore_from_snapshot(self, items) -> None:
        seqs, starts = [], []
        frontiers = {}
        old_total = 0
        for (tag, _p), val in items:
            if tag != "gen" or not val:
                continue
            if val[0] is not None:
                seqs.append(val[0])
            if val[1] is not None:
                starts.append(val[1])
            if len(val) >= 4 and val[2] is not None and val[0] is not None:
                cls, tot = val[2], val[3]
                old_total = max(old_total, tot)
                if frontiers.get(cls, MIN_TIME) < val[0]:
                    frontiers[cls] = val[0]
        if seqs:
            base = min(seqs)
            total = self.ctx.total_parallelism
            idx = self.ctx.global_index
            # smallest seq >= base in this instance's residue class
            self._seq = base + ((idx - base) % total)
            if frontiers and old_total:
                self._frontiers = frontiers
                self._old_total = old_total
                self._replay_horizon = max(frontiers.values())
        if starts:
            # the cluster clock is monotonic across restarts: anchoring to
            # the original t0 keeps the ideal schedule (and therefore the
            # measured latency of replayed events) honest
            self._start = min(starts)


class Journal:
    """Shared, partitioned, replayable event log (stands in for Kafka)."""

    def __init__(self, n_partitions: int = 16):
        self.n_partitions = n_partitions
        self.partitions: List[List[Tuple[int, Any, Any]]] = [
            [] for _ in range(n_partitions)]

    def append(self, ts: int, key, value) -> None:
        self.partitions[hash(key) % self.n_partitions].append((ts, key, value))

    def extend(self, records: Iterable[Tuple[int, Any, Any]]) -> None:
        for ts, key, value in records:
            self.append(ts, key, value)

    def total(self) -> int:
        return sum(len(p) for p in self.partitions)


class JournalSource(Processor):
    """Replayable source over a :class:`Journal`.

    Journal partition *jp* is read by the instance that owns state
    partition *jp* (``ctx.partition_ids``), and its offset snapshots under
    partition *jp* — after a topology change, the new owner of *jp* finds
    exactly the offset it needs (paper §4.5 "replayable source").
    ``finite=True`` emits DONE at the end of the journal (batch replay);
    otherwise the source idles waiting for more data.
    """

    #: the durable replay cursor is _offsets (saved per journal
    #: partition); the watermark policy is rebuilt by _setup(), pacing
    #: (_start/_emitted) re-anchors to the cluster clock after a restart,
    #: and _idle_wm_sent re-derives from the (restored) assignment
    EPHEMERAL_STATE = frozenset({
        "policy", "_start", "_emitted", "_idle_wm_sent",
    })

    def __init__(self, journal: Journal, finite: bool = True,
                 wm_policy: Optional[Callable[[], EventTimePolicy]] = None,
                 rate: Optional[float] = None, wm_lag: int = 0):
        self.journal = journal
        self.finite = finite
        #: ``wm_lag``: bounded out-of-orderness allowance for disordered
        #: journals (see PacedGeneratorSource)
        self.policy_factory = wm_policy or (
            lambda lag=wm_lag: EventTimePolicy(lag=lag))
        #: events/second per instance, paced against the cluster clock
        #: (None = drain as fast as possible)
        self.rate = rate
        self._offsets = None       # jp -> next index
        self.policy = None
        self._idle_wm_sent = False
        self._emitted = 0
        self._start = None

    def _setup(self):
        self._offsets = {
            jp: 0 for jp in self.ctx.partition_ids
            if jp < self.journal.n_partitions}
        self.policy = self.policy_factory()
        self._start = self.ctx.clock.now()
        self._emitted = 0

    def _due_budget(self) -> int:
        if self.rate is None:
            return 1 << 30
        due = int((self.ctx.clock.now() - self._start) * self.rate)
        return max(0, due - self._emitted)

    def complete(self) -> bool:
        if self._offsets is None:
            self._setup()
        if not self._offsets:
            # no journal partitions assigned: don't hold back the coalesced
            # watermark downstream
            if not self._idle_wm_sent:
                if self.outbox.offer(Watermark(MAX_TIME)):
                    self._idle_wm_sent = True
            return self.finite
        # merge-read across partitions in event-time order: offsets may
        # differ per partition (replay!), and reading one partition to
        # exhaustion before the next would emit watermarks that make the
        # other partitions' events late (premature window emission).
        budget = self._due_budget()
        parts = self.journal.partitions
        while budget > 0:
            best_jp, best_ts = -1, None
            for jp, off in self._offsets.items():
                part = parts[jp]
                if off < len(part):
                    ts = part[off][0]
                    if best_ts is None or ts < best_ts:
                        best_jp, best_ts = jp, ts
            if best_jp < 0:
                return self.finite  # all partitions exhausted
            off = self._offsets[best_jp]
            ts, key, value = parts[best_jp][off]
            if not self.outbox.offer(Event(ts, key, value)):
                return False
            self._offsets[best_jp] = off + 1
            budget -= 1
            self._emitted += 1
            wm = self.policy.observe(ts)
            if wm is not None:
                if not self.outbox.offer(Watermark(wm)):
                    return False
        return False

    # -- replay protocol --------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        for jp, off in self._offsets.items():
            self.outbox.offer_to_snapshot(("off", jp), off)
        return True

    def snapshot_partition(self, skey) -> Optional[int]:
        if isinstance(skey, tuple) and skey[0] == "off":
            return skey[1]
        return None

    def restore_from_snapshot(self, items) -> None:
        if self._offsets is None:
            self._setup()
        for (tag, jp), off in items:
            if tag == "off" and jp in self._offsets:
                self._offsets[jp] = max(self._offsets[jp], off)


class CollectorSink(Processor):
    """Collects events into a shared list; records arrival wall time for
    latency measurement: appends ``(wall_now, event)``."""

    #: the caller owns ``out`` (test/benchmark observability buffer);
    #: results are judged by the harness, not restored into the job
    EPHEMERAL_STATE = frozenset({"out"})

    def __init__(self, out: list, with_time: bool = False):
        self.out = out
        self.with_time = with_time

    # jetlint: disable=hot-path-unbounded-growth -- `out` is the harness's results buffer, bounded by the finite test/benchmark input and read only after the job ends
    def process(self, ordinal: int, inbox: Inbox) -> None:
        out, with_time = self.out, self.with_time
        if with_time:
            now = self.ctx.clock.now
            out.extend((now(), item) for item in inbox)
        else:
            out.extend(inbox)
        inbox.clear()
