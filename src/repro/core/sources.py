"""Sources and sinks.

* :class:`ListSource` — batch source over a fixed collection.
* :class:`PacedGeneratorSource` — streaming source that emits synthetic
  events on an ideal schedule (``rate`` events/second of *cluster clock*);
  any delay in actually emitting an event counts against the measured
  latency, exactly the paper's methodology (§7.1).  Deterministic in the
  sequence number, so it is replayable after restore on an unchanged
  topology.
* :class:`Journal` + :class:`JournalSource` — a partitioned, replayable,
  Kafka-like log.  Journal partitions are mapped onto the cluster's state
  partitions, so offsets snapshot/restore through the same consistent-hash
  routing as keyed state — sources stay aligned with the partition table
  across topology changes (node loss / elastic scale-out).
"""

from __future__ import annotations

from typing import Any, Callable, Iterable, List, Optional, Sequence, Tuple

from .events import Event, Watermark, MAX_TIME
from .processor import Inbox, Processor
from .watermark import EventTimePolicy


class ListSource(Processor):
    """Batch source: instance *i* of *N* emits ``items[i::N]``."""

    def __init__(self, items: Sequence, ts_fn: Optional[Callable] = None,
                 key_fn: Optional[Callable] = None):
        self.items = items
        self.ts_fn = ts_fn or (lambda v: 0)
        self.key_fn = key_fn or (lambda v: None)
        self._pos = None

    def complete(self) -> bool:
        if self._pos is None:
            self._pos = self.ctx.global_index
        items, step = self.items, self.ctx.total_parallelism
        while self._pos < len(items):
            v = items[self._pos]
            if not self.outbox.offer(Event(self.ts_fn(v), self.key_fn(v), v)):
                return False
            self._pos += step
        return True


class PacedGeneratorSource(Processor):
    """Streaming source paced against the cluster clock.

    ``gen_fn(seq) -> (ts_ms, key, value)`` must be deterministic; ``rate``
    is the aggregate events/second across all instances.  Event time starts
    at 0 (ms) and the paper's latency clock is ``emit_wall_time -
    ideal_time``; the engine exposes ``ideal_time`` via the event timestamp
    so sinks can compute end-to-end latency.
    """

    def __init__(self, gen_fn: Callable[[int], Tuple[int, Any, Any]],
                 rate: float, max_events: Optional[int] = None,
                 wm_policy: Optional[Callable[[], EventTimePolicy]] = None,
                 wm_stride: int = 1, wm_lag: int = 0):
        self.gen_fn = gen_fn
        self.rate = rate
        self.max_events = max_events
        #: ``wm_lag``: shorthand for a bounded-out-of-orderness policy —
        #: REQUIRED ( >= max skew) when gen_fn emits disordered timestamps,
        #: or events behind the watermark get dropped as late downstream
        self.policy_factory = wm_policy or (
            lambda lag=wm_lag: EventTimePolicy(lag=lag))
        self.wm_stride = wm_stride
        self._seq = None           # next seq for THIS instance
        self._start = None         # absolute schedule anchor (cluster clock)
        self.policy = None

    def _setup(self):
        if self._seq is None:      # a restore may have set the offset
            self._seq = self.ctx.global_index
        if self._start is None:    # a restore re-anchors to the ORIGINAL t0
            self._start = self.ctx.clock.now()
        self.policy = self.policy_factory()

    def complete(self) -> bool:
        if self.policy is None:
            self._setup()
        step = self.ctx.total_parallelism
        rate = self.rate
        clock, start = self.ctx.clock, self._start
        gen = self.gen_fn
        outbox = self.outbox
        observe = self.policy.observe
        max_events, wm_stride = self.max_events, self.wm_stride
        seq = self._seq
        try:
            while True:
                if max_events is not None and seq >= max_events:
                    return True
                # emit every event already due at this instant in one run —
                # one clock read and one outbox extend per burst instead of
                # one offer per event
                overdue = (clock.now() - start) * rate - seq
                if overdue < 0:
                    return False
                budget = int(overdue) // step + 1
                room = outbox.space()
                if room <= 0:
                    return False
                if budget > room:
                    budget = room
                if max_events is not None:
                    left = (max_events - seq + step - 1) // step
                    if budget > left:
                        budget = left
                buf = []
                append = buf.append
                unthrottled = wm_stride == 1
                last_ts = None
                while budget > 0 and len(buf) < room:
                    budget -= 1
                    ts, key, value = gen(seq)
                    append(Event(ts, key, value))
                    seq += step
                    if ts != last_ts:
                        # observe() only reacts to a changed timestamp, so
                        # runs of equal-ts events skip the call entirely
                        last_ts = ts
                        wm = observe(ts)
                        if wm is not None and (
                                unthrottled
                                or (seq // step) % wm_stride == 0):
                            append(Watermark(wm))
                outbox.extend(buf)
                if max_events is not None and seq >= max_events:
                    return True
        finally:
            self._seq = seq

    # replay support: offsets ride on the owned state partitions (like
    # JournalSource) so any post-restart topology finds them.  The restart
    # resumes from the MINIMUM saved sequence — exactly-once for the
    # generator's own state, at-least-once for events in the residue gap
    # when parallelism changed (documented; the journal source is the
    # exactly-once-replay path).
    def save_to_snapshot(self) -> bool:
        for p in self.ctx.partition_ids:
            self.outbox.offer_to_snapshot(("gen", p),
                                          (self._seq, self._start))
        return True

    def snapshot_partition(self, skey):
        if isinstance(skey, tuple) and skey[0] == "gen":
            return skey[1]
        return None

    def restore_from_snapshot(self, items) -> None:
        seqs = [val[0] for (tag, _p), val in items
                if tag == "gen" and val and val[0] is not None]
        starts = [val[1] for (tag, _p), val in items
                  if tag == "gen" and val and val[1] is not None]
        if seqs:
            base = min(seqs)
            total = self.ctx.total_parallelism
            idx = self.ctx.global_index
            # smallest seq >= base in this instance's residue class
            self._seq = base + ((idx - base) % total)
        if starts:
            # the cluster clock is monotonic across restarts: anchoring to
            # the original t0 keeps the ideal schedule (and therefore the
            # measured latency of replayed events) honest
            self._start = min(starts)


class Journal:
    """Shared, partitioned, replayable event log (stands in for Kafka)."""

    def __init__(self, n_partitions: int = 16):
        self.n_partitions = n_partitions
        self.partitions: List[List[Tuple[int, Any, Any]]] = [
            [] for _ in range(n_partitions)]

    def append(self, ts: int, key, value) -> None:
        self.partitions[hash(key) % self.n_partitions].append((ts, key, value))

    def extend(self, records: Iterable[Tuple[int, Any, Any]]) -> None:
        for ts, key, value in records:
            self.append(ts, key, value)

    def total(self) -> int:
        return sum(len(p) for p in self.partitions)


class JournalSource(Processor):
    """Replayable source over a :class:`Journal`.

    Journal partition *jp* is read by the instance that owns state
    partition *jp* (``ctx.partition_ids``), and its offset snapshots under
    partition *jp* — after a topology change, the new owner of *jp* finds
    exactly the offset it needs (paper §4.5 "replayable source").
    ``finite=True`` emits DONE at the end of the journal (batch replay);
    otherwise the source idles waiting for more data.
    """

    def __init__(self, journal: Journal, finite: bool = True,
                 wm_policy: Optional[Callable[[], EventTimePolicy]] = None,
                 rate: Optional[float] = None, wm_lag: int = 0):
        self.journal = journal
        self.finite = finite
        #: ``wm_lag``: bounded out-of-orderness allowance for disordered
        #: journals (see PacedGeneratorSource)
        self.policy_factory = wm_policy or (
            lambda lag=wm_lag: EventTimePolicy(lag=lag))
        #: events/second per instance, paced against the cluster clock
        #: (None = drain as fast as possible)
        self.rate = rate
        self._offsets = None       # jp -> next index
        self.policy = None
        self._idle_wm_sent = False
        self._emitted = 0
        self._start = None

    def _setup(self):
        self._offsets = {
            jp: 0 for jp in self.ctx.partition_ids
            if jp < self.journal.n_partitions}
        self.policy = self.policy_factory()
        self._start = self.ctx.clock.now()
        self._emitted = 0

    def _due_budget(self) -> int:
        if self.rate is None:
            return 1 << 30
        due = int((self.ctx.clock.now() - self._start) * self.rate)
        return max(0, due - self._emitted)

    def complete(self) -> bool:
        if self._offsets is None:
            self._setup()
        if not self._offsets:
            # no journal partitions assigned: don't hold back the coalesced
            # watermark downstream
            if not self._idle_wm_sent:
                if self.outbox.offer(Watermark(MAX_TIME)):
                    self._idle_wm_sent = True
            return self.finite
        # merge-read across partitions in event-time order: offsets may
        # differ per partition (replay!), and reading one partition to
        # exhaustion before the next would emit watermarks that make the
        # other partitions' events late (premature window emission).
        budget = self._due_budget()
        parts = self.journal.partitions
        while budget > 0:
            best_jp, best_ts = -1, None
            for jp, off in self._offsets.items():
                part = parts[jp]
                if off < len(part):
                    ts = part[off][0]
                    if best_ts is None or ts < best_ts:
                        best_jp, best_ts = jp, ts
            if best_jp < 0:
                return self.finite  # all partitions exhausted
            off = self._offsets[best_jp]
            ts, key, value = parts[best_jp][off]
            if not self.outbox.offer(Event(ts, key, value)):
                return False
            self._offsets[best_jp] = off + 1
            budget -= 1
            self._emitted += 1
            wm = self.policy.observe(ts)
            if wm is not None:
                if not self.outbox.offer(Watermark(wm)):
                    return False
        return False

    # -- replay protocol --------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        for jp, off in self._offsets.items():
            self.outbox.offer_to_snapshot(("off", jp), off)
        return True

    def snapshot_partition(self, skey) -> Optional[int]:
        if isinstance(skey, tuple) and skey[0] == "off":
            return skey[1]
        return None

    def restore_from_snapshot(self, items) -> None:
        if self._offsets is None:
            self._setup()
        for (tag, jp), off in items:
            if tag == "off" and jp in self._offsets:
                self._offsets[jp] = max(self._offsets[jp], off)


class CollectorSink(Processor):
    """Collects events into a shared list; records arrival wall time for
    latency measurement: appends ``(wall_now, event)``."""

    def __init__(self, out: list, with_time: bool = False):
        self.out = out
        self.with_time = with_time

    def process(self, ordinal: int, inbox: Inbox) -> None:
        out, with_time = self.out, self.with_time
        if with_time:
            now = self.ctx.clock.now
            out.extend((now(), item) for item in inbox)
        else:
            out.extend(inbox)
        inbox.clear()
