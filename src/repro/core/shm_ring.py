"""Shared-memory SPSC ring buffer: the cross-process edge transport.

Under the multiprocess backend every edge whose producer and consumer
tasklets live in different worker processes is a :class:`ShmRing` — one
``multiprocessing.shared_memory`` segment holding a byte-level ring of
length-prefixed records.  EventBlocks travel as raw column slabs (the
:meth:`EventBlock.to_wire` format: ts/key/value int64/float64 bytes copied
straight out of the numpy buffers), while watermarks, barriers, DONE and
scalar stragglers ride a small tagged control lane — so the columnar hot
path never pays per-row pickling.

Memory model
============

The ring is strictly SPSC: exactly one producer process writes records and
advances ``tail``; exactly one consumer process reads records and advances
``head``.  Both cursors are monotonically increasing byte offsets stored as
aligned 8-byte little-endian integers in the segment header.  On x86-64
(TSO) an aligned 8-byte store is atomic and stores are not reordered, and
CPython's ``struct.pack_into`` performs the payload stores before the
cursor store crosses the interpreter boundary — the same publication
pattern every pure-Python shm ring uses.  ``offer``/``poll`` never block;
``offer`` returning ``False`` is the backpressure signal, exactly the
:class:`~repro.core.queues.SPSCQueue` contract.

This argument is machine-checked twice over (ROADMAP "Machine-checked
contracts"): statically, jetlint's ``ring-role-violation`` pass
(``repro.analysis.ring_roles``) verifies the SPSC role split — producer
methods own ``tail`` and the bytes they stage, consumer methods own
``head``, no attribute or header word has two writing sides, and no
process role holds both ends of a ring; dynamically, the ring sanitizer
(``python -m repro.analysis.ring_sanitizer``) exhaustively interleaves
the exact ``offer`` mutation order modeled below (pad header, record
header, payload, ``msgs_in``, ``tail``) against atomic polls with a
producer crash injected at every step boundary, asserting no
torn/lost/duplicated record ever becomes observable.

Record layout
=============

``[u32 total_len][u8 tag][payload]`` — ``total_len`` includes the 5-byte
header.  Records never wrap: when the contiguous space to the physical end
of the data region cannot hold a record, a PAD record (or a bare tail gap
of < 5 bytes) fills it and the record starts at offset 0, keeping every
payload contiguous for ``np.frombuffer``.  A record larger than the data
region is a hard error — size rings to a few multiples of the largest
block (the default 1 MiB holds ~6 full 4096-row NEXMark blocks).

``has_room_for(item)`` serializes the item once, caches the encoding, and
answers whether an ``offer`` of that item is guaranteed to succeed — the
all-or-nothing admission primitive EventBlock routing needs on an edge
whose capacity is bytes, not slots.

Leak guards
===========

A shm segment outlives the process that forgot to unlink it, so rings
created here carry three layers of protection:

* every segment is named ``jetring_<creator-pid>_<nonce>``
  (:data:`RING_NAME_PREFIX`), so leaked segments are identifiable;
* the creating :class:`ShmRing` registers a ``weakref.finalize`` (which
  also runs at interpreter exit) that unlinks the segment if normal
  teardown never did; the callback is guarded by the creator's pid —
  worker processes inherit the object via fork and must NOT unlink a
  segment the coordinator is still using when they exit;
* :func:`sweep_leaked_rings` removes any ``jetring_*`` segment left on
  the host by previous crashed runs (a SIGKILL'd coordinator gets no
  atexit), for harnesses/CI to call up front.
"""

from __future__ import annotations

import os
import pickle
import secrets
import struct
import weakref
from multiprocessing import shared_memory
from typing import Any, List, Optional, Tuple

from .events import (Barrier, DONE, DoneItem, Event, EventBlock, LateEvent,
                     Watermark)

#: record tags
TAG_PICKLE = 0          # arbitrary item (pickle payload)
TAG_BLOCK = 1           # EventBlock.to_wire payload
TAG_EVENT = 2           # Event with int ts/key and int-or-float value
TAG_WATERMARK = 3       # int64 ts
TAG_BARRIER = 4         # int64 snapshot_id + u8 terminal
TAG_DONE = 5            # empty payload
TAG_PAD = 255           # fill to the physical end; carries no item

_HDR_BYTES = 64         # segment header: head @0, tail @8, msgs @16/@24
_REC = struct.Struct("<IB")
_Q = struct.Struct("<q")
_EVT_I = struct.Struct("<qqqB")     # ts, key, int value
_EVT_F = struct.Struct("<qqdB")     # ts, key, float value
_BARRIER = struct.Struct("<qB")
_PICKLE_PROTO = pickle.HIGHEST_PROTOCOL

DEFAULT_RING_BYTES = 1 << 20

#: shm segment name prefix for every ring created by this module
RING_NAME_PREFIX = "jetring_"
_SHM_DIR = "/dev/shm"


def _unlink_guarded(name: str, creator_pid: int) -> None:
    """Finalizer body: unlink ``name`` only in the process that created
    it.  Children inherit the creator's ShmRing (and its finalizer) via
    fork; a child exiting mid-job must not yank the segment out from
    under the coordinator."""
    if os.getpid() != creator_pid:
        return
    try:
        seg = shared_memory.SharedMemory(name=name)
    except (FileNotFoundError, OSError):
        return      # already unlinked by normal teardown
    try:
        seg.unlink()
    except (FileNotFoundError, OSError):  # pragma: no cover - racing exit
        pass
    finally:
        seg.close()


def sweep_leaked_rings() -> List[str]:
    """Remove ``jetring_*`` segments left behind by previous crashed runs
    (a SIGKILL'd process gets neither atexit nor finalizers).  Returns
    the names removed.  Call this up front in long-running harnesses —
    never mid-job, when live rings with the prefix exist."""
    swept: List[str] = []
    try:
        names = os.listdir(_SHM_DIR)
    except OSError:  # pragma: no cover - non-Linux / no shm mount
        return swept
    for fn in names:
        if fn.startswith(RING_NAME_PREFIX):
            try:
                os.unlink(os.path.join(_SHM_DIR, fn))
                swept.append(fn)
            except OSError:  # pragma: no cover - racing teardown
                pass
    return swept


def _encode(item) -> Tuple[int, bytes]:
    cls = item.__class__
    if cls is EventBlock:
        return TAG_BLOCK, item.to_wire()
    if cls is Event:
        ts, key, value = item.ts, item.key, item.value
        if type(ts) is int and type(key) is int:
            if type(value) is int and -(2**62) < value < 2**62:
                return TAG_EVENT, _EVT_I.pack(ts, key, value, 0)
            if type(value) is float:
                return TAG_EVENT, _EVT_F.pack(ts, key, value, 1)
    if cls is Watermark:
        return TAG_WATERMARK, _Q.pack(item.ts)
    if cls is Barrier:
        return TAG_BARRIER, _BARRIER.pack(item.snapshot_id,
                                          1 if item.terminal else 0)
    if cls is DoneItem:
        return TAG_DONE, b""
    return TAG_PICKLE, pickle.dumps(item, protocol=_PICKLE_PROTO)


def _decode(tag: int, payload) -> Any:
    if tag == TAG_BLOCK:
        return EventBlock.from_wire(payload)
    if tag == TAG_EVENT:
        if payload[-1]:
            ts, key, value, _ = _EVT_F.unpack(payload)
        else:
            ts, key, value, _ = _EVT_I.unpack(payload)
        return Event(ts, key, value)
    if tag == TAG_WATERMARK:
        return Watermark(_Q.unpack(payload)[0])
    if tag == TAG_BARRIER:
        sid, terminal = _BARRIER.unpack(payload)
        return Barrier(sid, bool(terminal))
    if tag == TAG_DONE:
        return DONE
    return pickle.loads(payload)


class ShmRing:
    """Fixed-capacity shared-memory SPSC ring with the SPSCQueue surface."""

    __slots__ = ("_shm", "_cap", "_mv", "_data", "_created", "_staged",
                 "_peeked", "_finalizer", "name", "__weakref__")

    def __init__(self, capacity_bytes: int = DEFAULT_RING_BYTES,
                 name: Optional[str] = None, create: bool = True):
        self._finalizer = None
        if create:
            if name is None:
                name = (f"{RING_NAME_PREFIX}{os.getpid()}_"
                        f"{secrets.token_hex(4)}")
            self._shm = shared_memory.SharedMemory(
                name=name, create=True, size=_HDR_BYTES + capacity_bytes)
            self._shm.buf[:_HDR_BYTES] = b"\x00" * _HDR_BYTES
            # leak guard: runs at GC or interpreter exit if stop_execution
            # never unlinked this ring; pid-guarded against forked children
            self._finalizer = weakref.finalize(
                self, _unlink_guarded, self._shm.name, os.getpid())
        else:
            self._shm = shared_memory.SharedMemory(name=name)
        self.name = self._shm.name
        self._created = create
        self._cap = self._shm.size - _HDR_BYTES
        self._mv = self._shm.buf
        self._data = self._shm.buf[_HDR_BYTES:]
        #: producer-side staged encoding: (item_id, tag, payload)
        self._staged: Optional[Tuple[int, int, bytes]] = None
        #: consumer-side lookahead for peek()
        self._peeked = None

    # -- header cursors ------------------------------------------------------
    def _head(self) -> int:
        return _Q.unpack_from(self._mv, 0)[0]

    def _tail(self) -> int:
        return _Q.unpack_from(self._mv, 8)[0]

    def _set_head(self, v: int) -> None:
        _Q.pack_into(self._mv, 0, v)

    def _set_tail(self, v: int) -> None:
        _Q.pack_into(self._mv, 8, v)

    def _msgs_in(self) -> int:
        return _Q.unpack_from(self._mv, 16)[0]

    def _msgs_out(self) -> int:
        return _Q.unpack_from(self._mv, 24)[0]

    # -- producer side -------------------------------------------------------
    def _stage(self, item) -> Tuple[int, bytes]:
        staged = self._staged
        if staged is not None and staged[0] == id(item):
            return staged[1], staged[2]
        tag, payload = _encode(item)
        self._staged = (id(item), tag, payload)
        return tag, payload

    def _space_needed(self, tail: int, rec: int) -> int:
        to_end = self._cap - (tail % self._cap)
        return rec if rec <= to_end else to_end + rec

    def has_room_for(self, item) -> bool:
        """True when an immediate ``offer(item)`` is guaranteed to succeed.
        Serializes (and caches) the item; in SPSC use free space only grows
        between this call and the offer, so the answer cannot go stale."""
        tag, payload = self._stage(item)
        rec = _REC.size + len(payload)
        if rec > self._cap:
            raise ValueError(
                f"item of {rec} bytes exceeds ring capacity {self._cap}")
        free = self._cap - (self._tail() - self._head())
        return self._space_needed(self._tail(), rec) <= free

    def offer(self, item) -> bool:
        """Enqueue ``item``; returns False (backpressure) when full."""
        tag, payload = self._stage(item)
        rec = _REC.size + len(payload)
        if rec > self._cap:
            raise ValueError(
                f"item of {rec} bytes exceeds ring capacity {self._cap}")
        tail = self._tail()
        free = self._cap - (tail - self._head())
        if self._space_needed(tail, rec) > free:
            return False
        cap, data = self._cap, self._data
        idx = tail % cap
        to_end = cap - idx
        if rec > to_end:
            # close out the physical tail with a PAD record (or leave the
            # < 5-byte remainder implicit) and restart at offset 0
            if to_end >= _REC.size:
                _REC.pack_into(data, idx, to_end, TAG_PAD)
            tail += to_end
            idx = 0
        _REC.pack_into(data, idx, rec, tag)
        data[idx + _REC.size:idx + rec] = payload
        _Q.pack_into(self._mv, 16, self._msgs_in() + 1)
        self._set_tail(tail + rec)
        self._staged = None
        return True

    def offer_many(self, items: List[Any], start: int = 0,
                   end: Optional[int] = None) -> int:
        n = len(items) if end is None else end
        i = start
        while i < n and self.offer(items[i]):
            i += 1
        return i - start

    def remaining_capacity(self) -> int:
        """Approximate free *item* slots (free bytes over a nominal record
        size).  Use :meth:`has_room_for` for admission decisions — byte
        capacity does not translate exactly into slots."""
        free = self._cap - (self._tail() - self._head())
        return free // 256

    # -- consumer side -------------------------------------------------------
    def _read_record(self, advance: bool):
        """Next (item, consumed_bytes) or None; skips PAD records."""
        head = self._head()
        cap, data = self._cap, self._data
        while True:
            if head == self._tail():
                return None
            idx = head % cap
            to_end = cap - idx
            if to_end < _REC.size:
                head += to_end          # implicit pad at the physical tail
                continue
            rec, tag = _REC.unpack_from(data, idx)
            if tag == TAG_PAD:
                head += rec
                continue
            item = _decode(tag, bytes(data[idx + _REC.size:idx + rec]))
            if advance:
                _Q.pack_into(self._mv, 24, self._msgs_out() + 1)
                self._set_head(head + rec)
            return item, head + rec

    def poll(self) -> Optional[Any]:
        if self._peeked is not None:
            item = self._peeked[0]
            self._peeked = None
            return item
        got = self._read_record(advance=True)
        return got[0] if got is not None else None

    def peek(self) -> Optional[Any]:
        if self._peeked is None:
            got = self._read_record(advance=True)
            if got is None:
                return None
            self._peeked = got
        return self._peeked[0]

    def poll_many(self, limit: int) -> List[Any]:
        out = []
        while len(out) < limit:
            item = self.poll()
            if item is None:
                break
            out.append(item)
        return out

    def poll_prefix(self, limit: int,
                    explode_blocks: bool = False) -> Tuple[List[Any], Any]:
        """Batched control-aware drain; see ``SPSCQueue.poll_prefix``:
        dequeues the leading run of data items (a block counts as one slot)
        plus at most one trailing control item."""
        events: List[Any] = []
        ctrl = None
        n = 0
        while n < limit:
            item = self.poll()
            if item is None:
                break
            n += 1
            cls = item.__class__
            if cls is EventBlock:
                if explode_blocks:
                    events.extend(item.to_events())
                else:
                    events.append(item)
            elif cls is Event or isinstance(item, Event):
                events.append(item)
            else:
                ctrl = item
                break
        return events, ctrl

    def drain_to(self, sink: list, limit: int) -> int:
        items = self.poll_many(limit)
        sink.extend(items)
        return len(items)

    # -- shared --------------------------------------------------------------
    def __len__(self) -> int:
        n = self._msgs_in() - self._msgs_out()
        return n + (1 if self._peeked is not None else 0)

    @property
    def capacity(self) -> int:
        return self._cap

    def is_empty(self) -> bool:
        return len(self) == 0

    def is_full(self) -> bool:
        return self._cap - (self._tail() - self._head()) < _REC.size + 1

    # -- lifecycle -----------------------------------------------------------
    def attach(self) -> "ShmRing":
        """Open the same segment by name (the other process's end)."""
        return ShmRing(name=self.name, create=False)

    def close(self) -> None:
        self._peeked = None
        self._data.release()
        try:
            self._shm.close()
        except BufferError:  # pragma: no cover - interpreter-version quirk
            pass

    def unlink(self) -> None:
        if self._finalizer is not None:
            self._finalizer.detach()    # normal teardown; guard not needed
        try:
            self._shm.unlink()
        except FileNotFoundError:  # pragma: no cover - already unlinked
            pass

    def __reduce__(self):  # pragma: no cover - defensive
        raise TypeError("ShmRing is shared by inheritance (fork), not pickle")
