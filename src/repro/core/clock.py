"""Clock abstraction: wall clock for real benchmarks, virtual clock for
deterministic tests and calibrated scale-out simulation."""

from __future__ import annotations

import time


class Clock:
    def now(self) -> float:
        raise NotImplementedError


class WallClock(Clock):
    __slots__ = ()

    def now(self) -> float:
        return time.monotonic()


class VirtualClock(Clock):
    """Manually advanced clock; the engine advances it when idle so that
    time-driven behaviour (snapshot intervals, ack cadence, pacing sources)
    runs deterministically and faster than real time."""

    __slots__ = ("_t", "auto_step")

    def __init__(self, start: float = 0.0, auto_step: float = 1e-4):
        self._t = start
        #: seconds added per idle engine iteration
        self.auto_step = auto_step

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self._t += dt
