"""Single-producer single-consumer bounded queues.

Jet connects each pair of communicating tasklets with a wait-free SPSC ring
buffer; a full queue is the local backpressure signal (the producer backs off
from its cooperative thread instead of blocking).  Inside this cooperative
single-core runtime the queues are stepped by one driver thread, so plain
index arithmetic *is* wait-free; the API surface (offer/poll never block,
``offer`` returning ``False`` == backpressure) is preserved exactly.
"""

from __future__ import annotations

from typing import Any, List, Optional


class SPSCQueue:
    """Fixed-capacity ring buffer with non-blocking offer/poll."""

    __slots__ = ("_buf", "_cap", "_head", "_tail")

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self._cap = capacity
        self._buf: List[Any] = [None] * capacity
        self._head = 0  # next slot to poll
        self._tail = 0  # next slot to fill

    # -- producer side -----------------------------------------------------
    def offer(self, item) -> bool:
        """Enqueue ``item``; returns False (backpressure) when full."""
        if self._tail - self._head == self._cap:
            return False
        self._buf[self._tail % self._cap] = item
        self._tail += 1
        return True

    def remaining_capacity(self) -> int:
        return self._cap - (self._tail - self._head)

    # -- consumer side -----------------------------------------------------
    def poll(self) -> Optional[Any]:
        """Dequeue one item or return None when empty."""
        if self._head == self._tail:
            return None
        idx = self._head % self._cap
        item = self._buf[idx]
        self._buf[idx] = None
        self._head += 1
        return item

    def peek(self) -> Optional[Any]:
        if self._head == self._tail:
            return None
        return self._buf[self._head % self._cap]

    def drain_to(self, sink: list, limit: int) -> int:
        """Move up to ``limit`` items into ``sink`` (a list). Returns count."""
        n = min(limit, self._tail - self._head)
        buf, cap, head = self._buf, self._cap, self._head
        for i in range(n):
            idx = (head + i) % cap
            sink.append(buf[idx])
            buf[idx] = None
        self._head = head + n
        return n

    # -- shared -------------------------------------------------------------
    def __len__(self) -> int:
        return self._tail - self._head

    @property
    def capacity(self) -> int:
        return self._cap

    def is_empty(self) -> bool:
        return self._head == self._tail

    def is_full(self) -> bool:
        return self._tail - self._head == self._cap
