"""Single-producer single-consumer bounded queues.

Jet connects each pair of communicating tasklets with a wait-free SPSC ring
buffer; a full queue is the local backpressure signal (the producer backs off
from its cooperative thread instead of blocking).  When both tasklets live in
one process (the in-process backend, or two tasklets on the same worker under
the multiprocess backend) the queues are stepped by one driver thread, so
plain index arithmetic *is* wait-free; the API surface (offer/poll never
block, ``offer`` returning ``False`` == backpressure) is preserved exactly.

This class also defines the *transport contract* every edge implementation
(:class:`SPSCQueue`, :class:`~repro.core.backpressure.NetworkLink`,
:class:`~repro.core.shm_ring.ShmRing`) shares: ``offer``/``offer_many``/
``has_room_for`` on the producer side, ``poll``/``peek``/``poll_prefix``/
``poll_many`` on the consumer side.  ``has_room_for(item)`` answers whether
an immediate ``offer(item)`` is guaranteed to succeed — block routing uses
it for all-or-nothing sub-block admission, which a slot count alone cannot
promise on byte-capacity transports.
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

from .events import Event, EventBlock


class SPSCQueue:
    """Fixed-capacity ring buffer with non-blocking offer/poll."""

    __slots__ = ("_buf", "_cap", "_head", "_tail")

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self._cap = capacity
        self._buf: List[Any] = [None] * capacity
        self._head = 0  # next slot to poll
        self._tail = 0  # next slot to fill

    # -- producer side -----------------------------------------------------
    def offer(self, item) -> bool:
        """Enqueue ``item``; returns False (backpressure) when full."""
        if self._tail - self._head == self._cap:
            return False
        # jetlint: disable=ring-role-violation -- _buf slot writes are disjoint by cursor ownership: the producer fills [tail % cap] (unreachable to the consumer until tail publishes) and the consumer None-clears [head % cap, tail % cap) it already owns
        self._buf[self._tail % self._cap] = item
        self._tail += 1
        return True

    def offer_many(self, items: List[Any], start: int = 0,
                   end: Optional[int] = None) -> int:
        """Enqueue ``items[start:end]`` until full; returns the count
        accepted.

        The accepted prefix lands in one slice-assignment per ring segment,
        so a batch costs O(segments), not O(items) of Python bookkeeping.
        """
        cap = self._cap
        head, tail = self._head, self._tail
        n = (len(items) if end is None else end) - start
        free = cap - (tail - head)
        if n > free:
            n = free
        if n <= 0:
            return 0
        buf = self._buf
        idx = tail % cap
        seg = cap - idx
        if n <= seg:
            buf[idx:idx + n] = items[start:start + n]
        else:
            buf[idx:] = items[start:start + seg]
            buf[:n - seg] = items[start + seg:start + n]
        self._tail = tail + n
        return n

    def remaining_capacity(self) -> int:
        return self._cap - (self._tail - self._head)

    def has_room_for(self, item) -> bool:
        """True when an immediate ``offer(item)`` must succeed (transport
        contract; a slot queue needs exactly one free slot per item)."""
        return self._tail - self._head < self._cap

    # -- consumer side -----------------------------------------------------
    def poll(self) -> Optional[Any]:
        """Dequeue one item or return None when empty."""
        if self._head == self._tail:
            return None
        idx = self._head % self._cap
        item = self._buf[idx]
        self._buf[idx] = None
        self._head += 1
        return item

    def peek(self) -> Optional[Any]:
        if self._head == self._tail:
            return None
        return self._buf[self._head % self._cap]

    def poll_many(self, limit: int) -> List[Any]:
        """Dequeue up to ``limit`` items as a list (may be empty)."""
        n = self._tail - self._head
        if limit < n:
            n = limit
        if n <= 0:
            return []
        buf, cap = self._buf, self._cap
        idx = self._head % cap
        seg = cap - idx
        if n <= seg:
            out = buf[idx:idx + n]
            buf[idx:idx + n] = [None] * n
        else:
            out = buf[idx:] + buf[:n - seg]
            buf[idx:] = [None] * seg
            buf[:n - seg] = [None] * (n - seg)
        self._head += n
        return out

    def poll_prefix(self, limit: int,
                    explode_blocks: bool = False) -> Tuple[List[Any], Any]:
        """Batched, control-aware drain for the tasklet hot path.

        Dequeues the leading run of data items — :class:`Event`s and
        :class:`EventBlock`s — (up to ``limit`` queue slots) as a list; if
        the next item is a control item (watermark, barrier, DONE) it is
        dequeued too and returned separately.  Stopping *before* any item
        that follows a control item keeps the drain observably identical
        to the seed item-at-a-time loop, while the common case — a long
        run of events — moves as C-level slices with one type check per
        item.

        ``explode_blocks=True`` replaces each EventBlock in the run with
        its per-event explosion (the tasklet's shim for processors that do
        not declare block support); the block still counts as one slot
        toward ``limit``.

        Returns ``(events, control_item_or_None)``.
        """
        n = self._tail - self._head
        if limit < n:
            n = limit
        if n <= 0:
            return (), None
        buf, cap = self._buf, self._cap
        idx = self._head % cap
        seg = cap - idx
        if n <= seg:
            chunk = buf[idx:idx + n]
        else:
            chunk = buf[idx:] + buf[:n - seg]
        ctrl = None
        k = n
        block_at = None
        for pos, item in enumerate(chunk):
            cls = item.__class__
            if cls is Event:
                continue
            if cls is EventBlock:
                if explode_blocks and block_at is None:
                    block_at = pos
                continue
            if isinstance(item, (Event, EventBlock)):
                continue
            ctrl = item
            k = pos
            break
        if block_at is None or block_at >= k:
            events = chunk if k == n and ctrl is None else chunk[:k]
        else:
            # explode shim: splice each block's event run into position
            events = chunk[:block_at]
            ext = events.extend
            for item in chunk[block_at:k]:
                if item.__class__ is EventBlock:
                    ext(item.to_events())
                else:
                    events.append(item)
        consumed = k + (1 if ctrl is not None else 0)
        # clear the consumed slots segment-wise
        if consumed <= seg:
            buf[idx:idx + consumed] = [None] * consumed
        else:
            buf[idx:] = [None] * seg
            buf[:consumed - seg] = [None] * (consumed - seg)
        self._head += consumed
        return events, ctrl

    def drain_to(self, sink: list, limit: int) -> int:
        """Move up to ``limit`` items into ``sink`` (a list). Returns count."""
        n = min(limit, self._tail - self._head)
        buf, cap, head = self._buf, self._cap, self._head
        for i in range(n):
            idx = (head + i) % cap
            sink.append(buf[idx])
            buf[idx] = None
        self._head = head + n
        return n

    # -- shared -------------------------------------------------------------
    def __len__(self) -> int:
        return self._tail - self._head

    @property
    def capacity(self) -> int:
        return self._cap

    def is_empty(self) -> bool:
        return self._head == self._tail

    def is_full(self) -> bool:
        return self._tail - self._head == self._cap
