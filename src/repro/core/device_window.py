"""The host→device bridge: a window-aggregation vertex offloaded to the
compiled device tier.

``Pipeline.window(w).aggregate(op, placement="device")`` lowers to a
:class:`DeviceWindowProcessor` vertex on a distributed partitioned
in-edge — each parallel instance owns a StreamExecutor over its
key-partition subset (partitioning of device state follows partitioning
of compute) — replacing the host two-stage accumulate/combine plan with
the device tier's fused accumulate+emit step (:mod:`repro.streaming`):

* **Packing** — incoming :class:`~repro.core.events.EventBlock` columns
  (the host hot path) append into fixed-size staging arrays; scalar
  :class:`~repro.core.events.Event`\\ s take the same arrays one row at a
  time.  A full staging buffer becomes one padded device batch
  ``{ts, key, value, valid, wm}``: the tail rows carry ``valid=False``
  and keys hash-bucket into ``n_key_buckets`` via ``key % n_key_buckets``
  (injective whenever the key space fits the bucket count; wider key
  spaces aggregate per *bucket* — the documented caveat).  The original
  key of every bucket is remembered host-side so emissions convert back.
* **Async drive** — batch *i+1* stages (``stage_batch``) while step *i*
  executes; step outputs stay on device as futures in an ordered pending
  list and are only materialized once ``is_ready()`` (polled from
  ``poll_async`` / the watermark path), so the cooperative tasklet loop
  NEVER blocks on the device.
* **Watermarks** — the device runs in hint-only frontier mode
  (``frontier_from_data=False``): host watermarks (already lagged at the
  source) are the only event-time authority, so every device instance
  observes the identical watermark sequence.  A watermark that does not
  cross a slide boundary forwards immediately (no window can close); one
  that does submits a wm-hinted step and forwards only after that step's
  emissions are harvested and the device emission front has passed the
  watermark — downstream still sees every result *before* the watermark
  that closed it, exactly the host contract.
* **Unpacking** — harvested ``(window_ends, results)`` rows become
  ``Event(w_end - 1, key, WindowResult(w_end, key, value))`` per nonzero
  bucket, the exact shape the host two-stage combiner emits (near-integer
  values collapse to int: counting/integer-sum aggregates compare equal
  to the host path bit-for-bit up to f32's 2**24 integer range).
* **Snapshots** — barriers align to step boundaries: staged rows flush as
  a final step, emission catches up to the last processed watermark
  (identical across instances — the coalesced watermark sequence is), and
  the device state stores per ORIGINAL key as ``("k", key) -> [(frame,
  value), ...]`` entries partitioned like the data keys, so restore after
  a topology change merges shards additively under the standard per-key
  contract.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .events import Event, EventBlock, Watermark
from .processor import Inbox, Processor
from .window import AggregateOperation, SlidingWindowDef, WindowResult

def _as_int_if_integral(v: float):
    r = round(v)
    return int(r) if abs(v - r) < 1e-6 else float(v)


class DeviceWindowProcessor(Processor):
    """Block-aware tasklet processor driving a device StreamExecutor.

    ``op`` must be a vectorizable aggregate: ``counting()`` or a
    ``summing(...)`` whose getter carries a block form (the same ops the
    host columnar fast path accepts).  Blocks feed the packer whole; with
    a summing op lacking a block getter the vertex falls back to scalar
    ingestion (``accepts_blocks`` stays False and the tasklet shim
    explodes blocks at the queue boundary).

    Known divergence from the host plan: the device pane matrix cannot
    distinguish "no events" from "events summing to exactly 0", so a
    summing window whose total is 0 emits nothing here while the host
    combiner emits an explicit ``WindowResult(..., 0)``.  Counting and
    positive-valued sums (the NEXMark shapes) are unaffected; keep
    sign-cancelling sums on the host if the empty-vs-zero distinction
    matters downstream.
    """

    #: Host-side staging and flow state never survives a restart by
    #: design: save_to_snapshot submits staged rows as a final pre-barrier
    #: device step and drains every in-flight output, so the durable
    #: window content lives entirely in the device state (saved as
    #: ("k", key) shards + ("meta", idx) entries and rebuilt by
    #: finish_snapshot_restore).  _emit_buf flushes before the barrier,
    #: watermark cursors re-advance from replayed sources, executor/_spec
    #: are rebuilt lazily by _ensure_executor, and _bucket_collisions is
    #: telemetry.
    EPHEMERAL_STATE = frozenset({
        "_ts", "_key", "_val", "_n", "_pending", "_emit_buf", "_steps",
        "_progress_hint", "_last_wm", "_wm_submitted", "_closed",
        "_spec", "_bucket_collisions",
    })

    def __init__(self, wdef: SlidingWindowDef, op: AggregateOperation,
                 n_key_buckets: int = 1024, batch_size: int = 1024,
                 max_windows_per_step: int = 8, ring_margin: int = 8,
                 emit_rounds: int = 0):
        if op.kind not in ("count", "sum"):
            raise ValueError(
                "device placement supports counting()/summing() aggregates "
                f"(got kind={op.kind!r}); keep other ops on the host")
        self.wdef = wdef
        self.op = op
        self.n_key_buckets = n_key_buckets
        self.batch_size = batch_size
        self.max_windows_per_step = max_windows_per_step
        self.ring_margin = ring_margin
        self.emit_rounds = emit_rounds
        # blocks are only useful when the value column vectorizes
        self.accepts_blocks = (op.kind == "count"
                               or op.block_get is not None)

        self.executor = None
        self.state = None
        # staging buffers (one device batch)
        B = batch_size
        self._ts = np.zeros(B, np.int32)
        self._key = np.zeros(B, np.int32)
        self._val = np.zeros(B, np.float32)
        self._n = 0
        #: bucket -> original key (the inverse of the packing hash; first
        #: writer wins on collision — see the module docstring caveat).
        #: An int64 array so block ingestion updates it vectorized.
        self._bkey_sentinel = np.int64(np.iinfo(np.int64).min)
        self._bucket_key = np.full(n_key_buckets, self._bkey_sentinel,
                                   np.int64)
        self._bucket_collisions = 0
        self._closed = False
        #: ordered in-flight step outputs: (wm_hint_or_None, device out)
        self._pending: deque = deque()
        self._emit_buf: deque = deque()
        self._wm_submitted = -1          # highest hint staged to the device
        self._last_wm = -1               # highest watermark fully processed
        self._top_ts = -1                # max event ts seen (host-side)
        self._steps = 0                  # telemetry: device steps driven
        self._progress_hint = False      # last _harvest_ready made progress
        self._snap_entries: Optional[List[Tuple[Any, Any]]] = None
        self._restore_frames: Dict[Any, Dict[int, float]] = {}
        self._restore_meta: List[Dict] = []

    # ------------------------------------------------------------ set-up --
    def init(self, outbox, ctx) -> None:
        super().init(outbox, ctx)
        # build + warm the executor NOW (one dummy step compiles the XLA
        # program) so the one-time compile cost lands at job start, not in
        # the middle of a paced run's latency measurement
        self._ensure_executor()
        staged, cnt = self.executor.stage_batch({
            "ts": np.zeros(self.batch_size, np.int32),
            "key": np.zeros(self.batch_size, np.int32),
            "value": np.zeros(self.batch_size, np.float32),
            "valid": np.zeros(self.batch_size, bool),
            "wm": np.asarray(-1, np.int32)})
        self.state, out = self.executor.step(self.state, staged,
                                             valid_count=cnt)
        np.asarray(out["valid"])        # block: compilation finished

    def _ensure_executor(self) -> None:
        if self.executor is not None:
            return
        from ..streaming import (StreamExecutor, StreamJobConfig,
                                 VectorWindowSpec)
        spec = VectorWindowSpec(
            size_ms=self.wdef.size, slide_ms=self.wdef.slide,
            n_key_buckets=self.n_key_buckets,
            max_windows_per_step=self.max_windows_per_step,
            ring_margin=self.ring_margin, emit_rounds=self.emit_rounds,
            frontier_from_data=False)
        self.executor = StreamExecutor(
            StreamJobConfig(window=spec, batch_size=self.batch_size))
        self.state = self.executor.init_state()
        self._spec = spec

    # ------------------------------------------------------------ ingest --
    def process(self, ordinal: int, inbox: Inbox) -> None:
        self._ensure_executor()
        op = self.op
        for item in inbox:
            if item.__class__ is EventBlock:
                self._ingest_block(item)
            else:
                # scalar fallback: one staged row per event; the op's own
                # accumulate over a fresh accumulator IS the row weight
                # (count -> 1, sum -> get(ev))
                b = int(item.key) % self.n_key_buckets
                prev = self._bucket_key[b]
                if prev == self._bkey_sentinel:
                    self._bucket_key[b] = item.key
                elif prev != item.key:
                    self._bucket_collisions += 1
                n = self._n
                self._ts[n] = item.ts
                self._key[n] = b
                self._val[n] = op.accumulate(op.create(), item)
                if item.ts > self._top_ts:
                    self._top_ts = item.ts
                self._n = n + 1
                if self._n == self.batch_size:
                    self._submit()
        inbox.clear()
        # opportunistically drain finished device steps (non-blocking)
        self._harvest_ready()
        if self._emit_buf:
            self._flush_emit()

    def _ingest_block(self, blk: EventBlock) -> None:
        K, B = self.n_key_buckets, self.batch_size
        ts = blk.ts
        buckets = blk.key % K
        if self.op.kind == "count":
            weights = None
        else:
            weights = np.asarray(self.op.block_get(blk), np.float32)
        # remember the original key per bucket (vectorized; first writer
        # wins) and count collisions — buckets already bound to a
        # DIFFERENT key — for telemetry
        bk = np.asarray(buckets, np.int64)
        kk = np.asarray(blk.key, np.int64)
        bmap = self._bucket_key
        prev = bmap[bk]
        fresh = prev == self._bkey_sentinel
        if fresh.any():
            # first occurrence in this block wins among duplicates: write
            # in reverse row order so the earliest assignment lands last
            idx = np.nonzero(fresh)[0][::-1]
            bmap[bk[idx]] = kk[idx]
            prev = bmap[bk]
        self._bucket_collisions += int(np.count_nonzero(prev != kk))
        top = int(ts.max()) if len(ts) else -1
        if top > np.iinfo(np.int32).max:
            # the device tier computes event time in int32 ms; silently
            # wrapping an int64 host timestamp would corrupt every frame
            # assignment downstream (the scalar path raises naturally)
            raise ValueError(
                f"device window timestamps must fit int32 ms (got {top}); "
                "rebase the stream to a relative time origin")
        if top > self._top_ts:
            self._top_ts = top
        i, n = 0, len(ts)
        while i < n:
            take = min(B - self._n, n - i)
            sl = slice(i, i + take)
            dst = slice(self._n, self._n + take)
            self._ts[dst] = ts[sl]
            self._key[dst] = buckets[sl]
            self._val[dst] = 1.0 if weights is None else weights[sl]
            self._n += take
            i += take
            if self._n == B:
                self._submit()

    # ------------------------------------------------------- device drive --
    def _submit(self, wm_hint: Optional[int] = None) -> None:
        """Stage the current staging buffer as one padded device batch and
        dispatch the step asynchronously; the output joins the pending
        list as a device future."""
        n = self._n
        B = self.batch_size
        wm = np.asarray(-1 if wm_hint is None else wm_hint, np.int32)
        if n == B:
            batch = {"ts": self._ts.copy(), "key": self._key.copy(),
                     "value": self._val.copy(),
                     "valid": np.ones(B, bool), "wm": wm}
        else:
            # pad the partial burst to the fixed device batch size
            # (np.pad copies, so the staging buffers stay reusable)
            pad = (0, B - n)
            batch = {"ts": np.pad(self._ts[:n], pad),
                     "key": np.pad(self._key[:n], pad),
                     "value": np.pad(self._val[:n], pad),
                     "valid": np.pad(np.ones(n, bool), pad), "wm": wm}
        staged, cnt = self.executor.stage_batch(batch)
        self.state, out = self.executor.step(self.state, staged,
                                             valid_count=cnt)
        self._pending.append((wm_hint, out))
        self._steps += 1
        self._n = 0

    @staticmethod
    def _is_ready(arr) -> bool:
        fn = getattr(arr, "is_ready", None)
        return fn() if fn is not None else True

    def _harvest_ready(self, block: bool = False) -> bool:
        """Materialize finished pending outputs in order, converting their
        emissions into WindowResult events.  Stops at the first output
        still executing unless ``block``; returns True when the pending
        list fully drained."""
        pending = self._pending
        progress = False
        while pending:
            _hint, out = pending[0]
            if not block and not self._is_ready(out["valid"]):
                break
            self._convert(out)
            pending.popleft()
            progress = True
        self._progress_hint = progress
        return not pending

    def _convert(self, out: Dict) -> None:
        valid = np.asarray(out["valid"])
        if not valid.any():
            return
        ends = np.asarray(out["window_ends"])
        res = np.asarray(out["results"])
        bmap, sentinel = self._bucket_key, self._bkey_sentinel
        buf = self._emit_buf
        for i in np.nonzero(valid)[0].tolist():
            row = res[i]
            w_end = int(ends[i])
            for b in np.nonzero(row)[0].tolist():
                k = bmap[b]
                key = b if k == sentinel else int(k)
                val = _as_int_if_integral(float(row[b]))
                buf.append(
                    Event(w_end - 1, key, WindowResult(w_end, key, val)))

    def _flush_emit(self) -> bool:
        buf = self._emit_buf
        while buf:
            if not self.outbox.offer(buf[0]):
                return False
            buf.popleft()
        return True

    def poll_async(self) -> bool:
        """Non-blocking pump the tasklet calls every slice: harvest device
        futures that finished since, and move their emissions out."""
        if self.executor is None or not self._pending:
            return False
        self._harvest_ready()
        progress = self._progress_hint
        if self._emit_buf:
            progress |= self._flush_emit()
        return progress

    # --------------------------------------------------------- watermarks --
    def try_process_watermark(self, wm: Watermark) -> bool:
        """Forward the watermark only once every window it closes has been
        emitted downstream (the host ordering contract), without ever
        blocking: not-ready device futures just defer to the next call."""
        self._ensure_executor()
        if not self._flush_emit():
            return False
        slide = self.wdef.slide
        if wm.ts // slide == self._last_wm // slide and wm.ts >= 0 \
                and self._last_wm >= 0:
            # no slide boundary crossed: window closure is slide-granular,
            # so this watermark cannot close anything the previous one did
            # not — forward immediately without a device roundtrip.  The
            # hint itself is NOT sent to the device; that is safe because
            # a later boundary-crossing watermark (or complete()'s
            # close-out) supersedes it before any emission decision needs
            # it.
            self._last_wm = wm.ts
            return True
        if wm.ts > self._wm_submitted:
            # flush staged rows + the hint in ONE wm-carrying step
            self._submit(wm_hint=wm.ts)
            self._wm_submitted = wm.ts
        # harvest everything up to (and including) the hint step
        if not self._harvest_ready():
            return False
        # the device emission front must have passed the watermark — a
        # bounded emit loop may need another round after a very large jump
        ne = self.state["next_emit"]
        if not self._is_ready(ne):
            return False
        ne_v = int(ne)
        if 0 <= ne_v <= wm.ts:
            self._submit(wm_hint=wm.ts)     # another catch-up round
            return False
        if not self._flush_emit():
            return False
        self._last_wm = wm.ts
        return True

    # ----------------------------------------------------------- complete --
    def complete(self) -> bool:
        if self.executor is None:
            return True
        # close every open window: flush staged rows, then drive wm-hinted
        # steps until no live frame remains (end-of-stream may sync)
        if not self._closed:
            if self._n:
                self._submit()
            close_wm = max(self._top_ts + self.wdef.size + self.wdef.slide,
                           self._last_wm + self.wdef.slide)
            for _ in range(10_000):
                self._submit(wm_hint=close_wm)
                self._harvest_ready(block=True)
                if not np.any(np.asarray(self.state["slot_frame"]) >= 0):
                    break
            self._harvest_ready(block=True)
            self._closed = True
        return self._flush_emit()

    # ----------------------------------------------------------- snapshot --
    def save_to_snapshot(self) -> bool:
        if self.executor is None:
            return True
        if self._snap_entries is None:
            # step-boundary alignment: staged rows become a final
            # pre-barrier step, emission catches up to the last processed
            # watermark (identical across instances), in-flight outputs
            # drain.  Snapshot time may sync with the device.
            if self._n:
                self._submit(wm_hint=self._wm_submitted
                             if self._wm_submitted >= 0 else None)
            for _ in range(10_000):
                self._harvest_ready(block=True)
                ne_v = int(self.state["next_emit"])
                if not (0 <= ne_v <= self._last_wm):
                    break
                self._submit(wm_hint=self._last_wm)
            self._snap_entries = self._build_snapshot_entries()
        # pre-barrier output (results the catch-up produced) leaves first
        if not self._flush_emit():
            return False
        for skey, val in self._snap_entries:
            self.outbox.offer_to_snapshot(skey, val)
        self._snap_entries = None
        return True

    def _build_snapshot_entries(self) -> List[Tuple[Any, Any]]:
        snap = self.executor.snapshot(self.state)
        host = {k: np.asarray(v) for k, v in snap.items()}
        panes, slot_frame = host["panes"], host["slot_frame"]
        entries: List[Tuple[Any, Any]] = []
        # per ORIGINAL key: [(frame, partial)] — mergeable shards under
        # the standard restore contract, partitioned like the data keys
        per_key: Dict[Any, List[Tuple[int, float]]] = {}
        bmap, sentinel = self._bucket_key, self._bkey_sentinel
        slots, buckets = np.nonzero(panes)
        for s, b in zip(slots.tolist(), buckets.tolist()):
            f = int(slot_frame[s])
            if f < 0:
                continue
            k = bmap[b]
            key = b if k == sentinel else int(k)
            per_key.setdefault(key, []).append((f, float(panes[s, b])))
        for key, frames in per_key.items():
            entries.append((("k", key), frames))
        entries.append((("meta", self.ctx.global_index), {
            "watermark": int(host["watermark"]),
            "next_emit": int(host["next_emit"]),
            "dropped_late": int(host["dropped_late"]),
            "dropped_conflict": int(host["dropped_conflict"]),
            "top_ts": self._top_ts,
        }))
        return entries

    def snapshot_partition(self, skey):
        from .dag import PARTITION_COUNT
        if skey[0] == "k":
            return hash(skey[1]) % PARTITION_COUNT
        return None

    def restore_from_snapshot(self, items) -> None:
        for skey, val in items:
            if skey[0] == "k":
                frames = self._restore_frames.setdefault(skey[1], {})
                for f, v in val:
                    frames[f] = frames.get(f, 0.0) + v
            elif skey[0] == "meta":
                self._restore_meta.append(val)

    def finish_snapshot_restore(self) -> None:
        if not self._restore_frames and not self._restore_meta:
            return
        self._ensure_executor()
        import jax.numpy as jnp
        spec = self._spec
        R, K = spec.ring_len, spec.n_key_buckets
        panes = np.zeros((R, K), np.float32)
        slot_frame = np.full(R, -1, np.int32)
        dropped_conflict = 0
        # older frames win slot conflicts (they emit sooner); a shard pair
        # whose in-flight data diverged by more than the ring span loses
        # the younger frame into dropped_conflict, mirroring accumulate
        for key, frames in sorted(self._restore_frames.items(),
                                  key=lambda kv: str(kv[0])):
            b = int(key) % K
            if self._bucket_key[b] == self._bkey_sentinel:
                self._bucket_key[b] = key
            for f, v in sorted(frames.items()):
                s = f % R
                if slot_frame[s] < 0 or slot_frame[s] == f:
                    slot_frame[s] = f
                    panes[s, b] += v
                elif f < slot_frame[s]:
                    # evict the younger occupant's partials, keep the older
                    panes[s, :] = 0.0
                    slot_frame[s] = f
                    panes[s, b] = v
                    dropped_conflict += 1
                else:
                    dropped_conflict += 1
        meta = self._restore_meta
        state = {
            "panes": jnp.asarray(panes),
            "slot_frame": jnp.asarray(slot_frame),
            "watermark": jnp.asarray(
                max((m["watermark"] for m in meta), default=-1), jnp.int32),
            "next_emit": jnp.asarray(
                max((m["next_emit"] for m in meta), default=-1), jnp.int32),
            "dropped_late": jnp.asarray(
                sum(m["dropped_late"] for m in meta), jnp.int32),
            "dropped_conflict": jnp.asarray(
                sum(m["dropped_conflict"] for m in meta)
                + dropped_conflict, jnp.int32),
        }
        self.state = self.executor._shard_state(state)
        self._top_ts = max((m["top_ts"] for m in meta), default=-1)
        self._restore_frames = {}
        self._restore_meta = []

    # ---------------------------------------------------------- telemetry --
    @property
    def late_dropped(self) -> int:
        """Deliberately dropped late events (device counter, host view)."""
        if self.state is None:
            return 0
        return int(np.asarray(self.state["dropped_late"]))

    @property
    def conflict_dropped(self) -> int:
        if self.state is None:
            return 0
        return int(np.asarray(self.state["dropped_conflict"]))

    @property
    def bucket_collisions(self) -> int:
        return self._bucket_collisions
