"""Jet core: DAG execution engine with tasklets, cooperative scheduling,
watermarks, windows, Chandy-Lamport snapshots and backpressure."""

from .backend import (ExecutionBackend, InProcessBackend, WorkerFailure,
                      make_backend)
from .clock import Clock, VirtualClock, WallClock
from .dag import DAG, Edge, PARTITION_COUNT, Routing, Vertex
from .device_window import DeviceWindowProcessor
from .engine import (JetCluster, Job, JobConfig, JobFailedError,
                     RestartPolicy, JOB_COMPLETED, JOB_FAILED, JOB_RUNNING)
from .events import (Barrier, DONE, Event, EventBlock, LateEvent, Watermark,
                     block_form)
from .pipeline import Pipeline, group_aggregate
from .processor import (FilterProcessor, FlatMapProcessor,
                        FusedFunctionProcessor, Inbox, MapProcessor, Outbox,
                        Processor, SinkProcessor)
from .sources import (CollectorSink, Journal, JournalSource, ListSource,
                      PacedGeneratorSource)
from .tasklet import (GUARANTEE_AT_LEAST_ONCE, GUARANTEE_EXACTLY_ONCE,
                      GUARANTEE_NONE)
from .watermark import EventTimePolicy, WatermarkCoalescer
from .window import (AggregateOperation, SessionResult, SessionWindowDef,
                     SessionWindowProcessor, WindowResult, averaging,
                     co_aggregate, counting, max_by, session, sliding,
                     summing, to_list, tumbling)

__all__ = [
    "ExecutionBackend", "InProcessBackend", "WorkerFailure", "make_backend",
    "Clock", "VirtualClock", "WallClock",
    "DAG", "Edge", "PARTITION_COUNT", "Routing", "Vertex",
    "DeviceWindowProcessor",
    "JetCluster", "Job", "JobConfig", "JobFailedError", "RestartPolicy",
    "JOB_COMPLETED", "JOB_FAILED", "JOB_RUNNING",
    "Barrier", "DONE", "Event", "EventBlock", "LateEvent", "Watermark",
    "block_form",
    "Pipeline", "group_aggregate",
    "FilterProcessor", "FlatMapProcessor", "FusedFunctionProcessor",
    "Inbox", "MapProcessor", "Outbox", "Processor", "SinkProcessor",
    "CollectorSink", "Journal", "JournalSource", "ListSource",
    "PacedGeneratorSource",
    "GUARANTEE_AT_LEAST_ONCE", "GUARANTEE_EXACTLY_ONCE", "GUARANTEE_NONE",
    "EventTimePolicy", "WatermarkCoalescer",
    "AggregateOperation", "SessionResult", "SessionWindowDef",
    "SessionWindowProcessor", "WindowResult", "averaging", "co_aggregate",
    "counting", "max_by", "session", "sliding", "summing", "to_list",
    "tumbling",
]
