"""Tasklets and the cooperative scheduler.

A :class:`ProcessorTasklet` adapts one processor instance to the cooperative
execution protocol: ``call()`` performs one short slice of work — drain some
input, run the processor, flush the outbox — and returns whether it made
progress.  A :class:`CooperativeWorker` owns a set of tasklets and steps
them round-robin, exactly the paper's "simply iterating over all tasklets
repeatedly works pretty well" (§3.2).

The tasklet also implements the two stream-protocol mechanisms that must be
engine-level, not processor-level:

* **watermark coalescing** across all input queues (min-rule), and
* **Chandy-Lamport barrier handling**: in exactly-once mode a queue that
  delivered barrier *n* is parked until every live input queue delivered
  barrier *n* (alignment), then the processor state is snapshotted and the
  barrier is forwarded; in at-least-once mode the first sighting snapshots
  immediately and nothing is parked.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable, List, Optional, Sequence

from .dag import PARTITION_COUNT, Routing
from .events import DONE, Barrier, DoneItem, Event, Watermark, MIN_TIME
from .processor import Inbox, Outbox, Processor
from .watermark import WatermarkCoalescer

# tasklet lifecycle states
RUNNING = "running"
SAVING_SNAPSHOT = "saving_snapshot"
EMITTING_BARRIER = "emitting_barrier"
COMPLETING = "completing"
EMITTING_DONE = "emitting_done"
DONE_STATE = "done"

GUARANTEE_NONE = "none"
GUARANTEE_AT_LEAST_ONCE = "at_least_once"
GUARANTEE_EXACTLY_ONCE = "exactly_once"


class InQueue:
    """One inbound queue (SPSC ring or the receiver side of a NetworkLink)
    plus its stream-protocol state."""

    __slots__ = ("q", "ordinal", "done", "parked_barrier", "seen_barrier",
                 "priority")

    def __init__(self, q, ordinal: int, priority: int = 0):
        self.q = q
        self.ordinal = ordinal
        self.done = False
        #: barrier id this queue is parked on (exactly-once alignment)
        self.parked_barrier: Optional[int] = None
        #: last barrier id delivered (at-least-once: no parking, but the
        #: snapshot still waits for the barrier on every live queue)
        self.seen_barrier: int = 0
        #: lower value drains first; higher-priority queues are not polled
        #: until every lower-value queue is done (hash-join build sides)
        self.priority = priority


class EdgeCollector:
    """Routes a tasklet's output items onto one out-edge's queues.

    ``queues[i]`` accepts ``offer(item) -> bool``; for distributed edges
    some of them are NetworkLink producers.  ``partition_to_queue`` maps a
    key partition to a queue index for PARTITIONED routing.  Control items
    (watermarks, barriers, DONE) are *broadcast* to every queue with
    resumable partial progress.
    """

    __slots__ = ("queues", "routing", "key_fn", "partition_to_queue",
                 "_rr_cursor", "_bc_item", "_bc_remaining")

    def __init__(self, queues: Sequence, routing: str,
                 key_fn: Optional[Callable],
                 partition_to_queue: Optional[List[int]] = None):
        self.queues = list(queues)
        self.routing = routing
        self.key_fn = key_fn
        self.partition_to_queue = partition_to_queue
        self._rr_cursor = 0
        self._bc_item = None
        self._bc_remaining: List[int] = []

    # -- data items ---------------------------------------------------------
    def offer(self, item: Event) -> bool:
        r = self.routing
        if r == Routing.PARTITIONED:
            key = self.key_fn(item) if self.key_fn else item.key
            pid = hash(key) % PARTITION_COUNT
            return self.queues[self.partition_to_queue[pid]].offer(item)
        if r == Routing.ROUND_ROBIN:
            n = len(self.queues)
            for i in range(n):
                qi = (self._rr_cursor + i) % n
                if self.queues[qi].offer(item):
                    self._rr_cursor = (qi + 1) % n
                    return True
            return False
        if r == Routing.ISOLATED:
            return self.queues[0].offer(item)
        # BROADCAST of data items uses the same resumable path as control
        return self.broadcast(item)

    # -- control items --------------------------------------------------------
    def broadcast(self, item) -> bool:
        """Offer ``item`` to every queue; resumable under backpressure.
        Only one broadcast may be in flight per collector at a time."""
        if self._bc_item is not item:
            self._bc_item = item
            self._bc_remaining = list(range(len(self.queues)))
        still = []
        for qi in self._bc_remaining:
            if not self.queues[qi].offer(item):
                still.append(qi)
        self._bc_remaining = still
        if not still:
            self._bc_item = None
            return True
        return False


class SnapshotContext:
    """Shared per-job snapshot coordination state (one per execution).

    A snapshot completes when every tasklet has either acked its barrier or
    become exempt (a tasklet whose inputs are exhausted will never see a
    barrier; its state is final and empty of in-flight work)."""

    __slots__ = ("guarantee", "requested_id", "writer", "tasklets", "_acked",
                 "completed_id", "on_complete", "terminal_requested")

    def __init__(self, guarantee: str, writer=None):
        self.guarantee = guarantee
        self.requested_id = 0       # bumped by the coordinator
        self.writer = writer        # SnapshotWriter (state backend)
        self.tasklets: List = []
        self._acked: set = set()
        self.completed_id = 0
        self.on_complete: Optional[Callable[[int], None]] = None
        self.terminal_requested = False

    def begin(self, snapshot_id: int) -> None:
        self.requested_id = snapshot_id
        self._acked = set()
        self._check()

    def ack(self, snapshot_id: int, tasklet) -> None:
        if snapshot_id != self.requested_id:
            return
        self._acked.add(id(tasklet))
        self._check()

    def notify_exempt(self, tasklet) -> None:
        """A tasklet entered a terminal phase; re-evaluate completion."""
        self._check()

    def _check(self) -> None:
        if self.completed_id == self.requested_id:
            return
        if all(id(t) in self._acked or t.is_snapshot_exempt
               for t in self.tasklets):
            self.completed_id = self.requested_id
            if self.on_complete is not None:
                self.on_complete(self.completed_id)


class ProcessorTasklet:
    """Drives one processor instance through the cooperative protocol."""

    def __init__(self, name: str, processor: Processor,
                 in_queues: List[InQueue],
                 collectors: List[EdgeCollector],
                 ssctx: SnapshotContext,
                 vertex_name: str,
                 global_index: int,
                 snapshot_pid_fn: Optional[Callable[[Any], int]] = None,
                 is_source: bool = False):
        self.name = name
        self.processor = processor
        self.in_queues = in_queues
        self.collectors = collectors
        self.ssctx = ssctx
        self.vertex_name = vertex_name
        self.global_index = global_index
        self.is_source = is_source or not in_queues
        # per-ordinal inboxes
        max_ord = max((iq.ordinal for iq in in_queues), default=-1)
        self.inboxes = [Inbox() for _ in range(max_ord + 1)]
        self.outbox = Outbox()
        self._pending_out: deque = deque()
        self._pending_wm: Optional[Watermark] = None
        self._wm_processed = False
        self.coalescer = WatermarkCoalescer(len(in_queues)) if in_queues else None
        self.state = RUNNING
        self.snapshot_in_progress: Optional[int] = None
        #: snapshot id waiting for the inboxes to drain before it may start
        self._armed_snapshot: Optional[int] = None
        self.last_snapshot_id = 0
        self._snapshot_pid_fn = snapshot_pid_fn
        self._queue_cursor = 0
        self._barrier_to_emit: Optional[Barrier] = None
        # stats
        self.items_in = 0
        self.items_out = 0
        self.calls = 0
        self.idle_calls = 0

    # ------------------------------------------------------------------ call --
    def call(self) -> bool:
        """One execution slice; returns True when progress was made."""
        self.calls += 1
        progress = False

        # 1. flush anything already produced
        if self._pending_out or len(self.outbox):
            progress |= self._flush_outbox()
            if self._pending_out:
                self.idle_calls += not progress
                return progress

        # 2. pending watermark: only once every already-drained item has been
        #    processed (all data <= a coalesced watermark is in the inboxes
        #    by the time it advances, so this ordering is what makes window
        #    emission see complete frames)
        if (self._pending_wm is not None
                and not any(len(ib) for ib in self.inboxes)):
            if not self._forward_watermark():
                self.idle_calls += not progress
                return progress
            progress = True

        st = self.state
        if st == RUNNING:
            progress |= self._run_slice()
        elif st == SAVING_SNAPSHOT:
            progress |= self._save_snapshot_slice()
        elif st == EMITTING_BARRIER:
            progress |= self._emit_barrier_slice()
        elif st == COMPLETING:
            progress |= self._complete_slice()
        elif st == EMITTING_DONE:
            progress |= self._emit_done_slice()
        if not progress:
            self.idle_calls += 1
        return progress

    # -------------------------------------------------------------- running --
    def _run_slice(self) -> bool:
        progress = False
        # source tasklets: react to coordinator-initiated snapshots
        if self.is_source and self.ssctx.guarantee != GUARANTEE_NONE:
            if self.ssctx.requested_id > self.last_snapshot_id:
                self._begin_snapshot(self.ssctx.requested_id)
                return True

        if self.is_source:
            # streaming/batch sources do their work in complete()
            done = self.processor.complete()
            emitted = self._flush_outbox()
            progress |= emitted
            if done:
                self.state = EMITTING_DONE
                return True
            return progress

        progress |= self._drain_inputs()
        # run the processor over non-empty inboxes
        for ordinal, inbox in enumerate(self.inboxes):
            if len(inbox):
                before = len(inbox)
                self.processor.process(ordinal, inbox)
                progress |= len(inbox) != before or len(self.outbox) > 0
                if len(self.outbox):
                    self._flush_outbox()
        # watermark became due after this slice's inbox processing
        if (self._pending_wm is not None
                and not any(len(ib) for ib in self.inboxes)):
            progress |= self._forward_watermark()
        # a snapshot armed by a barrier starts only once every pre-barrier
        # item has been fully processed and emitted (consistency of the cut)
        if (self._armed_snapshot is not None
                and not any(len(ib) for ib in self.inboxes)
                and not self._pending_out and not len(self.outbox)):
            sid = self._armed_snapshot
            self._armed_snapshot = None
            self._begin_snapshot(sid)
            return True
        # all inputs done?
        if (self.state == RUNNING and self.in_queues
                and all(iq.done for iq in self.in_queues)
                and not any(len(ib) for ib in self.inboxes)):
            self.state = COMPLETING
            self.ssctx.notify_exempt(self)
            progress = True
        return progress

    def _drain_inputs(self) -> bool:
        """Poll input queues round-robin, handling control items."""
        progress = False
        n = len(self.in_queues)
        exactly_once = self.ssctx.guarantee == GUARANTEE_EXACTLY_ONCE
        # priority edges: only drain the lowest not-yet-done priority class
        cur_priority = min((iq.priority for iq in self.in_queues
                            if not iq.done), default=0)
        for i in range(n):
            iq = self.in_queues[(self._queue_cursor + i) % n]
            if iq.done or iq.parked_barrier is not None:
                continue
            if iq.priority > cur_priority:
                continue
            inbox = self.inboxes[iq.ordinal]
            # drain a bounded batch from this queue
            for _ in range(256):
                item = iq.q.poll()
                if item is None:
                    break
                progress = True
                if isinstance(item, Event):
                    self.items_in += 1
                    inbox.add(item)
                    continue
                if isinstance(item, Watermark):
                    self._on_watermark(iq, item)
                    break  # process data before more control items
                if isinstance(item, Barrier):
                    iq.seen_barrier = item.snapshot_id
                    if exactly_once:
                        iq.parked_barrier = item.snapshot_id
                    self._recheck_alignment(item.snapshot_id)
                    break
                if isinstance(item, DoneItem):
                    self._on_queue_done(iq)
                    break
        self._queue_cursor = (self._queue_cursor + 1) % max(n, 1)
        return progress

    # ------------------------------------------------------------ watermarks --
    def _on_watermark(self, iq: InQueue, wm: Watermark) -> None:
        qi = self.in_queues.index(iq)
        new_ts = self.coalescer.observe(qi, wm.ts)
        if new_ts is not None:
            self._pending_wm = Watermark(new_ts)
            self._wm_processed = False

    def _forward_watermark(self) -> bool:
        wm = self._pending_wm
        if not self._wm_processed:
            if not self.processor.try_process_watermark(wm):
                self._flush_outbox()
                return False
            self._wm_processed = True
            self._flush_outbox()
        for c in self.collectors:
            if not c.broadcast(wm):
                return False
        self._pending_wm = None
        return True

    # -------------------------------------------------------------- barriers --
    def _recheck_alignment(self, snapshot_id: Optional[int] = None) -> None:
        """Arm the snapshot once barrier ``snapshot_id`` was delivered on
        every live queue.  Exactly-once additionally parks queues that are
        already past the barrier (done in ``_drain_inputs``); at-least-once
        keeps draining them, accepting replay-duplicates."""
        if snapshot_id is None:
            ids = [iq.seen_barrier for iq in self.in_queues
                   if not iq.done and iq.seen_barrier > self.last_snapshot_id]
            if not ids:
                return
            snapshot_id = min(ids)
        if snapshot_id <= self.last_snapshot_id:
            return
        live = [iq for iq in self.in_queues if not iq.done]
        if live and all(iq.seen_barrier >= snapshot_id for iq in live):
            self._armed_snapshot = snapshot_id

    def _begin_snapshot(self, snapshot_id: int) -> None:
        self.snapshot_in_progress = snapshot_id
        self.state = SAVING_SNAPSHOT

    def _save_snapshot_slice(self) -> bool:
        # transactional sinks key their prepared buffers by snapshot id
        self.processor.current_snapshot_id = self.snapshot_in_progress
        ok = self.processor.save_to_snapshot()
        # drain snapshotted state into the store
        writer = self.ssctx.writer
        if writer is not None:
            for key, value in self.outbox.snapshot_queue:
                pid = (self._snapshot_pid_fn(key)
                       if self._snapshot_pid_fn is not None else None)
                if pid is None:
                    pid = hash(key) % PARTITION_COUNT
                writer.put(self.snapshot_in_progress, self.vertex_name,
                           key, value, pid)
        self.outbox.snapshot_queue.clear()
        self._flush_outbox()
        if ok:
            self._barrier_to_emit = Barrier(self.snapshot_in_progress)
            self.state = EMITTING_BARRIER
        return True

    def _emit_barrier_slice(self) -> bool:
        b = self._barrier_to_emit
        for c in self.collectors:
            if not c.broadcast(b):
                return True  # made progress, still emitting
        # barrier fully forwarded: unpark queues, ack, resume
        self.last_snapshot_id = b.snapshot_id
        for iq in self.in_queues:
            if iq.parked_barrier == b.snapshot_id:
                iq.parked_barrier = None
        self._barrier_to_emit = None
        self.snapshot_in_progress = None
        self.state = RUNNING
        self.ssctx.ack(b.snapshot_id, self)
        return True

    # ------------------------------------------------------------- done/batch --
    def _on_queue_done(self, iq: InQueue) -> None:
        iq.done = True
        qi = self.in_queues.index(iq)
        new_ts = self.coalescer.queue_done(qi)
        if new_ts is not None:
            self._pending_wm = Watermark(new_ts)
            self._wm_processed = False
        ordinal_queues = [q for q in self.in_queues if q.ordinal == iq.ordinal]
        if all(q.done for q in ordinal_queues):
            self.processor.complete_edge(iq.ordinal)
        # a queue finishing can complete a pending barrier alignment
        if self.ssctx.guarantee != GUARANTEE_NONE:
            self._recheck_alignment()

    def _complete_slice(self) -> bool:
        done = self.processor.complete()
        self._flush_outbox()
        if done:
            self.state = EMITTING_DONE
        return True

    def _emit_done_slice(self) -> bool:
        for c in self.collectors:
            if not c.broadcast(DONE):
                return True
        self.state = DONE_STATE
        self.processor.close()
        self.ssctx.notify_exempt(self)
        return True

    # --------------------------------------------------------------- outbox --
    def _flush_outbox(self) -> bool:
        """Move outbox items to the edge collectors. Items go to every
        collector (one per out-edge); resumable under backpressure."""
        if len(self.outbox):
            self._pending_out.extend(
                (item, 0) for item in self.outbox.drain())
        progress = False
        while self._pending_out:
            item, start_c = self._pending_out[0]
            for ci in range(start_c, len(self.collectors)):
                if not self.collectors[ci].offer(item):
                    self._pending_out[0] = (item, ci)
                    return progress
            self._pending_out.popleft()
            self.items_out += 1
            progress = True
        return progress

    @property
    def is_done(self) -> bool:
        return self.state == DONE_STATE

    @property
    def is_snapshot_exempt(self) -> bool:
        """True when this tasklet can no longer receive a barrier: its
        inputs are exhausted (or it is a source that already finished)."""
        return self.state in (COMPLETING, EMITTING_DONE, DONE_STATE)

    def __repr__(self):  # pragma: no cover
        return f"Tasklet({self.name}, state={self.state})"


class CooperativeWorker:
    """One worker == one CPU core.  Steps its tasklets round-robin.

    Tracks per-tasklet wall time: a tasklet that hogs its slice (violating
    the paper's <1 ms cooperative budget) is a *straggler* — the report
    feeds the ops playbook (move the vertex to a non-cooperative thread, or
    in the active-active deployment simply prefer the healthy replica)."""

    __slots__ = ("tasklets", "name", "_time_in", "slice_budget_s",
                 "budget_violations")

    def __init__(self, name: str, slice_budget_s: float = 0.001):
        self.name = name
        self.tasklets: List[ProcessorTasklet] = []
        self._time_in: dict = {}
        self.slice_budget_s = slice_budget_s
        self.budget_violations: dict = {}

    def add(self, tasklet: ProcessorTasklet) -> None:
        self.tasklets.append(tasklet)

    def run_iteration(self) -> bool:
        import time as _time
        progress = False
        for t in self.tasklets:
            if not t.is_done:
                t0 = _time.perf_counter()
                progress |= t.call()
                dt = _time.perf_counter() - t0
                self._time_in[t.name] = self._time_in.get(t.name, 0.0) + dt
                if dt > self.slice_budget_s:
                    self.budget_violations[t.name] = \
                        self.budget_violations.get(t.name, 0) + 1
        return progress

    def hot_tasklets(self, top: int = 5):
        """(name, cumulative_s, budget_violations) sorted by time."""
        return sorted(((n, s, self.budget_violations.get(n, 0))
                       for n, s in self._time_in.items()),
                      key=lambda x: -x[1])[:top]

    @property
    def all_done(self) -> bool:
        return all(t.is_done for t in self.tasklets)
