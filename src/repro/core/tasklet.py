"""Tasklets and the cooperative scheduler.

A :class:`ProcessorTasklet` adapts one processor instance to the cooperative
execution protocol: ``call()`` performs one short slice of work — drain some
input, run the processor, flush the outbox — and returns whether it made
progress.  A :class:`CooperativeWorker` owns a set of tasklets and steps
them round-robin, exactly the paper's "simply iterating over all tasklets
repeatedly works pretty well" (§3.2).

The tasklet also implements the two stream-protocol mechanisms that must be
engine-level, not processor-level:

* **watermark coalescing** across all input queues (min-rule), and
* **Chandy-Lamport barrier handling**: in exactly-once mode a queue that
  delivered barrier *n* is parked until every live input queue delivered
  barrier *n* (alignment), then the processor state is snapshotted and the
  barrier is forwarded; in at-least-once mode the first sighting snapshots
  immediately and nothing is parked.
"""

from __future__ import annotations

import pickle as _pickle
import time as _time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from .dag import PARTITION_COUNT, Routing, partitions_for_keys
from .events import (DONE, Barrier, DoneItem, Event, EventBlock, Watermark,
                     MIN_TIME)
from .processor import Inbox, Outbox, Processor
from .watermark import WatermarkCoalescer

#: max data items moved from one input queue per drain slice (the paper's
#: batch-at-a-time tasklet granularity; also bounds slice latency)
DRAIN_BATCH = 256
#: routing cache bound for partitioned collectors (key -> queue index)
ROUTE_CACHE_MAX = 8192

# tasklet lifecycle states
RUNNING = "running"
SAVING_SNAPSHOT = "saving_snapshot"
EMITTING_BARRIER = "emitting_barrier"
COMPLETING = "completing"
EMITTING_DONE = "emitting_done"
DONE_STATE = "done"

GUARANTEE_NONE = "none"
GUARANTEE_AT_LEAST_ONCE = "at_least_once"
GUARANTEE_EXACTLY_ONCE = "exactly_once"


class TaskletFailureError(Exception):
    """A processor raised out of its cooperative slice.

    The scheduler wraps the original exception so the execution substrate
    can tell *which* tasklet failed and route the event into failure
    detection (restart policy) instead of crashing the driver.  The
    original exception is chained as ``__cause__``."""

    def __init__(self, tasklet, cause: BaseException):
        super().__init__(f"tasklet {tasklet.name} failed: {cause!r}")
        self.tasklet = tasklet
        self.cause = cause


#: value types whose == / hash are content-based and process-independent
_ATOMIC_ID = (int, float, str, bytes, bool, type(None))


def _stable_id(v):
    """Content-based stand-in for one identity component.  Atomic values
    represent themselves; anything else is reduced to its pickle bytes
    (content-deterministic for the simple record types that flow through
    pipelines) — never the object's default repr/hash, whose embedded
    address would not survive a restart or a process boundary."""
    if type(v) in _ATOMIC_ID:
        return v
    try:
        return _pickle.dumps(v, protocol=4)
    except Exception:
        return repr(v)


def poison_identity(ev):
    """Stable, hashable identity of one event for poison-record
    quarantine: the same record must produce the same identity on every
    replay, in every process generation, so dead-letter filtering keyed
    on it survives restarts and cold starts."""
    return (ev.ts, _stable_id(ev.key), _stable_id(ev.value))


class InQueue:
    """One inbound queue (SPSC ring or the receiver side of a NetworkLink)
    plus its stream-protocol state."""

    __slots__ = ("q", "ordinal", "done", "parked_barrier", "seen_barrier",
                 "priority", "index")

    def __init__(self, q, ordinal: int, priority: int = 0):
        self.q = q
        self.ordinal = ordinal
        self.done = False
        #: position within the owning tasklet's ``in_queues`` (set by the
        #: tasklet; lets watermark/done handling skip an O(n) list.index)
        self.index = 0
        #: barrier id this queue is parked on (exactly-once alignment)
        self.parked_barrier: Optional[int] = None
        #: last barrier id delivered (at-least-once: no parking, but the
        #: snapshot still waits for the barrier on every live queue)
        self.seen_barrier: int = 0
        #: lower value drains first; higher-priority queues are not polled
        #: until every lower-value queue is done (hash-join build sides)
        self.priority = priority


class EdgeCollector:
    """Routes a tasklet's output items onto one out-edge's queues.

    ``queues[i]`` accepts ``offer(item) -> bool``; for distributed edges
    some of them are NetworkLink producers.  ``partition_to_queue`` maps a
    key partition to a queue index for PARTITIONED routing.  Control items
    (watermarks, barriers, DONE) are *broadcast* to every queue with
    resumable partial progress.
    """

    __slots__ = ("queues", "routing", "key_fn", "partition_to_queue",
                 "_rr_cursor", "_bc_item", "_bc_remaining", "_route_cache",
                 "_p2q_arr", "_blk_pending")

    def __init__(self, queues: Sequence, routing: str,
                 key_fn: Optional[Callable],
                 partition_to_queue: Optional[List[int]] = None):
        self.queues = list(queues)
        self.routing = routing
        self.key_fn = key_fn
        self.partition_to_queue = partition_to_queue
        self._rr_cursor = 0
        self._bc_item = None
        self._bc_remaining: List[int] = []
        #: key -> queue index memo (partitioned routing); bounded so a
        #: high-cardinality key space cannot grow it without limit
        self._route_cache: dict = {}
        #: vectorized partition->queue table (built on first block)
        self._p2q_arr = None
        #: (block, computed sub-blocks) awaiting all-or-nothing admission
        self._blk_pending = None

    # -- data items ---------------------------------------------------------
    def _queue_index_for(self, item) -> int:
        # canonical routing decision; offer_many's inner loop inlines this
        # body for speed — keep the two in sync
        key = self.key_fn(item) if self.key_fn else item.key
        cache = self._route_cache
        qi = cache.get(key)
        if qi is None:
            qi = self.partition_to_queue[hash(key) % PARTITION_COUNT]
            if len(cache) < ROUTE_CACHE_MAX:
                cache[key] = qi
        return qi

    def _offer_block(self, blk: EventBlock) -> bool:
        """Route one EventBlock onto a partitioned edge.

        The key column is hashed once (vectorized), rows are stably
        counting-sorted by destination queue, and each destination gets
        ONE sub-block with its rows in stream order — exactly the
        per-queue sequence the per-item protocol produces.  Delivery is
        all-or-nothing: every destination must have a free slot, else
        nothing is enqueued and the call retries later (the computed
        split is cached for the retry).
        """
        if not len(blk):
            return True
        pending = self._blk_pending
        if pending is not None and pending[0] is blk:
            parts = pending[1]
        else:
            if self.key_fn is None:
                pids = partitions_for_keys(blk.key)
            else:
                # a custom key extractor sees the EVENT (e.g. all_to_one's
                # constant key): materialize rows for it — rare path
                key_fn = self.key_fn
                pids = np.fromiter(
                    (hash(key_fn(ev)) % PARTITION_COUNT
                     for ev in blk.to_events()),
                    np.int64, len(blk))
            if self._p2q_arr is None:
                self._p2q_arr = np.asarray(self.partition_to_queue,
                                           dtype=np.int64)
            dests = self._p2q_arr[pids]
            first = dests[0]
            if (dests == first).all():
                parts = [(int(first), blk)]
            else:
                order = np.argsort(dests, kind="stable")
                sd = dests[order]
                starts = np.nonzero(
                    np.concatenate(([True], sd[1:] != sd[:-1])))[0]
                ends = np.append(starts[1:], len(sd))
                parts = [(int(sd[s]), blk.take(order[s:e]))
                         for s, e in zip(starts, ends)]
        qs = self.queues
        # every destination must guarantee admission of ITS sub-block before
        # anything is enqueued; has_room_for (not a slot count) is what makes
        # this sound on byte-capacity transports like the shm ring
        for qi, sub in parts:
            if not qs[qi].has_room_for(sub):
                self._blk_pending = (blk, parts)
                return False
        for qi, sub in parts:
            qs[qi].offer(sub)
        self._blk_pending = None
        return True

    def offer(self, item: Event) -> bool:
        r = self.routing
        if r == Routing.PARTITIONED:
            if item.__class__ is EventBlock:
                return self._offer_block(item)
            return self.queues[self._queue_index_for(item)].offer(item)
        if r == Routing.ROUND_ROBIN:
            n = len(self.queues)
            for i in range(n):
                qi = (self._rr_cursor + i) % n
                if self.queues[qi].offer(item):
                    self._rr_cursor = (qi + 1) % n
                    return True
            return False
        if r == Routing.ISOLATED:
            return self.queues[0].offer(item)
        # BROADCAST of data items uses the same resumable path as control
        return self.broadcast(item)

    def offer_control(self, item) -> bool:
        """Forward a control item emitted by a *source* outbox (watermark).

        On a keyed edge the item applies to every partition, so it is
        broadcast; otherwise it follows the same routing a data item would
        (the seed behaviour)."""
        if self.routing == Routing.PARTITIONED:
            return self.broadcast(item)
        return self.offer(item)

    def offer_many(self, items: List[Any], start: int = 0,
                   end: Optional[int] = None) -> int:
        """Route ``items[start:end]`` in order; returns the count accepted.

        Items are moved as runs: a contiguous stretch headed for the same
        destination queue is handed over in one bulk ``offer_many`` instead
        of one call per item.  Routing decisions are identical to
        :meth:`offer`, so a prefix accepted here equals the same prefix
        offered item-at-a-time.
        """
        r = self.routing
        qs = self.queues
        n = len(items) if end is None else end
        if start >= n:
            return 0
        if r == Routing.ISOLATED or len(qs) == 1 and r != Routing.BROADCAST:
            # single destination: routing cannot differ per item
            return qs[0].offer_many(items, start, n)
        if r == Routing.PARTITIONED:
            key_fn = self.key_fn
            p2q = self.partition_to_queue
            cache = self._route_cache
            cache_get = cache.get
            dest_of = self._queue_index_for
            i = start
            while i < n:
                item = items[i]
                if item.__class__ is EventBlock:
                    if not self._offer_block(item):
                        break
                    i += 1
                    continue
                qi = dest_of(item)
                j = i + 1
                while j < n:
                    nxt = items[j]
                    if nxt.__class__ is EventBlock:
                        break
                    key = key_fn(nxt) if key_fn is not None else nxt.key
                    q2 = cache_get(key)
                    if q2 is None:
                        q2 = p2q[hash(key) % PARTITION_COUNT]
                        if len(cache) < ROUTE_CACHE_MAX:
                            cache[key] = q2
                    if q2 != qi:
                        break
                    j += 1
                if j == i + 1:      # runs of one: plain offer is cheaper
                    if not qs[qi].offer(item):
                        break
                    i = j
                else:
                    run = j - i
                    accepted = qs[qi].offer_many(items, i, j)
                    i += accepted
                    if accepted < run:
                        break       # destination full: stop at this item
            return i - start
        # ROUND_ROBIN spreads per item and BROADCAST needs the resumable
        # per-item protocol: fall back to the exact item-at-a-time logic
        i = start
        if r == Routing.ROUND_ROBIN:
            while i < n and self.offer(items[i]):
                i += 1
        else:
            while i < n and self.broadcast(items[i]):
                i += 1
        return i - start

    # -- control items --------------------------------------------------------
    def broadcast(self, item) -> bool:
        """Offer ``item`` to every queue; resumable under backpressure.
        Only one broadcast may be in flight per collector at a time."""
        if self._bc_item is not item:
            self._bc_item = item
            self._bc_remaining = list(range(len(self.queues)))
        still = []
        for qi in self._bc_remaining:
            if not self.queues[qi].offer(item):
                still.append(qi)
        self._bc_remaining = still
        if not still:
            self._bc_item = None
            return True
        return False


class SnapshotContext:
    """Shared per-job snapshot coordination state (one per execution).

    A snapshot completes when every tasklet has either acked its barrier or
    become exempt (a tasklet whose inputs are exhausted will never see a
    barrier; its state is final and empty of in-flight work)."""

    __slots__ = ("guarantee", "requested_id", "writer", "tasklets", "_acked",
                 "completed_id", "on_complete", "terminal_requested",
                 "aborted_count")

    def __init__(self, guarantee: str, writer=None):
        self.guarantee = guarantee
        self.requested_id = 0       # bumped by the coordinator
        self.writer = writer        # SnapshotWriter (state backend)
        self.tasklets: List = []
        self._acked: set = set()
        self.completed_id = 0
        self.on_complete: Optional[Callable[[int], None]] = None
        self.terminal_requested = False
        #: snapshots abandoned without commit (barrier ack timeout, worker
        #: death mid-barrier); the last *committed* snapshot stays
        #: authoritative for recovery
        self.aborted_count = 0

    def check_timeout(self) -> bool:
        """Abort the in-flight snapshot if its barrier acks are overdue;
        returns True when an abort happened.  The in-process context acks
        via direct calls on this thread — a barrier here cannot be lost,
        only slow — so the base implementation never aborts.  Contexts
        whose acks cross a process boundary (``MpSnapshotContext``)
        override this with a real deadline."""
        return False

    def retire_aborted(self) -> None:
        """Destroy the IMap storage of a snapshot that began but will
        never commit (execution torn down mid-barrier).  Without this the
        partially-written ``__jet.snapshot.<job>.<id>`` map of every
        abandoned epoch leaks for the life of the cluster.  Idempotent;
        a no-op when nothing is in flight."""
        if self.writer is None or self.completed_id == self.requested_id:
            return
        self.writer.store._map(self.writer.job_id,
                               self.requested_id).destroy()

    def begin(self, snapshot_id: int) -> None:
        self.requested_id = snapshot_id
        self._acked = set()
        self._check()

    def ack(self, snapshot_id: int, tasklet) -> None:
        if snapshot_id != self.requested_id:
            return
        self._acked.add(id(tasklet))
        self._check()

    def notify_exempt(self, tasklet) -> None:
        """A tasklet entered a terminal phase; re-evaluate completion."""
        self._check()

    def _check(self) -> None:
        if self.completed_id == self.requested_id:
            return
        if all(id(t) in self._acked or t.is_snapshot_exempt
               for t in self.tasklets):
            self.completed_id = self.requested_id
            if self.on_complete is not None:
                self.on_complete(self.completed_id)


class ProcessorTasklet:
    """Drives one processor instance through the cooperative protocol."""

    def __init__(self, name: str, processor: Processor,
                 in_queues: List[InQueue],
                 collectors: List[EdgeCollector],
                 ssctx: SnapshotContext,
                 vertex_name: str,
                 global_index: int,
                 snapshot_pid_fn: Optional[Callable[[Any], int]] = None,
                 is_source: bool = False,
                 poison_ids: Optional[frozenset] = None,
                 pinpoint: bool = False):
        self.name = name
        self.processor = processor
        self.in_queues = in_queues
        self.collectors = collectors
        self.ssctx = ssctx
        self.vertex_name = vertex_name
        self.global_index = global_index
        self.is_source = is_source or not in_queues
        #: explode shim: a processor that does not declare
        #: ``accepts_blocks`` receives per-event explosions of any
        #: EventBlock (exploded at the queue boundary, where the drain's
        #: per-item type check already runs)
        self._explode_blocks = not getattr(processor, "accepts_blocks",
                                           False)
        #: quarantined record identities for this vertex (the engine's
        #: dead-letter escalation, see ``DeadLetterQueue``): events whose
        #: :func:`poison_identity` matches are dropped before the
        #: processor sees them
        self._poison_ids = frozenset(poison_ids) if poison_ids else None
        #: pinpoint mode: this vertex crashed before and the offending
        #: record is not yet known — the processor is fed ONE item per
        #: call so a recurrence is attributable to the exact in-flight
        #: record (``_process_pinpoint``)
        self._pinpoint = pinpoint
        if self._poison_ids is not None or pinpoint:
            # both modes need per-event granularity: a quarantined or
            # suspect record inside an EventBlock must be addressable
            self._explode_blocks = True
        #: events dropped by quarantine (dead-letter accounting checks)
        self.poison_dropped = 0
        #: optional non-blocking pump for processors driving asynchronous
        #: device work (core/device_window.py): called once per RUNNING
        #: slice even when no input is pending, so finished device futures
        #: are harvested without ever blocking the cooperative loop
        self._poll_async = getattr(processor, "poll_async", None)
        for i, iq in enumerate(in_queues):
            iq.index = i
        # per-ordinal inboxes
        max_ord = max((iq.ordinal for iq in in_queues), default=-1)
        self.inboxes = [Inbox() for _ in range(max_ord + 1)]
        #: running count of non-empty inboxes — kept in sync at the two
        #: places inboxes mutate (drain refills them, ``process`` consumes
        #: them) so the per-call "all inboxes empty?" checks are O(1)
        self._nonempty_inboxes = 0
        self.outbox = Outbox()
        #: flushed-but-not-yet-forwarded items (list + cursor: the batched
        #: flush consumes a prefix without per-item deque churn)
        self._pend_items: List[Any] = []
        self._pend_pos = 0
        self._pend_col = 0
        #: fan-out flush: per-collector count of items accepted beyond
        #: ``_pend_pos`` within the current data run (the shared cursor
        #: advances by the minimum)
        self._pend_col_offs: List[int] = [0] * len(collectors)
        self._pending_wm: Optional[Watermark] = None
        self._wm_processed = False
        self.coalescer = WatermarkCoalescer(len(in_queues)) if in_queues else None
        self.state = RUNNING
        self.snapshot_in_progress: Optional[int] = None
        #: snapshot id waiting for the inboxes to drain before it may start
        self._armed_snapshot: Optional[int] = None
        self.last_snapshot_id = 0
        self._snapshot_pid_fn = snapshot_pid_fn
        self._queue_cursor = 0
        self._barrier_to_emit: Optional[Barrier] = None
        #: fault injection (runtime/chaos.py): an exception planted here is
        #: raised at the top of the next slice, indistinguishable from the
        #: processor itself failing — the seam every chaos "raise" fault
        #: uses on both substrates
        self._chaos_exc: Optional[BaseException] = None
        # stats
        self.items_in = 0
        self.items_out = 0
        self.calls = 0
        self.idle_calls = 0

    # ------------------------------------------------------------------ call --
    def call(self) -> bool:
        """One execution slice; returns True when progress was made."""
        if self._chaos_exc is not None:
            exc, self._chaos_exc = self._chaos_exc, None
            raise exc
        self.calls += 1
        progress = False

        # 1. flush anything already produced
        if self._pend_pos < len(self._pend_items) or len(self.outbox):
            progress |= self._flush_outbox()
            if self._pend_pos < len(self._pend_items):
                self.idle_calls += not progress
                return progress

        # 2. pending watermark: only once every already-drained item has been
        #    processed (all data <= a coalesced watermark is in the inboxes
        #    by the time it advances, so this ordering is what makes window
        #    emission see complete frames)
        if self._pending_wm is not None and not self._nonempty_inboxes:
            if not self._forward_watermark():
                self.idle_calls += not progress
                return progress
            progress = True

        st = self.state
        if st == RUNNING:
            progress |= self._run_slice()
        elif st == SAVING_SNAPSHOT:
            progress |= self._save_snapshot_slice()
        elif st == EMITTING_BARRIER:
            progress |= self._emit_barrier_slice()
        elif st == COMPLETING:
            progress |= self._complete_slice()
        elif st == EMITTING_DONE:
            progress |= self._emit_done_slice()
        if not progress:
            self.idle_calls += 1
        return progress

    # -------------------------------------------------------------- running --
    def _run_slice(self) -> bool:
        progress = False
        # source tasklets: react to coordinator-initiated snapshots
        if self.is_source and self.ssctx.guarantee != GUARANTEE_NONE:
            if self.ssctx.requested_id > self.last_snapshot_id:
                self._begin_snapshot(self.ssctx.requested_id)
                return True

        if self.is_source:
            # streaming/batch sources do their work in complete()
            done = self.processor.complete()
            emitted = self._flush_outbox()
            progress |= emitted
            if done:
                self.state = EMITTING_DONE
                return True
            return progress

        progress |= self._drain_inputs()
        # run the processor over non-empty inboxes
        if self._nonempty_inboxes:
            for ordinal, inbox in enumerate(self.inboxes):
                before = len(inbox)
                if before:
                    if self._poison_ids is not None:
                        self._drop_quarantined(inbox)
                    if not len(inbox):
                        pass        # the whole batch was quarantined
                    elif self._pinpoint:
                        self._process_pinpoint(ordinal, inbox)
                    else:
                        self.processor.process(ordinal, inbox)
                    after = len(inbox)
                    if not after:
                        self._nonempty_inboxes -= 1
                    progress |= after != before or len(self.outbox) > 0
                    if len(self.outbox):
                        self._flush_outbox()
        # asynchronous-device processors: harvest finished futures (the
        # pump is non-blocking; device completions happen off-thread)
        if self._poll_async is not None:
            progress |= self._poll_async()
            if len(self.outbox):
                self._flush_outbox()
        # watermark became due after this slice's inbox processing
        if self._pending_wm is not None and not self._nonempty_inboxes:
            progress |= self._forward_watermark()
        # a snapshot armed by a barrier starts only once every pre-barrier
        # item has been fully processed and emitted (consistency of the cut)
        if (self._armed_snapshot is not None
                and not self._nonempty_inboxes
                and self._pend_pos >= len(self._pend_items)
                and not len(self.outbox)):
            sid = self._armed_snapshot
            self._armed_snapshot = None
            self._begin_snapshot(sid)
            return True
        # all inputs done?
        if (self.state == RUNNING and self.in_queues
                and not self._nonempty_inboxes
                and all(iq.done for iq in self.in_queues)):
            self.state = COMPLETING
            self.ssctx.notify_exempt(self)
            progress = True
        return progress

    def _drop_quarantined(self, inbox) -> None:
        """Filter dead-lettered records out of the inbox before the
        processor runs (exactly-once on the surviving stream: the
        quarantined record is accounted for in the engine's dead-letter
        queue, never processed, never lost twice)."""
        ids = self._poison_ids
        items = inbox._items
        kept = [it for it in items
                if not isinstance(it, Event) or poison_identity(it) not in ids]
        dropped = len(items) - len(kept)
        if dropped:
            self.poison_dropped += dropped
            items.clear()
            items.extend(kept)

    def _process_pinpoint(self, ordinal: int, inbox) -> None:
        """Feed the processor one item per call.  Some processors pop
        items only after a successful step, others pop first — so at a
        raise the inbox head is not a reliable culprit.  With exactly one
        item in the inbox there is no ambiguity: a raise stamps that
        record onto the exception (``_jet_poison``), which rides the
        failure report to the engine's escalation ladder."""
        items = inbox._items
        head = items[0]
        rest = None
        if len(items) > 1:
            items.popleft()
            rest = list(items)
            items.clear()
            items.append(head)
        try:
            self.processor.process(ordinal, inbox)
        except BaseException as exc:
            if (isinstance(head, Event)
                    and getattr(exc, "_jet_poison", None) is None):
                try:
                    exc._jet_poison = {"vertex": self.vertex_name,
                                       "identity": poison_identity(head),
                                       "record": repr(head),
                                       "exact": True}
                except AttributeError:      # exception types with __slots__
                    pass
            raise
        finally:
            if rest:
                items.extend(rest)

    def _drain_inputs(self) -> bool:
        """Drain input queues round-robin in batched slices.

        Data events move as one bulk ``poll_prefix`` per queue (the queue
        segregates the leading run of events from the first control item),
        so the per-item cost is one type check inside the queue instead of
        a poll/isinstance/add round-trip per item.  Control items are still
        handled one at a time, in arrival order, exactly as the seed
        item-at-a-time loop did.
        """
        progress = False
        in_queues = self.in_queues
        n = len(in_queues)
        exactly_once = self.ssctx.guarantee == GUARANTEE_EXACTLY_ONCE
        # priority edges: only drain the lowest not-yet-done priority class
        cur_priority = min((iq.priority for iq in in_queues
                            if not iq.done), default=0)
        cursor = self._queue_cursor
        inboxes = self.inboxes
        for i in range(n):
            iq = in_queues[(cursor + i) % n]
            if iq.done or iq.parked_barrier is not None:
                continue
            if iq.priority > cur_priority:
                continue
            events, ctrl = iq.q.poll_prefix(DRAIN_BATCH,
                                            self._explode_blocks)
            if events:
                progress = True
                self.items_in += len(events)
                inbox = inboxes[iq.ordinal]
                if not len(inbox):
                    self._nonempty_inboxes += 1
                inbox.extend(events)
            if ctrl is not None:
                progress = True
                if isinstance(ctrl, Watermark):
                    self._on_watermark(iq, ctrl)
                elif isinstance(ctrl, Barrier):
                    iq.seen_barrier = ctrl.snapshot_id
                    if exactly_once:
                        iq.parked_barrier = ctrl.snapshot_id
                    self._recheck_alignment(ctrl.snapshot_id)
                elif isinstance(ctrl, DoneItem):
                    self._on_queue_done(iq)
        self._queue_cursor = (cursor + 1) % max(n, 1)
        return progress

    # ------------------------------------------------------------ watermarks --
    def _on_watermark(self, iq: InQueue, wm: Watermark) -> None:
        new_ts = self.coalescer.observe(iq.index, wm.ts)
        if new_ts is not None:
            self._pending_wm = Watermark(new_ts)
            self._wm_processed = False

    def _forward_watermark(self) -> bool:
        wm = self._pending_wm
        if not self._wm_processed:
            if not self.processor.try_process_watermark(wm):
                self._flush_outbox()
                return False
            self._wm_processed = True
            self._flush_outbox()
        for c in self.collectors:
            if not c.broadcast(wm):
                return False
        self._pending_wm = None
        return True

    # -------------------------------------------------------------- barriers --
    def _recheck_alignment(self, snapshot_id: Optional[int] = None) -> None:
        """Arm the snapshot once barrier ``snapshot_id`` was delivered on
        every live queue.  Exactly-once additionally parks queues that are
        already past the barrier (done in ``_drain_inputs``); at-least-once
        keeps draining them, accepting replay-duplicates."""
        if snapshot_id is None:
            ids = [iq.seen_barrier for iq in self.in_queues
                   if not iq.done and iq.seen_barrier > self.last_snapshot_id]
            if not ids:
                return
            snapshot_id = min(ids)
        if snapshot_id <= self.last_snapshot_id:
            return
        live = [iq for iq in self.in_queues if not iq.done]
        if live and all(iq.seen_barrier >= snapshot_id for iq in live):
            self._armed_snapshot = snapshot_id

    def _begin_snapshot(self, snapshot_id: int) -> None:
        self.snapshot_in_progress = snapshot_id
        self.state = SAVING_SNAPSHOT

    def _save_snapshot_slice(self) -> bool:
        # transactional sinks key their prepared buffers by snapshot id
        self.processor.current_snapshot_id = self.snapshot_in_progress
        ok = self.processor.save_to_snapshot()
        # drain snapshotted state into the store
        writer = self.ssctx.writer
        if writer is not None:
            for key, value in self.outbox.snapshot_queue:
                pid = (self._snapshot_pid_fn(key)
                       if self._snapshot_pid_fn is not None else None)
                if pid is None:
                    pid = hash(key) % PARTITION_COUNT
                writer.put(self.snapshot_in_progress, self.vertex_name,
                           key, value, pid, instance=self.global_index)
        self.outbox.snapshot_queue.clear()
        self._flush_outbox()
        if ok:
            self._barrier_to_emit = Barrier(self.snapshot_in_progress)
            self.state = EMITTING_BARRIER
        return True

    def _emit_barrier_slice(self) -> bool:
        b = self._barrier_to_emit
        for c in self.collectors:
            if not c.broadcast(b):
                return True  # made progress, still emitting
        # barrier fully forwarded: unpark queues, ack, resume
        self.last_snapshot_id = b.snapshot_id
        for iq in self.in_queues:
            if iq.parked_barrier == b.snapshot_id:
                iq.parked_barrier = None
        self._barrier_to_emit = None
        self.snapshot_in_progress = None
        self.state = RUNNING
        self.ssctx.ack(b.snapshot_id, self)
        return True

    # ------------------------------------------------------------- done/batch --
    def _on_queue_done(self, iq: InQueue) -> None:
        iq.done = True
        new_ts = self.coalescer.queue_done(iq.index)
        if new_ts is not None:
            self._pending_wm = Watermark(new_ts)
            self._wm_processed = False
        ordinal_queues = [q for q in self.in_queues if q.ordinal == iq.ordinal]
        if all(q.done for q in ordinal_queues):
            self.processor.complete_edge(iq.ordinal)
        # a queue finishing can complete a pending barrier alignment
        if self.ssctx.guarantee != GUARANTEE_NONE:
            self._recheck_alignment()

    def _complete_slice(self) -> bool:
        done = self.processor.complete()
        self._flush_outbox()
        if done:
            self.state = EMITTING_DONE
        return True

    def _emit_done_slice(self) -> bool:
        for c in self.collectors:
            if not c.broadcast(DONE):
                return True
        self.state = DONE_STATE
        self.processor.close()
        self.ssctx.notify_exempt(self)
        return True

    # --------------------------------------------------------------- outbox --
    def _flush_outbox(self) -> bool:
        """Move outbox items to the edge collectors. Items go to every
        collector (one per out-edge); resumable under backpressure.

        Single out-edge (the overwhelmingly common shape) forwards the
        whole pending slice with one bulk ``offer_many``; fan-out keeps
        the per-item resumable protocol."""
        items, pos = self._pend_items, self._pend_pos
        if len(self.outbox):
            drained = self.outbox.drain()
            if pos >= len(items):
                items = self._pend_items = drained
                pos = self._pend_pos = 0
                self._pend_col = 0
            else:
                items.extend(drained)
        n = len(items)
        if pos >= n:
            return False
        collectors = self.collectors
        progress = False
        if len(collectors) == 1:
            c = collectors[0]
            if not self.is_source:
                # non-source outboxes hold only data events: pure bulk path
                accepted = c.offer_many(items, pos)
                if accepted:
                    progress = True
                    pos += accepted
                    self.items_out += accepted
            else:
                # a source outbox interleaves watermarks with events:
                # forward runs of events in bulk, control items one by one
                while pos < n:
                    item = items[pos]
                    cls = item.__class__
                    if (cls is Event or cls is EventBlock
                            or isinstance(item, (Event, EventBlock))):
                        j = pos + 1
                        while j < n:
                            nxt = items[j]
                            ncls = nxt.__class__
                            if not (ncls is Event or ncls is EventBlock
                                    or isinstance(nxt, (Event, EventBlock))):
                                break
                            j += 1
                        accepted = c.offer_many(items, pos, j)
                        if accepted:
                            progress = True
                            pos += accepted
                            self.items_out += accepted
                        if pos < j:
                            break
                    else:
                        if not c.offer_control(item):
                            break
                        progress = True
                        pos += 1
                        self.items_out += 1
        else:
            # fan-out: every item goes to every collector before the shared
            # cursor advances.  Runs of data events move in bulk per
            # collector with independent progress (``_pend_col_offs``); the
            # cursor advances by the minimum across collectors, so each
            # queue still sees the exact per-item sequence it would have
            # seen under the per-item protocol.
            offs = self._pend_col_offs
            n_cols = len(collectors)
            is_source = self.is_source
            if not n_cols:
                # terminal vertex with no out-edges: consume silently, the
                # behaviour of the per-item loop this path replaced
                self.items_out += n - pos
                pos = n
                progress = True
            while pos < n:
                item = items[pos]
                # a fused source with fan-out can interleave watermarks
                # here too: they must take the control route on keyed edges
                if is_source and not (item.__class__ is Event
                                      or item.__class__ is EventBlock
                                      or isinstance(item,
                                                    (Event, EventBlock))):
                    col = self._pend_col
                    blocked = False
                    while col < n_cols:
                        if not collectors[col].offer_control(item):
                            blocked = True
                            break
                        col += 1
                    self._pend_col = col
                    if blocked:
                        break
                    self._pend_col = 0
                    pos += 1
                    self.items_out += 1
                    progress = True
                    continue
                # maximal run of data events starting at pos
                if is_source:
                    j = pos + 1
                    while j < n and (items[j].__class__ is Event
                                     or items[j].__class__ is EventBlock
                                     or isinstance(items[j],
                                                   (Event, EventBlock))):
                        j += 1
                else:
                    j = n
                run = j - pos
                blocked = False
                for ci in range(n_cols):
                    if offs[ci] < run:
                        offs[ci] += collectors[ci].offer_many(
                            items, pos + offs[ci], j)
                        if offs[ci] < run:
                            blocked = True
                adv = min(offs)
                if adv:
                    pos += adv
                    for ci in range(n_cols):
                        offs[ci] -= adv
                    self.items_out += adv
                    progress = True
                if blocked:
                    break
        if pos >= n:
            self._pend_items = []
            self._pend_pos = 0
        else:
            self._pend_pos = pos
        return progress

    @property
    def is_done(self) -> bool:
        return self.state == DONE_STATE

    @property
    def is_snapshot_exempt(self) -> bool:
        """True when this tasklet can no longer receive a barrier: its
        inputs are exhausted (or it is a source that already finished)."""
        return self.state in (COMPLETING, EMITTING_DONE, DONE_STATE)

    def __repr__(self):  # pragma: no cover
        return f"Tasklet({self.name}, state={self.state})"


class CooperativeWorker:
    """One worker == one CPU core.  Steps its tasklets round-robin.

    Tracks per-tasklet wall time: a tasklet that hogs its slice (violating
    the paper's <1 ms cooperative budget) is a *straggler* — the report
    feeds the ops playbook (move the vertex to a non-cooperative thread, or
    in the active-active deployment simply prefer the healthy replica)."""

    __slots__ = ("tasklets", "name", "_time_in", "slice_budget_s",
                 "budget_violations", "_iterations")

    #: every iteration in this initial window is fully timed (catches
    #: stragglers in short-lived jobs before sampling kicks in)
    TIMING_WARMUP_ITERS = 512
    #: after warmup, one iteration in this many is timed; recorded time is
    #: scaled by the period so cumulative numbers stay comparable
    TIMING_SAMPLE_PERIOD = 32

    def __init__(self, name: str, slice_budget_s: float = 0.001):
        self.name = name
        self.tasklets: List[ProcessorTasklet] = []
        self._time_in: dict = {}
        self.slice_budget_s = slice_budget_s
        self.budget_violations: dict = {}
        self._iterations = 0

    def add(self, tasklet: ProcessorTasklet) -> None:
        self.tasklets.append(tasklet)

    def run_iteration(self) -> bool:
        """Step every live tasklet once.

        ``perf_counter`` pairs around every tasklet call used to be the
        scheduler's single biggest fixed cost; timing is now *sampled* —
        full coverage during a warmup window, then 1-in-N iterations —
        which keeps straggler detection while taking the clock calls off
        the steady-state hot path."""
        self._iterations = it = self._iterations + 1
        if it <= self.TIMING_WARMUP_ITERS:
            return self._run_iteration_timed(1)
        if not it % self.TIMING_SAMPLE_PERIOD:
            return self._run_iteration_timed(self.TIMING_SAMPLE_PERIOD)
        progress = False
        for t in self.tasklets:
            if not t.is_done:
                try:
                    progress |= t.call()
                except Exception as e:
                    raise TaskletFailureError(t, e) from e
        return progress

    def _run_iteration_timed(self, weight: int) -> bool:
        perf = _time.perf_counter
        time_in = self._time_in
        budget = self.slice_budget_s
        progress = False
        for t in self.tasklets:
            if not t.is_done:
                t0 = perf()
                try:
                    progress |= t.call()
                except Exception as e:
                    raise TaskletFailureError(t, e) from e
                dt = perf() - t0
                time_in[t.name] = time_in.get(t.name, 0.0) + dt * weight
                if dt > budget:
                    self.budget_violations[t.name] = \
                        self.budget_violations.get(t.name, 0) + 1
        return progress

    def hot_tasklets(self, top: int = 5):
        """(name, cumulative_s_estimate, budget_violations) sorted by time.
        Times are sampled estimates once the warmup window has passed."""
        return sorted(((n, s, self.budget_violations.get(n, 0))
                       for n, s in self._time_in.items()),
                      key=lambda x: -x[1])[:top]

    @property
    def all_done(self) -> bool:
        return all(t.is_done for t in self.tasklets)
