"""Distributed-edge flow control: the adaptive receive window.

Local backpressure is just a full SPSC queue.  Across node boundaries Jet
uses a credit scheme modelled on the TCP receive window (paper §3.3): the
producer may send up to ``acked_seq + receive_window`` items; the consumer
acks every ``ACK_INTERVAL`` (100 ms) and sizes the window to roughly
``WINDOW_FILL_FACTOR`` (3×) the number of items it processed during the
last interval — i.e. ~300 ms worth of flow in steady state.

:class:`NetworkLink` simulates one ordered link between a producer instance
and a consumer instance on different nodes, with configurable one-way
latency.  The engine pumps links every scheduler iteration.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Deque, Optional, Tuple

from .clock import Clock
from .events import Event, EventBlock

ACK_INTERVAL_S = 0.1
WINDOW_FILL_FACTOR = 3
MIN_RECEIVE_WINDOW = 16
MAX_RECEIVE_WINDOW = 1 << 16


class NetworkLink:
    """Ordered, latency-ful, credit-flow-controlled SPSC link."""

    __slots__ = ("clock", "latency", "_in_flight", "_recv", "recv_capacity",
                 "sent_seq", "acked_seq", "receive_window", "_processed",
                 "_last_ack", "bytes_sent", "items_sent")

    def __init__(self, clock: Clock, latency_s: float = 0.0005,
                 recv_capacity: int = 4096,
                 initial_window: int = 1024):
        self.clock = clock
        self.latency = latency_s
        self._in_flight: Deque[Tuple[float, Any]] = deque()
        self._recv: Deque[Any] = deque()
        self.recv_capacity = recv_capacity
        self.sent_seq = 0          # items pushed by producer
        self.acked_seq = 0         # items the consumer has acknowledged
        self.receive_window = initial_window
        self._processed = 0        # consumed since last ack
        self._last_ack = clock.now()
        self.bytes_sent = 0
        self.items_sent = 0

    # -- producer side ---------------------------------------------------------
    def offer(self, item) -> bool:
        """Send if credit allows. False == remote backpressure."""
        if self.sent_seq >= self.acked_seq + self.receive_window:
            return False
        self._in_flight.append((self.clock.now() + self.latency, item))
        self.sent_seq += 1
        self.items_sent += 1
        return True

    def offer_many(self, items, start: int = 0, end=None) -> int:
        """Send a batch while credit allows; returns the count accepted."""
        n = (len(items) if end is None else end) - start
        credit = self.acked_seq + self.receive_window - self.sent_seq
        if n > credit:
            n = credit
        if n <= 0:
            return 0
        due = self.clock.now() + self.latency
        self._in_flight.extend((due, it) for it in items[start:start + n])
        self.sent_seq += n
        self.items_sent += n
        return n

    def remaining_capacity(self) -> int:
        return max(0, self.acked_seq + self.receive_window - self.sent_seq)

    def has_room_for(self, item) -> bool:
        """Transport contract (see ``SPSCQueue``): one credit == one item."""
        return self.sent_seq < self.acked_seq + self.receive_window

    # -- consumer side ---------------------------------------------------------
    def poll(self) -> Optional[Any]:
        if not self._recv:
            return None
        self._processed += 1
        return self._recv.popleft()

    def poll_prefix(self, limit: int, explode_blocks: bool = False):
        """Batched control-aware drain; see ``SPSCQueue.poll_prefix``."""
        recv = self._recv
        n = len(recv)
        if limit < n:
            n = limit
        if n <= 0:
            return (), None
        events = []
        append = events.append
        extend = events.extend
        popleft = recv.popleft
        ctrl = None
        consumed = 0
        while consumed < n:
            item = recv[0]
            cls = item.__class__
            if cls is EventBlock:
                if explode_blocks:
                    extend(item.to_events())
                else:
                    append(item)
            elif cls is Event or isinstance(item, Event):
                append(item)
            else:
                ctrl = item
                popleft()
                consumed += 1
                break
            popleft()
            consumed += 1
        self._processed += consumed
        return events, ctrl

    def peek(self) -> Optional[Any]:
        return self._recv[0] if self._recv else None

    def __len__(self):
        return len(self._recv)

    def is_empty(self) -> bool:
        # empty for the consumer; in-flight items are not yet visible
        return not self._recv

    def pending_anywhere(self) -> bool:
        return bool(self._recv) or bool(self._in_flight)

    # -- engine pump -------------------------------------------------------------
    def pump(self) -> bool:
        """Deliver due in-flight items; run the ack protocol. Returns True
        if anything moved (progress tracking for the idle detector)."""
        now = self.clock.now()
        progress = False
        while (self._in_flight
               and self._in_flight[0][0] <= now
               and len(self._recv) < self.recv_capacity):
            self._recv.append(self._in_flight.popleft()[1])
            progress = True
        if now - self._last_ack >= ACK_INTERVAL_S:
            self._send_ack(now)
            progress = True
        return progress

    def _send_ack(self, now: float) -> None:
        """Consumer -> producer ack: advances acked_seq and adapts the
        receive window to ~3x the per-interval processing rate."""
        consumed_total = self.sent_seq - len(self._in_flight) - len(self._recv)
        self.acked_seq = consumed_total
        if self._processed > 0:
            target = self._processed * WINDOW_FILL_FACTOR
            # exponential move toward target, clamped
            self.receive_window = max(
                MIN_RECEIVE_WINDOW,
                min(MAX_RECEIVE_WINDOW, (self.receive_window + target) // 2))
        self._processed = 0
        self._last_ack = now
