"""Jet processors: the custom logic of a DAG vertex.

A :class:`Processor` consumes items from an :class:`Inbox` and emits to an
:class:`Outbox`.  The owning tasklet refills the inbox from the inbound
queues, repeatedly calls :meth:`Processor.process` until the inbox drains,
and flushes the outbox downstream.  A processor must tolerate its outbox
rejecting items (bounded capacity == backpressure): it returns with items
still in the inbox and is called again later.

This mirrors ``com.hazelcast.jet.core.Processor`` including the snapshot
hooks used by the Chandy-Lamport protocol.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable, Iterable, Iterator, List, Optional, Tuple

from .events import DONE, Event, EventBlock, Watermark


class Inbox:
    """A batch of input items from one edge ordinal."""

    __slots__ = ("_items",)

    def __init__(self):
        self._items: deque = deque()

    def add(self, item):
        self._items.append(item)

    def extend(self, items):
        """Bulk-append a drained slice (the tasklet's batched refill)."""
        self._items.extend(items)

    def peek(self):
        return self._items[0] if self._items else None

    def poll(self):
        return self._items.popleft() if self._items else None

    def remove(self):
        self._items.popleft()

    def clear(self):
        self._items.clear()

    def __len__(self):
        return len(self._items)

    def __iter__(self) -> Iterator:
        return iter(self._items)

    def is_empty(self) -> bool:
        return not self._items


class Outbox:
    """Bounded emission buffer; ``offer`` returning False == backpressure.

    The tasklet drains the outbox into the outbound edge queues between
    ``process`` calls.  ``batch_limit`` bounds the number of items buffered
    per execution slice, which also bounds tasklet latency (a slice stays
    under ~1 ms of work, the paper's cooperative-yield budget).
    """

    __slots__ = ("_items", "_limit", "snapshot_queue")

    def __init__(self, batch_limit: int = 512):
        self._items: List[Any] = []
        self._limit = batch_limit
        # (key, value) pairs captured by save_to_snapshot(); drained by the
        # tasklet into the snapshot store.
        self.snapshot_queue: List[Tuple[Any, Any]] = []

    def offer(self, item) -> bool:
        items = self._items
        if len(items) >= self._limit:
            return False
        items.append(item)
        return True

    def space(self) -> int:
        """Slots left before the batch limit (bulk-emitting producers size
        their run to this instead of probing ``offer`` per item)."""
        return self._limit - len(self._items)

    def extend(self, items) -> None:
        """Bulk-append a pre-sized run (caller respects :meth:`space`)."""
        self._items.extend(items)

    def offer_to_snapshot(self, key, value) -> bool:
        self.snapshot_queue.append((key, value))
        return True

    def drain(self) -> List[Any]:
        items, self._items = self._items, []
        return items

    def __len__(self):
        return len(self._items)


class ProcessorContext:
    """Runtime info handed to a processor at init time."""

    __slots__ = (
        "vertex_name",
        "global_index",
        "local_index",
        "total_parallelism",
        "node_id",
        "node_count",
        "partition_ids",
        "partition_count",
        "clock",
        "logger",
    )

    def __init__(self, vertex_name: str, global_index: int, local_index: int,
                 total_parallelism: int, node_id: int, node_count: int,
                 partition_ids: Tuple[int, ...], partition_count=None,
                 clock=None, logger=None):
        self.vertex_name = vertex_name
        self.global_index = global_index
        self.local_index = local_index
        self.total_parallelism = total_parallelism
        self.node_id = node_id
        self.node_count = node_count
        # partitions owned by this processor instance (for keyed state)
        self.partition_ids = partition_ids
        # cluster-wide partition count (None when the embedding harness
        # does not partition state); lets a processor address partitions
        # it does NOT own, e.g. to replicate replay offsets everywhere
        self.partition_count = partition_count
        self.clock = clock
        self.logger = logger


class Processor:
    """Base processor. Subclasses override the hooks they need.

    **State declarations** — the snapshot contract is machine-checked
    (``python -m repro.analysis``, see ROADMAP "Machine-checked
    contracts"): every ``self.*`` attribute a subclass mutates on the hot
    path (``process`` / ``process_block`` / ``try_process_watermark`` /
    ``complete`` / ``complete_edge`` / ``poll_async``) must be written in
    :meth:`save_to_snapshot` and read back in
    :meth:`restore_from_snapshot` / :meth:`finish_snapshot_restore`, or
    be declared in one of two class-level sets:

    * ``EPHEMERAL_STATE`` — attributes that legitimately do NOT survive a
      restart (rebuilt lazily, drained before every barrier, re-derived
      from replay, or pure telemetry).  Declare them with a comment
      saying *why* losing them is correct;
    * ``SNAPSHOT_STATE`` — attributes that ARE saved/restored but under a
      transformed name or route the checker's reference scan cannot
      follow (e.g. ``TransactionalSink.pending`` restores into
      ``prepared``).

    Declarations are unioned along the inheritance chain.  Everything
    else unaccounted for is a ``snapshot-missing-save`` /
    ``snapshot-missing-restore`` finding and fails CI.
    """

    #: hot-path mutable attributes that deliberately do not survive a
    #: restart (see class docstring); checked by repro.analysis
    EPHEMERAL_STATE: frozenset = frozenset()

    #: hot-path mutable attributes saved/restored under a transformed
    #: name the checker cannot trace (see class docstring)
    SNAPSHOT_STATE: frozenset = frozenset()

    #: False for processors that make blocking calls; the engine then runs
    #: them on a dedicated non-cooperative thread (paper §3.2).
    is_cooperative = True

    #: True for processors whose ``process`` understands
    #: :class:`~repro.core.events.EventBlock` items.  When False (the
    #: default) the owning tasklet explodes incoming blocks into per-event
    #: runs at the queue boundary, so a black-box processor keeps exact
    #: per-event semantics (the columnar fast path is opt-in per vertex).
    accepts_blocks = False

    def init(self, outbox: Outbox, ctx: ProcessorContext) -> None:
        self.outbox = outbox
        self.ctx = ctx

    # -- data path ----------------------------------------------------------
    def process(self, ordinal: int, inbox: Inbox) -> None:
        """Consume as much of the inbox as possible, emitting via outbox."""
        raise NotImplementedError

    def try_process_watermark(self, wm: Watermark) -> bool:
        """Return True when the watermark is fully handled and may be
        forwarded; False to be called again (backpressured emission)."""
        return True

    def complete_edge(self, ordinal: int) -> bool:
        """Called when an input edge is exhausted; True when done."""
        return True

    def complete(self) -> bool:
        """Called after ALL input edges are exhausted; return True when the
        processor has emitted everything (batch semantics)."""
        return True

    # -- snapshot hooks -------------------------------------------------------
    def save_to_snapshot(self) -> bool:
        """Emit state as (key, value) pairs via outbox.offer_to_snapshot.
        Return True when finished (may be re-called under backpressure)."""
        return True

    def restore_from_snapshot(self, items: Iterable[Tuple[Any, Any]]) -> None:
        """Reload state saved by :meth:`save_to_snapshot`."""

    def finish_snapshot_restore(self) -> None:
        """Called once after all snapshot items were restored."""

    def close(self) -> None:
        """Release resources at job end."""


# ---------------------------------------------------------------------------
# Built-in stateless processors (targets of the fusion planner)
# ---------------------------------------------------------------------------


class FusedFunctionProcessor(Processor):
    """Executes a fused chain of map/filter/flatMap functions.

    The pipeline planner collapses consecutive stateless stages into a single
    vertex running this processor — Jet's operator fusion (paper §3.1).  The
    chain is compiled once into a single Python closure so the per-event cost
    is one call, not one call per stage.

    When every step of the chain declares a block form the planner also
    hands over ``block_chain`` (EventBlock -> EventBlock | None) and the
    vertex becomes block-aware: whole blocks run as column ops, per-event
    cost drops to per-block cost.
    """

    def __init__(self, chain: Callable[[Event], Iterable[Event]],
                 block_chain: Optional[Callable] = None):
        # chain: Event -> iterable of Events (possibly empty)
        self._chain = chain
        self._block_chain = block_chain
        self.accepts_blocks = block_chain is not None

    def process(self, ordinal: int, inbox: Inbox) -> None:
        chain = self._chain
        ob = self.outbox
        out_items = ob._items
        limit = ob._limit
        # the tasklet segregates control items at the queue boundary, so the
        # inbox holds only data events: iterate the backing deque directly
        # and extend the outbox list in place (same emitted sequence as the
        # per-item offer loop; a flat_map may overshoot the batch limit by
        # its fan-out, which only shifts a batch boundary)
        items = inbox._items
        popleft = items.popleft
        extend = out_items.extend
        block_chain = self._block_chain
        if block_chain is None:
            while items:
                if len(out_items) >= limit:
                    return
                extend(chain(items[0]))
                popleft()
            return
        append = out_items.append
        while items:
            if len(out_items) >= limit:
                return
            item = items[0]
            if item.__class__ is EventBlock:
                out = block_chain(item)
                if out is not None and len(out):
                    append(out)
            else:
                extend(chain(item))
            popleft()


class MapProcessor(FusedFunctionProcessor):
    def __init__(self, fn: Callable[[Event], Event]):
        super().__init__(lambda ev: (fn(ev),))


class FilterProcessor(FusedFunctionProcessor):
    def __init__(self, pred: Callable[[Event], bool]):
        super().__init__(lambda ev: (ev,) if pred(ev) else ())


class FlatMapProcessor(FusedFunctionProcessor):
    def __init__(self, fn: Callable[[Event], Iterable[Event]]):
        super().__init__(fn)


class SinkProcessor(Processor):
    """Terminal vertex: hands events to a consumer callable.

    The consumer is typically a results collector (tests/benchmarks) or an
    external-system adapter (see repro.snapshot.sinks for transactional /
    idempotent variants).
    """

    def __init__(self, consumer: Callable[[Event], None]):
        self._consumer = consumer

    def process(self, ordinal: int, inbox: Inbox) -> None:
        consumer = self._consumer
        items = inbox._items
        popleft = items.popleft
        while items:
            consumer(popleft())
