"""Pluggable execution substrates for the Jet engine.

The engine core (:mod:`repro.core.engine`) is substrate-agnostic: it plans
executions, owns job lifecycle and snapshot *policy*, and delegates every
"how does work actually run" decision to an :class:`ExecutionBackend`.  Two
backends ship:

* :class:`InProcessBackend` (default) — the paper-faithful cooperative
  model with every worker stepped by one driver thread.  All queues are
  in-process (:class:`~repro.core.queues.SPSCQueue` locally,
  :class:`~repro.core.backpressure.NetworkLink` across simulated nodes).
* :class:`~repro.runtime.worker_proc.MultiprocessBackend` — each
  (node, cooperative-thread) pair becomes a real OS process; edges that
  cross a process boundary become shared-memory EventBlock rings
  (:class:`~repro.core.shm_ring.ShmRing`).

The backend contract
====================

A backend is bound to one :class:`~repro.core.engine.JetCluster` and is
consulted at four points of an execution's life:

**Build time** (inside ``ExecutionContext._build``):

* ``create_snapshot_context(job)`` returns the
  :class:`~repro.core.tasklet.SnapshotContext` coordinating barrier/ack
  bookkeeping for one execution attempt.  The in-process context acks
  synchronously; the multiprocess one broadcasts begin/committed over
  control pipes and collects acks (plus snapshot entries) from workers.
* ``make_transport(execution, edge, src, dst)`` returns the queue-like
  object carrying items from producer location ``src`` to consumer
  location ``dst`` (each a ``(node_id, worker_slot)`` pair).  The object
  must satisfy the transport contract documented on
  :class:`~repro.core.queues.SPSCQueue` (offer/offer_many/has_room_for/
  poll/peek/poll_prefix).
* ``assign_tasklet(execution, inst, tasklet)`` places a built tasklet on
  its worker (an in-process :class:`CooperativeWorker`, or a recorded
  (node, slot) -> process plan).

**Lifecycle**: ``start_execution`` runs after build *and after any
snapshot restore* (the multiprocess backend forks workers here, so
restored state is inherited by the children); ``stop_execution`` tears an
attempt down (remove tasklets from workers / terminate worker processes
and unlink rings).  Both must be idempotent.

**Driving**: ``step(jobs)`` performs one scheduler iteration of whatever
the backend owns (stepping cooperative workers and pumping links, or
draining worker control pipes) and returns whether progress was made;
``execution_done(execution)`` reports completion of the data plane.

**Snapshot fan-out**: ``notify_snapshot_committed(execution, sid)``
delivers the phase-2 commit signal to every processor's
``on_snapshot_committed`` hook wherever the processors actually live.

``clock_supported(clock)`` lets a backend veto clocks it cannot honor (a
:class:`~repro.core.clock.VirtualClock` cannot tick across processes).

Failure semantics: cooperative vs detected
==========================================

Two distinct failure paths feed the engine's recovery machinery, and they
must not be conflated:

* **Cooperative failure** — an API call (``JetCluster.kill_node``,
  ``add_node``) scheduled by the operator/test.  The engine *initiates*
  the teardown, so every resource is released in order and the restart is
  immediate and unconditional (it does not consume the restart budget).
* **Detected failure** — the substrate notices, mid-flight, that part of
  the execution died without being asked to: a worker process SIGKILL'd
  by the OS (exitcode < 0), a hung worker (no heartbeat within the
  supervisor deadline), an error-exited worker (processor raised), or —
  in-process — a :class:`~repro.core.tasklet.TaskletFailureError` out of
  a cooperative slice.  The backend converts the observation into
  :class:`WorkerFailure` records surfaced via :meth:`take_failures`;
  the engine's :class:`~repro.core.engine.RestartPolicy` then decides
  between a bounded backoff restart (restore from the last *committed*
  snapshot) and the terminal ``FAILED`` status.

Abort vs commit: a snapshot whose barrier protocol is broken by a
detected failure (a worker dies holding an un-acked barrier, an ack
deadline lapses, a barrier broadcast hits a dead pipe) is **aborted** —
its buffered entries are discarded and the last committed snapshot stays
authoritative — never completed with partial state and never allowed to
stall the job waiting for an ack that cannot come.

``inject_fault(execution, kind, ...)`` is the seeded chaos layer's seam
(:mod:`repro.runtime.chaos`): backends translate an abstract fault kind
("kill", "stall", "raise", "drop_ack", "delay_ack") into the most real
failure they can produce (SIGKILL/SIGSTOP a worker process; plant an
exception inside a cooperative slice).  Returns False for kinds the
substrate cannot express, so schedules stay portable across backends.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .backpressure import NetworkLink
from .clock import Clock, VirtualClock
from .queues import SPSCQueue
from .tasklet import GUARANTEE_NONE, SnapshotContext, TaskletFailureError

Location = Tuple[int, int]      # (node_id, worker_slot)

#: WorkerFailure kinds
FAILURE_CRASHED = "crashed"     # process died on a signal (e.g. SIGKILL)
FAILURE_HUNG = "hung"           # no heartbeat within the deadline
FAILURE_ERROR = "error"         # processor raised / nonzero exit


class WorkerFailure:
    """One detected (uncooperative) failure, as classified by the
    substrate.  ``kind`` is one of :data:`FAILURE_CRASHED` /
    :data:`FAILURE_HUNG` / :data:`FAILURE_ERROR`; ``key`` locates the
    worker (``(node_id, worker_slot)``) where that is meaningful."""

    __slots__ = ("kind", "key", "detail", "exitcode", "pid", "vertex",
                 "exc_type", "poison")

    def __init__(self, kind: str, key: Optional[Location] = None,
                 detail: str = "", exitcode: Optional[int] = None,
                 pid: Optional[int] = None, vertex: Optional[str] = None,
                 exc_type: Optional[str] = None,
                 poison: Optional[dict] = None):
        self.kind = kind
        self.key = key
        self.detail = detail
        self.exitcode = exitcode
        self.pid = pid
        #: DAG vertex whose processor raised, when attributable — feeds
        #: failure fingerprinting (runtime/supervisor.py)
        self.vertex = vertex
        #: exception class name of the root cause, when attributable
        self.exc_type = exc_type
        #: exact offending record stamped by pinpoint replay
        #: (``ProcessorTasklet._process_pinpoint``): dict with
        #: vertex/identity/record/exact — the engine quarantines it to
        #: the dead-letter queue on fingerprint recurrence
        self.poison = poison

    def __repr__(self):
        return (f"WorkerFailure({self.kind}, key={self.key}, "
                f"pid={self.pid}, exitcode={self.exitcode}, "
                f"detail={self.detail[:80]!r})")


class ExecutionBackend:
    """Abstract execution substrate; see the module docstring for the
    contract.  Subclasses must be stateless across executions except for
    what they stash in ``execution.backend_data``."""

    name = "abstract"

    def __init__(self):
        self.cluster = None

    def bind(self, cluster) -> None:
        self.cluster = cluster

    def clock_supported(self, clock: Clock) -> bool:
        return True

    # -- build time ----------------------------------------------------------
    def create_snapshot_context(self, job) -> SnapshotContext:
        raise NotImplementedError

    def make_transport(self, execution, edge, src: Location,
                       dst: Location):
        raise NotImplementedError

    def assign_tasklet(self, execution, inst, tasklet) -> None:
        raise NotImplementedError

    # -- lifecycle -----------------------------------------------------------
    def start_execution(self, execution) -> None:
        raise NotImplementedError

    def stop_execution(self, execution) -> None:
        raise NotImplementedError

    # -- driving -------------------------------------------------------------
    def step(self, jobs) -> bool:
        raise NotImplementedError

    def execution_done(self, execution) -> bool:
        raise NotImplementedError

    def notify_snapshot_committed(self, execution, snapshot_id: int) -> None:
        raise NotImplementedError

    # -- failure detection ---------------------------------------------------
    def take_failures(self, execution) -> List[WorkerFailure]:
        """Detected failures since the last call (drained; each failure is
        reported exactly once).  The engine consults this every driver
        iteration and routes non-empty results into the job's restart
        policy."""
        if execution is None:
            return []
        failures = execution.backend_data.get("failures")
        if not failures:
            return []
        execution.backend_data["failures"] = []
        return failures

    def inject_fault(self, execution, kind: str, worker_index: int = 0,
                     **params) -> bool:
        """Chaos seam: inject one fault of ``kind`` into a live execution.
        Returns True if the substrate could express the fault (see module
        docstring)."""
        return False

    def shutdown(self) -> None:
        """Release any cluster-wide resources (idempotent)."""


class InProcessBackend(ExecutionBackend):
    """The default cooperative substrate: every tasklet of every node runs
    on this thread, stepped round-robin; cross-node edges are simulated
    :class:`NetworkLink`s pumped once per scheduler iteration.  This is
    byte-for-byte the seed engine's behavior, factored behind the backend
    contract."""

    name = "inproc"

    def create_snapshot_context(self, job) -> SnapshotContext:
        writer = (self.cluster.snapshot_store.writer(job.id)
                  if job.config.processing_guarantee != GUARANTEE_NONE
                  else None)
        return SnapshotContext(job.config.processing_guarantee, writer)

    def make_transport(self, execution, edge, src: Location, dst: Location):
        if src[0] == dst[0]:
            return SPSCQueue(edge.queue_size)
        link = NetworkLink(self.cluster.clock,
                           latency_s=self.cluster.link_latency_s,
                           recv_capacity=edge.queue_size)
        execution.links.append(link)
        return link

    def assign_tasklet(self, execution, inst, tasklet) -> None:
        cluster = self.cluster
        worker = cluster.nodes[inst.node].workers[
            inst.local_index % cluster.cooperative_threads]
        worker.add(tasklet)

    def start_execution(self, execution) -> None:
        pass    # tasklets were placed on live workers at build time

    def stop_execution(self, execution) -> None:
        dead = set(map(id, execution.tasklets))
        for node in self.cluster.nodes.values():
            for w in node.workers:
                w.tasklets = [t for t in w.tasklets if id(t) not in dead]

    def step(self, jobs) -> bool:
        progress = False
        for node in self.cluster.nodes.values():
            for worker in node.workers:
                try:
                    progress |= worker.run_iteration()
                except TaskletFailureError as tf:
                    # detected (uncooperative) failure on the in-process
                    # substrate: route it into the owning job's failure
                    # queue instead of crashing the driver; the engine's
                    # restart policy takes it from there
                    self._record_tasklet_failure(jobs, tf)
                    progress = True
        for job in jobs:
            if job.execution is not None:
                for link in job.execution.links:
                    progress |= link.pump()
        return progress

    @staticmethod
    def _record_tasklet_failure(jobs, tf: TaskletFailureError) -> None:
        for job in jobs:
            execution = job.execution
            if execution is not None and any(t is tf.tasklet
                                             for t in execution.tasklets):
                execution.backend_data.setdefault("failures", []).append(
                    WorkerFailure(FAILURE_ERROR,
                                  detail=f"{tf.tasklet.name}: "
                                         f"{tf.cause!r}",
                                  vertex=tf.tasklet.vertex_name,
                                  exc_type=type(tf.cause).__name__,
                                  poison=getattr(tf.cause, "_jet_poison",
                                                 None)))
                return
        # no owning execution (already torn down): nothing to heal
        raise tf

    def inject_fault(self, execution, kind: str, worker_index: int = 0,
                     **params) -> bool:
        """In-process chaos: "kill" and "raise" both plant an exception in
        a deterministic live tasklet (there is no process to SIGKILL; an
        exception out of a cooperative slice IS this substrate's
        uncooperative failure).  Ring/ack faults have no in-process
        equivalent and report unsupported."""
        if kind not in ("kill", "raise"):
            return False
        live = sorted((t for t in execution.tasklets if not t.is_done),
                      key=lambda t: t.name)
        if not live:
            return False
        target = live[worker_index % len(live)]
        target._chaos_exc = RuntimeError(
            params.get("message", f"chaos[{kind}] injected"))
        return True

    def execution_done(self, execution) -> bool:
        return all(t.is_done for t in execution.tasklets)

    def notify_snapshot_committed(self, execution, snapshot_id: int) -> None:
        for t in execution.tasklets:
            hook = getattr(t.processor, "on_snapshot_committed", None)
            if hook is not None:
                hook(snapshot_id)


def make_backend(spec) -> ExecutionBackend:
    """Resolve a backend from its registry name (``"inproc"``/``"mp"``) or
    pass an already-constructed :class:`ExecutionBackend` through."""
    if isinstance(spec, ExecutionBackend):
        return spec
    if spec in (None, "inproc"):
        return InProcessBackend()
    if spec == "mp":
        from ..runtime.worker_proc import MultiprocessBackend
        return MultiprocessBackend()
    raise ValueError(f"unknown execution backend {spec!r} "
                     "(expected 'inproc', 'mp', or an ExecutionBackend)")
