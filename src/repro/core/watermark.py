"""Event-time watermarks: generation policy and multi-input coalescing.

Jet sources stamp watermarks according to an out-of-orderness allowance;
multi-input vertices coalesce per-queue watermarks by taking the minimum
(an edge's watermark asserts "no later item on THIS edge is earlier").
"""

from __future__ import annotations

from typing import Dict, Optional

from .events import MIN_TIME


class EventTimePolicy:
    """Bounded out-of-orderness watermark generation with throttling.

    ``lag``            — max allowed event-time disorder.
    ``min_step``       — don't emit a watermark unless it advanced this much
                         (throttling; Jet default granularity is 10-50 ms
                         worth of event time for low-latency jobs).
    ``idle_timeout``   — after this much wall time without events, mark the
                         source idle so it stops holding back the coalesced
                         watermark downstream.
    """

    __slots__ = ("lag", "min_step", "idle_timeout", "_top_ts", "_last_wm")

    def __init__(self, lag: int = 0, min_step: int = 1,
                 idle_timeout: Optional[float] = None):
        self.lag = lag
        self.min_step = min_step
        self.idle_timeout = idle_timeout
        self._top_ts = MIN_TIME
        self._last_wm = MIN_TIME

    def observe(self, ts: int) -> Optional[int]:
        """Record an event timestamp; return a new watermark ts or None."""
        if ts > self._top_ts:
            self._top_ts = ts
            wm = ts - self.lag
            if wm >= self._last_wm + self.min_step:
                self._last_wm = wm
                return wm
        return None

    @property
    def current(self) -> int:
        return self._last_wm


class WatermarkCoalescer:
    """Min-coalescing of watermarks across input queues.

    Tracks the last watermark seen on each queue; the coalesced output only
    advances when the *minimum* across all live queues advances.  Queues that
    reported DONE or idle are excluded.
    """

    __slots__ = ("_queue_wm", "_live", "_coalesced")

    def __init__(self, n_queues: int):
        self._queue_wm = [MIN_TIME] * n_queues
        self._live = [True] * n_queues
        self._coalesced = MIN_TIME

    def observe(self, queue_index: int, wm_ts: int) -> Optional[int]:
        """Record watermark from one queue; return new coalesced ts or None."""
        if wm_ts > self._queue_wm[queue_index]:
            self._queue_wm[queue_index] = wm_ts
        return self._recompute()

    def queue_done(self, queue_index: int) -> Optional[int]:
        self._live[queue_index] = False
        return self._recompute()

    def _recompute(self) -> Optional[int]:
        live_wms = [wm for wm, live in zip(self._queue_wm, self._live) if live]
        if not live_wms:
            return None
        new = min(live_wms)
        if new > self._coalesced:
            self._coalesced = new
            return new
        return None

    @property
    def coalesced(self) -> int:
        return self._coalesced
