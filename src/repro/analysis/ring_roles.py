"""Pass 5 — SPSC role discipline on ring transports.

Every edge transport (``ShmRing``, ``SPSCQueue``, ``NetworkLink``) is
*strictly* single-producer single-consumer: the producer side owns
``tail`` and the slots it fills, the consumer side owns ``head`` and the
slots it drains, and nothing else may touch either (shm_ring.py
"Memory model").  The argument is entirely conventional — nothing in the
code stops a consumer method from bumping ``tail`` or a coordinator from
polling a ring the worker owns — so this pass machine-checks it:

1. **Inside a transport class** (any class defining both a producer
   entry and a consumer entry): the attribute sets written by the
   producer-side methods and by the consumer-side methods must be
   disjoint, cursor-named attributes (``head``/``tail``) must only be
   written by their owning side, the ``_set_head``/``_set_tail`` helpers
   must only be reachable from their owning side, and header writes via
   ``struct.pack_into(self._buf, OFFSET, ...)`` must hit disjoint
   offsets per side.

2. **Across classes**: a single class whose methods call both producer
   entries and consumer entries on the *same* ring-typed attribute holds
   both ends of one ring — one descheduled slice away from corrupting
   it.

3. **Across process roles**: in a worker-entry module (one defining
   ``_worker_main``), data-plane calls on ring-named receivers must stay
   on one side of the fork — each ring name may be produced from one
   process role and consumed from one process role, and never both ends
   from the same role (see :func:`model.child_spans`).

Receivers/attributes count as "ring-typed" by name (contains ``ring``/
``queue``, or a ``q``/``_q``/``qs`` form) — a deliberate lint-grade
heuristic: transports in this tree are always named that way, and a
false name costs one suppression with a reason.

Rule: ``ring-role-violation``.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .model import (AnalysisContext, ClassInfo, Finding, MethodFlow,
                    ModuleInfo, child_spans, in_spans)

PRODUCER_ENTRIES = ("offer", "offer_many", "has_room_for")
CONSUMER_ENTRIES = ("poll", "poll_prefix", "poll_many", "peek", "drain_to")

#: cursor helper-method ownership: only the named side may reach these
SIDE_OF_HELPER = {"_set_tail": "producer", "set_tail": "producer",
                  "_set_head": "consumer", "set_head": "consumer"}

_RINGISH_RE = re.compile(r"ring|queue|(^|_)qs?($|_)")


def _ringish(name: str) -> bool:
    return bool(_RINGISH_RE.search(name.lower()))


def _cursor_owner(attr: str) -> Optional[str]:
    """Which side owns a cursor-named attribute (``_tail`` -> producer,
    ``head_pos`` -> consumer); None for non-cursor names."""
    n = attr.strip("_").lower()
    if n == "tail" or n.startswith("tail_") or n.endswith("_tail"):
        return "producer"
    if n == "head" or n.startswith("head_") or n.endswith("_head"):
        return "consumer"
    return None


def _is_transport(ci: ClassInfo) -> bool:
    return (any(m in ci.methods for m in PRODUCER_ENTRIES)
            and any(m in ci.methods for m in CONSUMER_ENTRIES))


def _side_writes(flows: Dict[str, Tuple[ClassInfo, MethodFlow]],
                 exclude: Set[str]) -> Dict[str, Tuple[int, str]]:
    """attr -> (line, via-method) for every self-attribute write performed
    by the side's exclusive methods."""
    out: Dict[str, Tuple[int, str]] = {}
    for mname in sorted(flows):
        if mname in exclude:
            continue
        _owner, flow = flows[mname]
        for attr in flow.writes:
            line = flow.write_lines.get(attr, flow.node.lineno)
            if attr not in out or line < out[attr][0]:
                out[attr] = (line, mname)
    return out


def _header_writes(flows: Dict[str, Tuple[ClassInfo, MethodFlow]],
                   exclude: Set[str]) -> Dict[Tuple[str, int], int]:
    """(buffer attr, constant offset) -> line for every
    ``*.pack_into(self.buf, OFFSET, ...)`` performed by the side."""
    out: Dict[Tuple[str, int], int] = {}
    for mname in sorted(flows):
        if mname in exclude:
            continue
        _owner, flow = flows[mname]
        for node in ast.walk(flow.node):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "pack_into"
                    and len(node.args) >= 2):
                continue
            off = node.args[1]
            if not (isinstance(off, ast.Constant)
                    and isinstance(off.value, int)):
                continue
            for attr, _d in flow.taints(node.args[0]):
                key = (attr, off.value)
                if key not in out or node.lineno < out[key]:
                    out[key] = node.lineno
    return out


def _check_transport_class(ctx: AnalysisContext, ci: ClassInfo,
                           findings: List[Finding]) -> None:
    path = ci.module.path
    pflows = ctx.reachable_flows(ci, [m for m in PRODUCER_ENTRIES
                                      if m in ci.methods])
    cflows = ctx.reachable_flows(ci, [m for m in CONSUMER_ENTRIES
                                      if m in ci.methods])
    # helpers reachable from both sides carry no side information; their
    # writes (there should be none) cannot be attributed
    shared = set(pflows) & set(cflows)
    for helper, side in SIDE_OF_HELPER.items():
        wrong = cflows if side == "producer" else pflows
        if helper in wrong and helper not in shared:
            _owner, flow = wrong[helper]
            entries = (CONSUMER_ENTRIES if side == "producer"
                       else PRODUCER_ENTRIES)
            findings.append(Finding(
                "ring-role-violation", path, flow.node.lineno,
                f"{ci.name}.{helper} (a {side}-side cursor publisher) is "
                f"reachable from the "
                f"{'consumer' if side == 'producer' else 'producer'} "
                f"entries {[m for m in entries if m in ci.methods]}; only "
                f"the {side} may advance this cursor"))
    pw = _side_writes(pflows, shared)
    cw = _side_writes(cflows, shared)
    for attr in sorted(set(pw) & set(cw)):
        pline, pvia = pw[attr]
        cline, cvia = cw[attr]
        findings.append(Finding(
            "ring-role-violation", path, min(pline, cline),
            f"{ci.name}.{attr} is written by both the producer side "
            f"({pvia}, line {pline}) and the consumer side ({cvia}, line "
            f"{cline}); SPSC discipline gives each attribute exactly one "
            f"writing side"))
    for side_name, writes, other in (("producer", pw, "consumer"),
                                     ("consumer", cw, "producer")):
        for attr in sorted(writes):
            owner_side = _cursor_owner(attr)
            if owner_side is not None and owner_side != side_name \
                    and attr not in (set(pw) & set(cw)):
                line, via = writes[attr]
                findings.append(Finding(
                    "ring-role-violation", path, line,
                    f"{ci.name}.{via} writes cursor `{attr}` from the "
                    f"{side_name} side; `{attr}` is {owner_side}-owned "
                    f"(the {other} must never see it move backwards or "
                    f"early)"))
    ph = _header_writes(pflows, shared)
    ch = _header_writes(cflows, shared)
    for (attr, off) in sorted(set(ph) & set(ch)):
        findings.append(Finding(
            "ring-role-violation", path, min(ph[(attr, off)],
                                             ch[(attr, off)]),
            f"{ci.name}: header offset {off} of self.{attr} is "
            f"pack_into-written by both sides (producer line "
            f"{ph[(attr, off)]}, consumer line {ch[(attr, off)]}); "
            f"header words are single-writer"))


def _check_both_ends(ci: ClassInfo, findings: List[Finding]) -> None:
    """One class calling producer AND consumer entries on the same
    ring-typed attribute holds both ends of the ring."""
    per_attr: Dict[str, Dict[str, int]] = {}
    for mname in sorted(ci.methods):
        flow = ci.flow(mname)
        if flow is None:
            continue
        for attr, meth, line in flow.attr_calls:
            if meth not in PRODUCER_ENTRIES and meth not in CONSUMER_ENTRIES:
                continue
            if not _ringish(attr):
                continue
            calls = per_attr.setdefault(attr, {})
            if meth not in calls or line < calls[meth]:
                calls[meth] = line
    for attr in sorted(per_attr):
        calls = per_attr[attr]
        p = sorted(m for m in calls if m in PRODUCER_ENTRIES)
        c = sorted(m for m in calls if m in CONSUMER_ENTRIES)
        if p and c:
            line = min(calls.values())
            findings.append(Finding(
                "ring-role-violation", ci.module.path, line,
                f"{ci.name} drives both ends of self.{attr}: producer "
                f"calls {p} and consumer calls {c}; SPSC transports need "
                f"the two ends in different owners"))


def _receiver_name(expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Subscript):
        return _receiver_name(expr.value)
    return None


def _check_process_roles(mod: ModuleInfo, findings: List[Finding]) -> None:
    spans = child_spans(mod)
    if not spans:
        return
    #: ring name -> side -> {role -> first line}
    usage: Dict[str, Dict[str, Dict[str, int]]] = {}
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        meth = node.func.attr
        if meth in PRODUCER_ENTRIES:
            side = "producer"
        elif meth in CONSUMER_ENTRIES:
            side = "consumer"
        else:
            continue
        name = _receiver_name(node.func.value)
        if name is None or not _ringish(name):
            continue
        role = ("worker" if in_spans(node.lineno, spans) else "coordinator")
        roles = usage.setdefault(name, {}).setdefault(side, {})
        if role not in roles or node.lineno < roles[role]:
            roles[role] = node.lineno
    for name in sorted(usage):
        sides = usage[name]
        for side, other in (("producer", "consumer"),
                            ("consumer", "producer")):
            roles = sides.get(side, {})
            if len(roles) > 1:
                findings.append(Finding(
                    "ring-role-violation", mod.path, min(roles.values()),
                    f"ring `{name}` has {side} calls from both coordinator "
                    f"code (line {roles['coordinator']}) and worker code "
                    f"(line {roles['worker']}); a ring has exactly one "
                    f"{side} process"))
        both = (set(sides.get("producer", {}))
                & set(sides.get("consumer", {})))
        for role in sorted(both):
            pline = sides["producer"][role]
            cline = sides["consumer"][role]
            findings.append(Finding(
                "ring-role-violation", mod.path, min(pline, cline),
                f"{role} code holds both ends of ring `{name}` (produces "
                f"at line {pline}, consumes at line {cline}); the data "
                f"plane must keep producer and consumer in different "
                f"process roles"))


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        for cname in sorted(mod.classes):
            ci = mod.classes[cname]
            if _is_transport(ci):
                _check_transport_class(ctx, ci, findings)
            else:
                _check_both_ends(ci, findings)
        _check_process_roles(mod, findings)
    return findings
