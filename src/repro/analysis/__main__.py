"""CLI: ``python -m repro.analysis [paths...] [--json] [--out FILE]``.

Exits 1 when any unsuppressed finding remains — the CI gate.
"""

from __future__ import annotations

import argparse
import os
import sys

from . import RULES, run_paths
from .report import render_console, render_json, split


def _default_paths() -> list:
    # prefer the repo layout (src/repro under cwd); fall back to the
    # package's own source tree so the module runs from anywhere
    cand = os.path.join("src", "repro")
    if os.path.isdir(cand):
        return [cand]
    return [os.path.dirname(os.path.dirname(os.path.abspath(__file__)))]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jetlint: AST contract checker for the Jet repro "
                    "(snapshot completeness/aliasing, hot-path "
                    "non-blocking, block-form purity)")
    ap.add_argument("paths", nargs="*", help="files or directories "
                    "(default: src/repro)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of console lines")
    ap.add_argument("--out", help="also write the report to this file")
    ap.add_argument("--rules", help="comma-separated rule subset to run")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings in console output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:28s} {desc}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    paths = args.paths or _default_paths()
    findings, files, unused = run_paths(paths, rules)
    if args.as_json:
        report = render_json(findings, files, unused)
    else:
        report = render_console(findings, files, unused,
                                show_suppressed=args.show_suppressed)
    print(report)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(render_json(findings, files, unused) + "\n")
    active, _ = split(findings)
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
