"""CLI: ``python -m repro.analysis [paths...] [--json] [--out FILE]``.

Exits 1 when any unsuppressed finding remains — the CI gate.

``--changed`` is the incremental mode for pre-commit hooks: findings are
reported only for files git considers modified (worktree diff against
HEAD plus untracked files), but the analysis context is still built from
the full tree — the cross-module passes (protocol conformance, hot-path
reachability, ring role attribution) are only sound with the complete
registry in view.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import List, Optional, Set

from . import RULES, run_paths
from .report import render_console, render_json, split


def _default_paths() -> list:
    # prefer the repo layout (src/repro under cwd); fall back to the
    # package's own source tree so the module runs from anywhere
    cand = os.path.join("src", "repro")
    if os.path.isdir(cand):
        return [cand]
    return [os.path.dirname(os.path.dirname(os.path.abspath(__file__)))]


def _changed_files() -> Optional[Set[str]]:
    """Python files git sees as modified (vs HEAD) or untracked, as
    paths relative to the current directory; None when git is absent."""
    try:
        top = subprocess.run(
            ["git", "rev-parse", "--show-toplevel"],
            capture_output=True, text=True, check=True).stdout.strip()
        listings = [
            subprocess.run(["git", "diff", "--name-only", "HEAD"],
                           capture_output=True, text=True,
                           check=True).stdout,
            subprocess.run(
                ["git", "ls-files", "--others", "--exclude-standard"],
                capture_output=True, text=True, check=True).stdout,
        ]
    except (OSError, subprocess.CalledProcessError):
        return None
    changed: Set[str] = set()
    for listing in listings:
        for name in listing.splitlines():
            name = name.strip()
            if name.endswith(".py"):
                changed.add(os.path.relpath(os.path.join(top, name)))
    return changed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jetlint: AST contract checker for the Jet repro "
                    "(snapshot completeness/aliasing, hot-path "
                    "non-blocking, block-form purity, SPSC ring roles, "
                    "protocol conformance, resource leaks)")
    ap.add_argument("paths", nargs="*", help="files or directories "
                    "(default: src/repro)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of console lines")
    ap.add_argument("--out", help="also write the report to this file")
    ap.add_argument("--rules", help="comma-separated rule subset to run")
    ap.add_argument("--changed", action="store_true",
                    help="report findings only for git-modified files "
                         "(the analysis still sees the full tree)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings in console output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:28s} {desc}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    only_files: Optional[List[str]] = None
    if args.changed:
        changed = _changed_files()
        if changed is None:
            print("jetlint: --changed needs a git checkout",
                  file=sys.stderr)
            return 2
        only_files = sorted(changed)
        print(f"jetlint: --changed filter: {len(only_files)} "
              f"modified python file(s)", file=sys.stderr)

    paths = args.paths or _default_paths()
    findings, files, unused = run_paths(paths, rules, only_files=only_files)
    if args.as_json:
        report = render_json(findings, files, unused)
    else:
        report = render_console(findings, files, unused,
                                show_suppressed=args.show_suppressed)
    print(report)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(render_json(findings, files, unused) + "\n")
    active, _ = split(findings)
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
