"""Deterministic ring sanitizer: exhaustive interleaving exploration of
the ShmRing publication protocol.

core/shm_ring.py argues its safety in prose: on TSO, the producer's
payload stores land before the 8-byte ``tail`` store publishes them, so
the consumer — which reads only ``[head, tail)`` — can never observe a
torn record; crash-kill the producer mid-offer and the half-written
record simply stays unpublished.  This module turns that argument into a
machine-checked property.

It models the ring at byte level with the *exact* record layout of
``ShmRing`` (``[u32 total_len][u8 tag][payload]``, 255-tagged PAD
records on wraparound, implicit < 5-byte tail gaps) and splits ``offer``
into its individual mutation steps — pad header, record header, payload,
``msgs_in``, ``tail`` — in the same order the real code performs them.
A depth-first explorer then drives every interleaving of

* one producer micro-step,
* one consumer ``poll`` (atomic: the consumer only touches bytes the
  producer published, which is the very property under test), and
* a producer **crash** at every micro-step boundary — including
  immediately before and after the cursor publication itself,

memoizing visited states so the exploration is exhaustive and bounded.
At every quiescent endpoint (producer finished or crashed, ring
drained) it asserts:

* **no torn record** — every polled record has a sane header and the
  exact payload the producer staged for that sequence number;
* **no lost record** — every offer whose ``tail`` store was applied is
  eventually polled;
* **no duplicated or reordered record** — polled sequence numbers are
  exactly ``0..published-1`` in order;
* **counter consistency** — without a crash, ``msgs_in == msgs_out ==
  published`` at quiescence (with a crash the in-counter may lead: the
  counters are advisory telemetry, not the publication protocol).

The "teeth" of the sanitizer: :data:`BUGGY_ORDERS` re-runs the same
exploration with deliberately broken publication orders (``tail`` store
before the payload store; skipping the PAD record on wraparound) and the
test suite asserts a violation IS found — proving the explorer can see
the bug class it guards against.

CLI (used by the chaos-smoke CI job)::

    python -m repro.analysis.ring_sanitizer [--capacity N] [--sizes a,b,c]
        [--buggy none|tail-first|skip-pad] [--json out.json]

Exit status 1 when a violation is found; the JSON report carries the
full interleaving trace for the artifact upload.
"""

from __future__ import annotations

import argparse
import json
import struct
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_REC = struct.Struct("<IB")     # [u32 total_len][u8 tag] — ShmRing._REC
TAG_PAD = 255                   # ShmRing.TAG_PAD

#: the real publication order of ShmRing.offer's mutation steps
CORRECT_ORDER = ("pad", "header", "payload", "msgs_in", "tail")
#: deliberately broken orders the teeth tests must catch
BUGGY_ORDERS = {
    # publish the cursor before the payload lands: a consumer slice
    # between the two stores reads garbage
    "tail-first": ("pad", "header", "tail", "payload", "msgs_in"),
    # skip the PAD record on wraparound: the consumer walks into stale
    # bytes at the physical tail
    "skip-pad": ("header", "payload", "msgs_in", "tail"),
}


@dataclass
class Config:
    capacity: int = 32
    #: payload sizes of the records the producer offers, in order;
    #: defaults chosen to force a PAD record and an implicit tail gap
    sizes: Tuple[int, ...] = (7, 12, 5, 9, 6)
    order: Tuple[str, ...] = CORRECT_ORDER
    crash: bool = True
    #: initial byte value of the data region (0xEE surfaces reads of
    #: never-written bytes; the real segment is zero-filled)
    init_byte: int = 0xEE
    max_states: int = 2_000_000


def _payload(seq: int, size: int) -> bytes:
    return bytes(((seq * 31 + i) & 0xFF) for i in range(size))


@dataclass
class Violation:
    reason: str
    trace: List[str]

    def to_json(self) -> dict:
        return {"reason": self.reason, "trace": self.trace}


@dataclass
class Result:
    config_order: Tuple[str, ...]
    states: int = 0
    endpoints: int = 0
    published_max: int = 0
    violations: List[Violation] = field(default_factory=list)
    truncated: bool = False

    @property
    def ok(self) -> bool:
        return not self.violations and not self.truncated

    def to_json(self) -> dict:
        return {
            "order": list(self.config_order),
            "states": self.states,
            "endpoints": self.endpoints,
            "published_max": self.published_max,
            "truncated": self.truncated,
            "ok": self.ok,
            "violations": [v.to_json() for v in self.violations],
        }


class _State:
    """One node of the interleaving graph: full ring bytes + cursors +
    producer progress + what the consumer saw so far."""

    __slots__ = ("data", "head", "tail", "msgs_in", "msgs_out",
                 "p_idx", "plan", "crashed", "published", "consumed",
                 "trace")

    def __init__(self, cfg: Config):
        self.data = bytearray([cfg.init_byte] * cfg.capacity)
        self.head = 0
        self.tail = 0
        self.msgs_in = 0
        self.msgs_out = 0
        self.p_idx = 0                      # next script record
        self.plan: Optional[Tuple] = None   # remaining micro-ops
        self.crashed = False
        self.published = 0
        self.consumed: Tuple = ()           # ((seq, payload) | ("torn", why))
        self.trace: Tuple[str, ...] = ()

    def clone(self) -> "_State":
        s = object.__new__(_State)
        s.data = bytearray(self.data)
        for name in ("head", "tail", "msgs_in", "msgs_out", "p_idx",
                     "plan", "crashed", "published", "consumed", "trace"):
            setattr(s, name, getattr(self, name))
        return s

    def key(self) -> Tuple:
        # trace excluded: two paths reaching identical ring+progress
        # state have identical futures
        return (bytes(self.data), self.head, self.tail, self.msgs_in,
                self.msgs_out, self.p_idx, self.plan, self.crashed,
                self.published, self.consumed)


def _plan_offer(st: _State, cfg: Config) -> Optional[Tuple]:
    """The mutation steps of one ShmRing.offer, computed from the
    cursors as the real code reads them up front.  None == ring full
    (offer returns False; the producer retries after consumer progress)."""
    seq = st.p_idx
    payload = _payload(seq, cfg.sizes[seq])
    rec = _REC.size + len(payload)
    cap = cfg.capacity
    if rec > cap:
        raise ValueError("record exceeds ring capacity")
    tail, head = st.tail, st.head
    to_end = cap - (tail % cap)
    needed = rec if rec <= to_end else to_end + rec
    if needed > cap - (tail - head):
        return None
    ops: List[Tuple] = []
    idx = tail % cap
    if rec > to_end:
        if to_end >= _REC.size and "pad" in cfg.order:
            ops.append(("pad", idx, to_end))
        tail += to_end
        idx = 0
    ops.append(("header", idx, rec, seq))
    ops.append(("payload", idx + _REC.size, payload))
    ops.append(("msgs_in",))
    ops.append(("tail", tail + rec))
    ops.sort(key=lambda op: cfg.order.index(op[0]))
    return tuple(ops)


def _apply(st: _State, op: Tuple) -> None:
    kind = op[0]
    if kind == "pad":
        _, idx, length = op
        _REC.pack_into(st.data, idx, length, TAG_PAD)
    elif kind == "header":
        _, idx, rec, seq = op
        _REC.pack_into(st.data, idx, rec, seq)
    elif kind == "payload":
        _, idx, payload = op
        st.data[idx:idx + len(payload)] = payload
    elif kind == "msgs_in":
        st.msgs_in += 1
    elif kind == "tail":
        st.tail = op[1]
        st.published += 1


def _poll(st: _State, cap: int) -> Optional[Tuple]:
    """One atomic consumer poll against the published region; returns
    (seq, payload), ("torn", why), or None when empty.  Mirrors
    ShmRing._read_record including PAD skipping and implicit gaps."""
    head = st.head
    while True:
        if head == st.tail:
            return None
        idx = head % cap
        to_end = cap - idx
        if to_end < _REC.size:
            head += to_end          # implicit pad at the physical tail
            continue
        rec, tag = _REC.unpack_from(st.data, idx)
        if rec < _REC.size or rec > to_end:
            return ("torn",
                    f"record header at byte {idx} has impossible length "
                    f"{rec} (tag {tag}, {to_end} bytes to physical end)")
        if tag == TAG_PAD:
            if rec != to_end:
                return ("torn",
                        f"PAD record at byte {idx} has length {rec}, "
                        f"expected {to_end}")
            head += rec
            continue
        payload = bytes(st.data[idx + _REC.size:idx + rec])
        st.msgs_out += 1
        st.head = head + rec
        return (tag, payload)


def explore(cfg: Config) -> Result:
    """Exhaustively explore producer/consumer interleavings (with crash
    injection at every producer micro-step boundary when ``cfg.crash``)
    and check the no-torn/no-lost/no-duplicate invariants at every
    quiescent endpoint."""
    res = Result(config_order=cfg.order)
    root = _State(cfg)
    seen = {root.key()}
    stack = [root]
    nrec = len(cfg.sizes)
    while stack:
        st = stack.pop()
        res.states += 1
        if res.states >= cfg.max_states:
            res.truncated = True
            break
        succs: List[_State] = []
        producer_done = st.crashed or (st.p_idx >= nrec
                                       and st.plan is None)
        # -- producer micro-step ------------------------------------------
        if not producer_done:
            if st.plan is None:
                plan = _plan_offer(st, cfg)
                if plan is not None:
                    nxt = st.clone()
                    nxt.plan = plan
                    nxt.trace += (f"P:start-offer#{st.p_idx}",)
                    succs.append(nxt)
                # plan None == ring full: producer spins; consumer or
                # crash branches below provide the progress
            else:
                nxt = st.clone()
                op, rest = st.plan[0], st.plan[1:]
                _apply(nxt, op)
                nxt.plan = rest or None
                if not rest:
                    nxt.p_idx += 1
                nxt.trace += (f"P:{op[0]}#{st.p_idx}",)
                succs.append(nxt)
            if cfg.crash:
                nxt = st.clone()
                nxt.crashed = True
                at = ("idle" if st.plan is None
                      else f"before-{st.plan[0][0]}#{st.p_idx}")
                nxt.trace += (f"P:crash@{at}",)
                succs.append(nxt)
        # -- consumer poll -------------------------------------------------
        probe = st.clone()
        got = _poll(probe, cfg.capacity)
        if got is not None:
            if got[0] == "torn":
                res.violations.append(Violation(
                    f"torn record observed: {got[1]}",
                    list(st.trace) + ["C:poll->torn"]))
                continue
            probe.consumed = st.consumed + (got,)
            probe.trace += (f"C:poll->#{got[0]}",)
            succs.append(probe)
        elif producer_done or (st.plan is None and not succs):
            # quiescent endpoint: drained, and the producer is finished,
            # crashed, or blocked with no way to make progress
            res.endpoints += 1
            res.published_max = max(res.published_max, st.published)
            err = _check_endpoint(st, cfg)
            if err is not None:
                res.violations.append(Violation(err, list(st.trace)))
            continue
        for nxt in succs:
            k = nxt.key()
            if k not in seen:
                seen.add(k)
                stack.append(nxt)
    return res


def _check_endpoint(st: _State, cfg: Config) -> Optional[str]:
    if len(st.consumed) != st.published:
        return (f"lost or duplicated records: {st.published} published "
                f"but {len(st.consumed)} consumed at quiescence")
    for i, (seq, payload) in enumerate(st.consumed):
        if seq != i:
            return (f"record order violated: position {i} polled "
                    f"sequence {seq}")
        want = _payload(i, cfg.sizes[i])
        if payload != want:
            return (f"torn record: sequence {i} polled "
                    f"{payload.hex()} != staged {want.hex()}")
    if not st.crashed and (st.msgs_in != st.published
                           or st.msgs_out != st.published):
        return (f"counter drift without a crash: msgs_in={st.msgs_in} "
                f"msgs_out={st.msgs_out} published={st.published}")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.ring_sanitizer",
        description="exhaustive interleaving + crash-injection check of "
                    "the ShmRing publication protocol")
    ap.add_argument("--capacity", type=int, default=32)
    ap.add_argument("--sizes", default="7,12,5,9,6",
                    help="comma-separated payload sizes to offer")
    ap.add_argument("--no-crash", action="store_true",
                    help="skip crash injection (interleavings only)")
    ap.add_argument("--buggy", choices=["none"] + sorted(BUGGY_ORDERS),
                    default="none",
                    help="run a deliberately broken publication order "
                         "(expects to FIND a violation)")
    ap.add_argument("--max-states", type=int, default=2_000_000)
    ap.add_argument("--json", dest="out",
                    help="write the JSON report (with any violation "
                         "trace) to this file")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(",") if s.strip())
    order = (CORRECT_ORDER if args.buggy == "none"
             else BUGGY_ORDERS[args.buggy])
    cfg = Config(capacity=args.capacity, sizes=sizes, order=order,
                 crash=not args.no_crash, max_states=args.max_states)
    res = explore(cfg)
    doc = res.to_json()
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(json.dumps(doc, indent=2) + "\n")
    expect_violation = args.buggy != "none"
    found = bool(res.violations)
    print(f"ring-sanitizer: order={','.join(order)} states={res.states} "
          f"endpoints={res.endpoints} published_max={res.published_max} "
          f"violations={len(res.violations)}"
          + (" (truncated)" if res.truncated else ""))
    for v in res.violations[:3]:
        print(f"  violation: {v.reason}")
        print(f"  trace: {' '.join(v.trace[-12:])}")
    if expect_violation:
        if found:
            print("ring-sanitizer: buggy order correctly caught")
            return 0
        print("ring-sanitizer: buggy order NOT caught — explorer has "
              "no teeth", file=sys.stderr)
        return 1
    if res.truncated:
        print("ring-sanitizer: state budget exhausted before full "
              "exploration", file=sys.stderr)
        return 1
    return 1 if found else 0


if __name__ == "__main__":
    sys.exit(main())
