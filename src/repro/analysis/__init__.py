"""jetlint — AST contract checker for the Jet reproduction.

Seven passes enforce the engine's load-bearing conventions (see
ROADMAP.md "Machine-checked contracts"):

1. ``snapshot-missing-save`` / ``snapshot-missing-restore`` — every
   hot-path mutation of processor state must survive the Chandy-Lamport
   cycle or be declared ``EPHEMERAL_STATE`` / ``SNAPSHOT_STATE``;
2. ``snapshot-aliasing`` — snapshot payloads must not alias live
   mutable containers (the PR 6 bug shape);
3. ``hot-path-blocking`` / ``hot-path-unbounded-growth`` — cooperative
   hot paths never block a worker thread or grow without bound;
4. ``block-form-impure`` / ``block-form-mismatch`` — block forms are
   pure column expressions and ``accepts_blocks`` declarations match
   the code;
5. ``ring-role-violation`` — SPSC discipline on ring transports: one
   writing side per attribute/cursor, one process role per ring end;
6. ``protocol-unhandled-message`` / ``protocol-dead-arm`` — every
   tagged-tuple control message sent has a handler arm on the other
   side, and every arm has a sender;
7. ``resource-leak`` — every ``SharedMemory``/``Process``/``Pipe``/
   ``open`` acquisition has release evidence on all paths (try/finally,
   ``with``, ``weakref.finalize``, or ownership transfer).

Suppression syntax (reason is mandatory)::

    self.cache.append(x)  # jetlint: disable=hot-path-unbounded-growth -- bounded by drain in complete()

A trailing comment covers its own line; a standalone comment line
covers the next line.  Either form on (or directly above) a
``def``/``class`` header covers the whole body.

Usage::

    python -m repro.analysis src/repro [--json] [--out report.json]

Exit status is 1 when any unsuppressed finding remains, 0 otherwise.
"""

from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Tuple

from . import (block_form, hot_path, protocol, resource_leak, ring_roles,
               snapshot_aliasing, snapshot_completeness)
from .model import AnalysisContext, Finding, ModuleInfo

#: rule name -> one-line description (``--list-rules``)
RULES: Dict[str, str] = {
    "snapshot-missing-save":
        "hot-path mutated self.* never referenced in save_to_snapshot",
    "snapshot-missing-restore":
        "saved self.* never referenced in restore hooks",
    "snapshot-aliasing":
        "snapshot payload aliases a live mutable container",
    "hot-path-blocking":
        "sleep/lock/IO/print reachable from a cooperative hot path",
    "hot-path-unbounded-growth":
        "hot-path container growth with no shrink anywhere in the class",
    "block-form-impure":
        "block form uses non-whitelisted ops (loops, mutation, calls)",
    "block-form-mismatch":
        "accepts_blocks declaration disagrees with the process path",
    "ring-role-violation":
        "SPSC role discipline broken on a ring transport",
    "protocol-unhandled-message":
        "control-message tag sent with no handler arm on the other side",
    "protocol-dead-arm":
        "dispatch arm for a tag no sender ever produces",
    "resource-leak":
        "OS resource acquired without release evidence on all paths",
    "bad-suppression":
        "jetlint disable comment without a `-- reason` string",
}

PASSES = (snapshot_completeness.run, snapshot_aliasing.run,
          hot_path.run, block_form.run, ring_roles.run, protocol.run,
          resource_leak.run)


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
    return out


def _analyze_modules(modules: List[ModuleInfo]) -> List[Finding]:
    ctx = AnalysisContext(modules)
    findings: List[Finding] = []
    for run_pass in PASSES:
        findings.extend(run_pass(ctx))
    for mod in modules:
        for line in mod.bad_suppressions:
            findings.append(Finding(
                "bad-suppression", mod.path, line,
                "jetlint suppression without a reason — write "
                "`# jetlint: disable=<rule> -- <why this is safe>`"))
    # match suppressions (bad-suppression itself cannot be suppressed)
    by_path = {m.path: m for m in modules}
    for f in findings:
        if f.rule == "bad-suppression":
            continue
        mod = by_path.get(f.path)
        if mod is None:
            continue
        s = mod.suppression_for(f.rule, f.line)
        if s is not None:
            f.suppressed = True
            f.reason = s.reason
            s.used = True
    return findings


def analyze_sources(sources: Dict[str, str],
                    rules: Optional[Iterable[str]] = None
                    ) -> List[Finding]:
    """Run every pass over {path: source}.  The test-suite entry point."""
    modules = [ModuleInfo(path, src) for path, src in sources.items()]
    findings = _analyze_modules(modules)
    if rules:
        wanted = set(rules)
        findings = [f for f in findings if f.rule in wanted]
    return findings


def run_paths(paths: Iterable[str],
              rules: Optional[Iterable[str]] = None,
              only_files: Optional[Iterable[str]] = None
              ) -> Tuple[List[Finding], int,
                         List[Tuple[str, int, Tuple[str, ...]]]]:
    """(findings, files_scanned, unused suppression sites).

    ``only_files`` filters the *reported* findings and unused
    suppressions to those paths while still building the analysis
    context from the full tree — cross-module passes (protocol
    conformance, reachability) need the whole registry even when only
    one file changed (the ``--changed`` incremental mode).
    """
    files = iter_py_files(paths)
    modules: List[ModuleInfo] = []
    for path in files:
        with open(path, "r", encoding="utf-8") as fh:
            modules.append(ModuleInfo(path, fh.read()))
    findings = _analyze_modules(modules)
    if rules:
        wanted = set(rules)
        findings = [f for f in findings if f.rule in wanted]
    report_mods = modules
    if only_files is not None:
        keep = {os.path.normpath(p) for p in only_files}
        findings = [f for f in findings
                    if os.path.normpath(f.path) in keep]
        report_mods = [m for m in modules
                       if os.path.normpath(m.path) in keep]
    unused = sorted((m.path, s.line, s.rules) for m in report_mods
                    for s in m.suppressions if not s.used)
    return findings, len(files), unused
