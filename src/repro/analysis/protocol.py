"""Pass 6 — worker control-protocol conformance.

The coordinator and its worker processes speak tagged tuples over pipes:
``("snapshot", n)``, ``("hb",)``, ``("ack", n, entries)``, ...  The PR 7
wedge was a *protocol hole* — a legal message arriving in a state the
receiver had no arm for — and nothing but convention keeps the two sides'
vocabularies aligned as tags are added.

This pass closes the loop inside every worker-entry module (one defining
``_worker_main``; see :func:`model.child_spans`):

* **senders** — every ``X.send((<tag literal>, ...))`` and every literal
  tuple handed to a ``.broadcast(...)`` call, classified coordinator-side
  or worker-side by whether the call site is worker-reachable;
* **dispatches** — every receive loop: a scope that binds ``msg =
  conn.recv()`` and compares ``msg[0]`` (directly or through ``op =
  msg[0]``) against string tags.

Checks, per direction (coordinator→worker and worker→coordinator):

* ``protocol-unhandled-message`` — a sent tag missing from a receiving
  dispatch's arms (only dispatches with >= 2 arms count as full
  dispatches; a single-arm compare is a filter, not a receive loop), or
  a tag sent when the other side has no dispatch at all;
* ``protocol-dead-arm`` — a dispatch arm whose tag no sender on the
  other side ever produces (dead protocol surface, or a tag someone
  renamed on one side only).

Tags must be string literals (or module-level string constants) —
dynamic tags are invisible to this pass and should be avoided in
protocol code.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .model import (AnalysisContext, Finding, ModuleInfo, child_spans,
                    in_spans)

SEND_ATTRS = frozenset({"send"})
BROADCAST_ATTRS = frozenset({"broadcast"})
#: a scope needs this many distinct arms to count as a full dispatch
MIN_DISPATCH_ARMS = 2


@dataclass
class _Send:
    tag: str
    line: int
    role: str           # "worker" | "coordinator"


@dataclass
class _Dispatch:
    role: str
    recv_line: int
    arms: Dict[str, int] = field(default_factory=dict)   # tag -> line


def _module_consts(mod: ModuleInfo) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for stmt in mod.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and isinstance(stmt.value, ast.Constant) \
                and isinstance(stmt.value.value, str):
            out[stmt.targets[0].id] = stmt.value.value
    return out


def _tuple_tag(expr: ast.expr, consts: Dict[str, str]) -> Optional[str]:
    if not (isinstance(expr, ast.Tuple) and expr.elts):
        return None
    head = expr.elts[0]
    if isinstance(head, ast.Constant) and isinstance(head.value, str):
        return head.value
    if isinstance(head, ast.Name):
        return consts.get(head.id)
    return None


def _own_nodes(fn: ast.AST):
    """Walk ``fn`` without descending into nested function/class scopes
    (those are analyzed as their own scopes)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _scan_scope(fn: ast.AST, role: str, consts: Dict[str, str],
                sends: List[_Send], dispatches: List[_Dispatch]) -> None:
    recv_vars: Set[str] = set()
    tag_vars: Set[str] = set()
    recv_line = 0
    arms: Dict[str, int] = {}
    nodes = list(_own_nodes(fn))
    # visit order is not source order: resolve the recv-var and tag-var
    # bindings first, then read compares/sends against the full sets
    for node in nodes:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and isinstance(node.value.func, ast.Attribute) \
                and node.value.func.attr == "recv":
            recv_vars.add(node.targets[0].id)
            if not recv_line or node.lineno < recv_line:
                recv_line = node.lineno
    for node in nodes:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Subscript) \
                and isinstance(node.value.value, ast.Name) \
                and node.value.value.id in recv_vars \
                and isinstance(node.value.slice, ast.Constant) \
                and node.value.slice.value == 0:
            tag_vars.add(node.targets[0].id)
    for node in nodes:
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute):
            if node.func.attr in SEND_ATTRS and node.args:
                tag = _tuple_tag(node.args[0], consts)
                if tag is not None:
                    sends.append(_Send(tag, node.lineno, role))
            elif node.func.attr in BROADCAST_ATTRS:
                for arg in node.args:
                    tag = _tuple_tag(arg, consts)
                    if tag is not None:
                        sends.append(_Send(tag, node.lineno, role))
                        break
        elif isinstance(node, ast.Compare) and len(node.ops) == 1 \
                and len(node.comparators) == 1:
            left, cmp = node.left, node.comparators[0]
            is_tag = ((isinstance(left, ast.Name) and left.id in tag_vars)
                      or (isinstance(left, ast.Subscript)
                          and isinstance(left.value, ast.Name)
                          and left.value.id in recv_vars
                          and isinstance(left.slice, ast.Constant)
                          and left.slice.value == 0))
            if not is_tag:
                continue
            if isinstance(node.ops[0], ast.Eq):
                if isinstance(cmp, ast.Constant) \
                        and isinstance(cmp.value, str):
                    arms.setdefault(cmp.value, node.lineno)
            elif isinstance(node.ops[0], ast.In):
                if isinstance(cmp, (ast.Tuple, ast.List, ast.Set)):
                    for e in cmp.elts:
                        if isinstance(e, ast.Constant) \
                                and isinstance(e.value, str):
                            arms.setdefault(e.value, node.lineno)
    if arms:
        dispatches.append(_Dispatch(role, recv_line or fn.lineno, arms))


def _analyze_module(mod: ModuleInfo, findings: List[Finding]) -> None:
    spans = child_spans(mod)
    if not spans:
        return
    consts = _module_consts(mod)
    sends: List[_Send] = []
    dispatches: List[_Dispatch] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            role = "worker" if in_spans(node.lineno, spans) \
                else "coordinator"
            _scan_scope(node, role, consts, sends, dispatches)
    for role, other in (("worker", "coordinator"),
                        ("coordinator", "worker")):
        sent: Dict[str, int] = {}
        for s in sends:
            if s.role == role and (s.tag not in sent
                                   or s.line < sent[s.tag]):
                sent[s.tag] = s.line
        receivers = [d for d in dispatches if d.role == other]
        full = [d for d in receivers if len(d.arms) >= MIN_DISPATCH_ARMS]
        for tag in sorted(sent):
            if not full:
                findings.append(Finding(
                    "protocol-unhandled-message", mod.path, sent[tag],
                    f"{role} code sends (\"{tag}\", ...) but no "
                    f"{other}-side dispatch (recv loop with >= "
                    f"{MIN_DISPATCH_ARMS} arms) exists in this module "
                    f"to handle it"))
                continue
            for d in full:
                if tag not in d.arms:
                    findings.append(Finding(
                        "protocol-unhandled-message", mod.path,
                        d.recv_line,
                        f"{other}-side dispatch handles "
                        f"{sorted(d.arms)} but not \"{tag}\" (sent by "
                        f"{role} code at line {sent[tag]}); an unhandled "
                        f"tag is the PR 7 wedge shape — every legal "
                        f"message needs an arm"))
        for d in receivers:
            for tag in sorted(d.arms):
                if tag not in sent:
                    findings.append(Finding(
                        "protocol-dead-arm", mod.path, d.arms[tag],
                        f"{other}-side dispatch arm \"{tag}\" has no "
                        f"{role}-side sender in this module; dead arms "
                        f"hide renamed or removed tags"))


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        _analyze_module(mod, findings)
    return findings
