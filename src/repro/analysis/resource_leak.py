"""Pass 7 — OS-resource lifecycle discipline.

Shared-memory segments outlive the process that forgot to unlink them,
worker processes outlive the job that forgot to join them, and pipe fds
accumulate until the coordinator hits EMFILE — the PR 7 shm-leak guard
exists because exactly this happened.  This pass demands *release
evidence on every path* for each acquisition of ``SharedMemory`` /
``Process`` / ``Pipe`` / ``open`` / ``os.open`` / ``socket`` /
``tempfile.*``:

* acquisition inside a ``with`` statement (or a later ``with x:``) —
  safe by construction;
* a release call (``close``/``unlink``/``terminate``/``join``/...)
  inside a ``finally`` block, or ``weakref.finalize`` registration —
  safe on exception paths;
* a release only in straight-line code — flagged as *success-path
  only*: the acquisition leaks when anything in between raises;
* ownership transfer — returning the resource, storing it into an
  attribute/container, passing it positionally to a constructor
  (capitalized callee), or handing it to an ``append``/``register``/
  ``finalize``-style call — moves the obligation to the new owner and
  satisfies this pass.  Keyword arguments do NOT transfer ownership
  (``Process(args=(conn, ...))`` ships a *copy* to the child; the
  parent's fd still needs closing).

For ``self.attr = SharedMemory(...)`` the evidence is interprocedural
via the class's method flows: some method of the class (or a base) must
call a release method on that attribute, or register it with
``weakref.finalize``/``atexit`` (see ``ShmRing`` in core/shm_ring.py for
the reference pattern — the fixture "leak hidden behind a ``self.*()``
helper" is exactly an acquisition in a helper with no such method
anywhere).

Rule: ``resource-leak``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .model import (AnalysisContext, ClassInfo, Finding, ModuleInfo,
                    dotted_name, import_aliases)

#: dotted acquisition targets (resolved through import aliases)
ACQUIRE_DOTTED = frozenset({
    "multiprocessing.Pipe", "multiprocessing.Process",
    "multiprocessing.connection.Pipe",
    "multiprocessing.shared_memory.SharedMemory",
    "os.open", "os.fdopen", "os.pipe",
    "io.open", "gzip.open", "builtins.open",
    "socket.socket", "socket.create_connection",
    "tempfile.mkstemp", "tempfile.mkdtemp", "tempfile.TemporaryFile",
    "tempfile.NamedTemporaryFile",
})
#: bare-name fallbacks that acquire even when un-aliased
ACQUIRE_BUILTINS = frozenset({"open"})
#: attribute-call fallbacks: these constructor names acquire no matter
#: how the receiver was obtained (``ctx = multiprocessing.get_context(
#: "fork"); ctx.Pipe()`` defeats import-alias resolution)
ACQUIRE_ATTRS = frozenset({
    "Pipe", "Process", "SharedMemory", "Pool", "NamedTemporaryFile",
    "TemporaryFile",
})

#: method names that release the receiver
RELEASE_METHODS = frozenset({
    "close", "unlink", "terminate", "kill", "join", "shutdown",
    "release", "cancel", "detach", "stop", "cleanup",
})
#: module functions that release their first argument
RELEASE_FUNCS = frozenset({
    "os.close", "os.unlink", "os.remove", "os.replace", "os.rmdir",
    "shutil.rmtree",
})
#: callee names that take ownership of argument resources
TRANSFER_CALLEES = frozenset({
    "append", "appendleft", "add", "put", "insert", "push", "extend",
    "setdefault", "register", "finalize", "track", "adopt",
})


def _acquisition_kind(call: ast.Call,
                      aliases: Dict[str, str]) -> Optional[str]:
    dotted = dotted_name(call.func, aliases)
    if dotted in ACQUIRE_DOTTED:
        return dotted.rsplit(".", 1)[-1]
    if isinstance(call.func, ast.Name) \
            and call.func.id in ACQUIRE_BUILTINS \
            and call.func.id not in aliases:
        return call.func.id
    if isinstance(call.func, ast.Attribute) \
            and call.func.attr in ACQUIRE_ATTRS:
        return call.func.attr
    return None


class _ScopeScan:
    """Release/escape evidence for the local names of one function."""

    def __init__(self, fn: ast.AST, aliases: Dict[str, str]):
        self.aliases = aliases
        self.released_finally: Set[str] = set()
        self.released_except: Set[str] = set()
        self.released_normal: Set[str] = set()
        self.escaped: Set[str] = set()
        self.with_managed: Set[str] = set()
        self._walk(list(getattr(fn, "body", [])), in_finally=False,
                   in_except=False)

    # -- statement walk (tracks finally/except context) ---------------------
    def _walk(self, body: List[ast.stmt], in_finally: bool,
              in_except: bool) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Try):
                self._walk(stmt.body, in_finally, in_except)
                for h in stmt.handlers:
                    self._walk(h.body, in_finally, True)
                self._walk(stmt.orelse, in_finally, in_except)
                self._walk(stmt.finalbody, True, in_except)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    if isinstance(item.context_expr, ast.Name):
                        self.with_managed.add(item.context_expr.id)
                    self._scan(item.context_expr, in_finally, in_except)
                self._walk(stmt.body, in_finally, in_except)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                # a nested scope capturing the name keeps it alive and
                # may release it later: treat as escape-by-closure
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Name):
                        self.escaped.add(node.id)
                continue
            if isinstance(stmt, ast.Return) and stmt.value is not None:
                for node in ast.walk(stmt.value):
                    if isinstance(node, ast.Name):
                        self.escaped.add(node.id)
            if isinstance(stmt, ast.Assign):
                # storing into an attribute / container transfers
                # ownership to the holder
                if any(isinstance(t, (ast.Attribute, ast.Subscript))
                       for t in stmt.targets):
                    for node in ast.walk(stmt.value):
                        if isinstance(node, ast.Name):
                            self.escaped.add(node.id)
            for node in ast.walk(stmt):
                if isinstance(node, (ast.Yield, ast.YieldFrom)) \
                        and node.value is not None:
                    for sub in ast.walk(node.value):
                        if isinstance(sub, ast.Name):
                            self.escaped.add(sub.id)
            self._scan(stmt, in_finally, in_except)
            for attr in ("body", "orelse"):
                inner = getattr(stmt, attr, None)
                if isinstance(inner, list) and inner \
                        and isinstance(inner[0], ast.stmt):
                    self._walk(inner, in_finally, in_except)

    # -- expression scan ----------------------------------------------------
    def _scan(self, node: ast.AST, in_finally: bool,
              in_except: bool) -> None:
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            fn = call.func
            if isinstance(fn, ast.Attribute) \
                    and isinstance(fn.value, ast.Name) \
                    and fn.attr in RELEASE_METHODS:
                self._release(fn.value.id, in_finally, in_except)
            dotted = dotted_name(fn, self.aliases)
            if dotted in RELEASE_FUNCS and call.args \
                    and isinstance(call.args[0], ast.Name):
                self._release(call.args[0].id, in_finally, in_except)
            if dotted is not None and dotted.endswith(".finalize"):
                for arg in call.args:
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Name):
                            self.escaped.add(sub.id)
            # ownership transfer through calls
            callee = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None)
            if callee is None:
                continue
            takes_all = callee in TRANSFER_CALLEES
            is_ctor = callee[:1].isupper()
            if takes_all or is_ctor:
                for arg in call.args:        # positional args only
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Name):
                            self.escaped.add(sub.id)

    def _release(self, name: str, in_finally: bool,
                 in_except: bool) -> None:
        if in_finally:
            self.released_finally.add(name)
        elif in_except:
            self.released_except.add(name)
        else:
            self.released_normal.add(name)

    # -- verdict ------------------------------------------------------------
    def verdict(self, name: str) -> Optional[str]:
        """None == safe; otherwise the finding flavor."""
        if name in self.escaped or name in self.with_managed \
                or name in self.released_finally:
            return None
        if name in self.released_except and name in self.released_normal:
            return None
        if name in self.released_normal or name in self.released_except:
            return "success-path"
        return "never"


def _local_acquisitions(fn: ast.AST, aliases: Dict[str, str],
                        self_name: Optional[str]
                        ) -> Tuple[List[Tuple[str, int, str]],
                                   List[Tuple[str, int, str]],
                                   List[Tuple[int, str]]]:
    """(locals, self_attrs, anonymous) acquired in this scope (not
    descending into nested defs).  ``with ACQ(...)`` and acquisitions in
    a Return (ownership moves to the caller) are skipped."""
    local: List[Tuple[str, int, str]] = []
    attrs: List[Tuple[str, int, str]] = []
    anon: List[Tuple[int, str]] = []
    with_ctx: Set[int] = set()
    returned: Set[int] = set()
    arg_of_call: Set[int] = set()
    stack = list(ast.iter_child_nodes(fn))
    nodes: List[ast.AST] = []
    while stack:
        node = stack.pop()
        nodes.append(node)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))
    for node in nodes:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                with_ctx.add(id(item.context_expr))
        elif isinstance(node, ast.Return) and node.value is not None:
            for sub in ast.walk(node.value):
                returned.add(id(sub))
        elif isinstance(node, ast.Call):
            fn_ = node.func
            callee = fn_.attr if isinstance(fn_, ast.Attribute) else (
                fn_.id if isinstance(fn_, ast.Name) else None)
            # only ownership-taking callees (constructors, container/
            # registry adds) absorb an inline acquisition; an acquisition
            # passed to a plain call still leaks after the call returns
            if callee is not None and (callee[:1].isupper()
                                       or callee in TRANSFER_CALLEES):
                for arg in node.args:
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Call):
                            arg_of_call.add(id(sub))
    assigned: Set[int] = set()
    for node in nodes:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        kind = None
        if isinstance(node.value, ast.Call):
            kind = _acquisition_kind(node.value, aliases)
        if kind is None:
            continue
        assigned.add(id(node.value))
        tgt = node.targets[0]
        if isinstance(tgt, ast.Name):
            local.append((tgt.id, node.lineno, kind))
        elif isinstance(tgt, ast.Attribute) \
                and isinstance(tgt.value, ast.Name) \
                and tgt.value.id == self_name:
            attrs.append((tgt.attr, node.lineno, kind))
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                if isinstance(e, ast.Name):
                    local.append((e.id, node.lineno, kind))
                elif isinstance(e, ast.Attribute) \
                        and isinstance(e.value, ast.Name) \
                        and e.value.id == self_name:
                    attrs.append((e.attr, node.lineno, kind))
    for node in nodes:
        if isinstance(node, ast.Call) and id(node) not in assigned:
            kind = _acquisition_kind(node, aliases)
            if kind is None:
                continue
            if id(node) in with_ctx or id(node) in returned \
                    or id(node) in arg_of_call:
                continue
            anon.append((node.lineno, kind))
    return local, attrs, anon


def _class_release_evidence(ctx: AnalysisContext,
                            ci: ClassInfo) -> Set[str]:
    """Attributes some method along the inheritance chain releases."""
    out: Set[str] = set()
    for cur in ctx.mro_chain(ci):
        for mname in cur.methods:
            flow = cur.flow(mname)
            if flow is None:
                continue
            for attr, meth, _line in flow.attr_calls:
                if meth in RELEASE_METHODS:
                    out.add(attr)
            for attr in flow.shrinks:
                out.add(attr)
            # weakref.finalize / atexit.register mentioning self.attr
            for node in ast.walk(flow.node):
                if not isinstance(node, ast.Call):
                    continue
                dotted = dotted_name(node.func,
                                     import_aliases(cur.module))
                if dotted is None or not (
                        dotted.endswith(".finalize")
                        or dotted.startswith("atexit.")):
                    continue
                for arg in node.args:
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Attribute):
                            out.add(sub.attr)
    return out


def _self_name(fn: ast.AST) -> Optional[str]:
    args = getattr(fn, "args", None)
    if args is None:
        return None
    pos = args.posonlyargs + args.args
    return pos[0].arg if pos else None


def _scopes(mod: ModuleInfo):
    """(function node, owning ClassInfo or None) for every def."""
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            owner = None
            for ci in mod.classes.values():
                if node.name in ci.methods \
                        and ci.methods[node.name] is node:
                    owner = ci
                    break
            yield node, owner


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[Tuple[str, int]] = set()
    for mod in ctx.modules:
        aliases = import_aliases(mod)
        class_evidence: Dict[str, Set[str]] = {}
        for fn, owner in _scopes(mod):
            sname = _self_name(fn) if owner is not None else None
            local, attrs, anon = _local_acquisitions(fn, aliases, sname)
            if local:
                scan = _ScopeScan(fn, aliases)
                for name, line, kind in local:
                    flavor = scan.verdict(name)
                    if flavor is None or (mod.path, line) in seen:
                        continue
                    seen.add((mod.path, line))
                    if flavor == "never":
                        findings.append(Finding(
                            "resource-leak", mod.path, line,
                            f"`{name}` acquires {kind} but is never "
                            f"released, returned, or stored; close it "
                            f"(try/finally or with) or transfer "
                            f"ownership"))
                    else:
                        findings.append(Finding(
                            "resource-leak", mod.path, line,
                            f"`{name}` ({kind}) is released only on the "
                            f"success path; an exception in between "
                            f"leaks it — use try/finally or with"))
            for attr, line, kind in attrs:
                if owner is None or (mod.path, line) in seen:
                    continue
                if owner.name not in class_evidence:
                    class_evidence[owner.name] = \
                        _class_release_evidence(ctx, owner)
                if attr in class_evidence[owner.name]:
                    continue
                seen.add((mod.path, line))
                findings.append(Finding(
                    "resource-leak", mod.path, line,
                    f"{owner.name}.{attr} acquires {kind} but no method "
                    f"of the class (or its bases) releases it or "
                    f"registers a finalizer for it"))
            for line, kind in anon:
                if (mod.path, line) in seen:
                    continue
                seen.add((mod.path, line))
                findings.append(Finding(
                    "resource-leak", mod.path, line,
                    f"{kind} acquired without binding a name: the "
                    f"resource can never be released; use `with` or "
                    f"bind and close it"))
    return findings
