"""Console and JSON reporters for jetlint findings."""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

from .model import Finding


def split(findings: List[Finding]) -> Tuple[List[Finding], List[Finding]]:
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    return active, suppressed


def render_console(findings: List[Finding], files: int,
                   unused_suppressions: List[Tuple[str, int]],
                   show_suppressed: bool = False) -> str:
    active, suppressed = split(findings)
    lines: List[str] = []
    for f in sorted(active, key=lambda f: (f.path, f.line, f.rule)):
        lines.append(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
    if show_suppressed:
        for f in sorted(suppressed, key=lambda f: (f.path, f.line)):
            lines.append(f"{f.path}:{f.line}: [suppressed:{f.rule}] "
                         f"{f.message} (reason: {f.reason})")
    for path, line in unused_suppressions:
        lines.append(f"{path}:{line}: note: unused jetlint suppression")
    lines.append(
        f"jetlint: {len(active)} finding(s), {len(suppressed)} suppressed, "
        f"{files} file(s) scanned")
    return "\n".join(lines)


def render_json(findings: List[Finding], files: int,
                unused_suppressions: List[Tuple[str, int]]) -> str:
    active, suppressed = split(findings)
    counts: Dict[str, int] = {}
    for f in active:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    doc = {
        "tool": "jetlint",
        "version": 1,
        "files_scanned": files,
        "unsuppressed": len(active),
        "suppressed": len(suppressed),
        "counts_by_rule": counts,
        "findings": [f.to_json() for f in sorted(
            findings, key=lambda f: (f.path, f.line, f.rule))],
        "unused_suppressions": [
            {"path": p, "line": ln} for p, ln in unused_suppressions],
    }
    return json.dumps(doc, indent=2, sort_keys=True)
