"""Console and JSON reporters for jetlint findings.

Besides the findings themselves, both reporters carry the *suppression
inventory*: per rule, how many findings are currently argued-safe
(suppressed with a reason) and how many suppression comments no longer
match any finding.  The inventory is the early-warning channel for
suppression rot — an unused suppression means either the bug shape was
fixed (delete the comment) or the pass stopped seeing it (fix the pass).
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

from .model import Finding

#: an unused suppression site: (path, line, rules the comment names)
UnusedSite = Tuple[str, int, Tuple[str, ...]]


def split(findings: List[Finding]) -> Tuple[List[Finding], List[Finding]]:
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    return active, suppressed


def suppression_inventory(findings: List[Finding],
                          unused: List[UnusedSite]
                          ) -> Dict[str, Dict[str, int]]:
    """Per-rule counts of suppressed findings and unused suppressions."""
    inv: Dict[str, Dict[str, int]] = {}

    def slot(rule: str) -> Dict[str, int]:
        return inv.setdefault(rule, {"suppressed": 0, "unused": 0})

    for f in findings:
        if f.suppressed:
            slot(f.rule)["suppressed"] += 1
    for _path, _line, rules in unused:
        for rule in rules:
            slot(rule)["unused"] += 1
    return inv


def render_console(findings: List[Finding], files: int,
                   unused_suppressions: List[UnusedSite],
                   show_suppressed: bool = False) -> str:
    active, suppressed = split(findings)
    lines: List[str] = []
    for f in sorted(active, key=lambda f: (f.path, f.line, f.rule)):
        lines.append(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
    if show_suppressed:
        for f in sorted(suppressed, key=lambda f: (f.path, f.line)):
            lines.append(f"{f.path}:{f.line}: [suppressed:{f.rule}] "
                         f"{f.message} (reason: {f.reason})")
    for path, line, rules in unused_suppressions:
        lines.append(f"{path}:{line}: note: unused jetlint suppression "
                     f"({', '.join(rules)})")
    lines.append(
        f"jetlint: {len(active)} finding(s), {len(suppressed)} suppressed, "
        f"{files} file(s) scanned")
    return "\n".join(lines)


def render_json(findings: List[Finding], files: int,
                unused_suppressions: List[UnusedSite]) -> str:
    active, suppressed = split(findings)
    counts: Dict[str, int] = {}
    for f in active:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    doc = {
        "tool": "jetlint",
        "version": 2,
        "files_scanned": files,
        "unsuppressed": len(active),
        "suppressed": len(suppressed),
        "counts_by_rule": counts,
        "suppression_inventory": suppression_inventory(
            findings, unused_suppressions),
        "findings": [f.to_json() for f in sorted(
            findings, key=lambda f: (f.path, f.line, f.rule))],
        "unused_suppressions": [
            {"path": p, "line": ln, "rules": list(rules)}
            for p, ln, rules in unused_suppressions],
    }
    return json.dumps(doc, indent=2, sort_keys=True)
