"""jetlint core model: parsed modules, the class registry, suppressions,
findings, and the self-attribute dataflow used by every pass.

The analyzer is a thin AST framework: each pass is a function
``(AnalysisContext) -> Iterable[Finding]`` registered in
:mod:`repro.analysis.passes`.  Everything passes share lives here:

* **ModuleInfo / ClassInfo** — one parse per file, a cross-file registry
  of classes keyed by name so inheritance (``EPHEMERAL_STATE`` unions,
  Processor-subclass detection) resolves across modules;
* **suppressions** — ``# jetlint: disable=<rule>[,<rule>] -- <reason>``
  comments.  The reason is MANDATORY: a disable without one is itself a
  finding (``bad-suppression``) and suppresses nothing.  A suppression on
  a ``def``/``class`` header line covers the whole body; anywhere else it
  covers its own line only;
* **MethodFlow** — per-method self-attribute dataflow with local alias
  tracking (``frames = self.frames; frames[k] = ...`` counts as a write
  to ``self.frames``), the workhorse of the snapshot passes.  Besides
  mutator calls it records *every* method call whose receiver taints to a
  ``self`` attribute (``attr_calls``) — the ring-role pass reads queue
  roles (offer vs poll) off that registry;
* **process roles** — :func:`child_spans` computes which lines of a
  module run inside a forked worker process: the body of the
  ``_worker_main`` entry function (the multiprocess backend's child entry
  convention) plus every module-level function transitively reachable
  from it by plain-name calls.  The ring-role and protocol passes use it
  to tell coordinator-side code from worker-side code.

The alias model is deliberately simple — a single forward walk, no
fixpoint — and errs conservative: an alias carries the *set* of
attributes it might refer to, and mutating through it marks them all.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

#: comment syntax: "jetlint: disable=<rule>,<rule> -- reason text";
#: trailing comments cover their own line, standalone comment lines
#: cover the next line, and either form on/above a def/class header
#: covers the whole body
SUPPRESS_RE = re.compile(
    r"#\s*jetlint:\s*disable=([A-Za-z0-9_,\-]+)(?:\s+--\s*(\S.*?))?\s*$")

#: container-mutating method names: a call `x.append(...)` where `x`
#: aliases `self.attr` is a write to that attribute
MUTATOR_METHODS = frozenset({
    "append", "appendleft", "extend", "extendleft", "add", "update",
    "setdefault", "insert", "pop", "popitem", "popleft", "remove",
    "discard", "clear", "sort", "reverse",
})
#: subset that *grows* a container (the unbounded-growth heuristic)
GROWTH_METHODS = frozenset({
    "append", "appendleft", "extend", "extendleft", "add", "insert",
    "setdefault",
})
#: subset that shrinks/empties one (evidence of bounded growth)
SHRINK_METHODS = frozenset({
    "pop", "popitem", "popleft", "remove", "discard", "clear",
})
#: constructors whose result is a mutable container
CONTAINER_CTORS = frozenset({
    "dict", "list", "set", "deque", "defaultdict", "Counter",
    "OrderedDict", "bytearray",
})
#: engine-owned attributes every Processor has; never processor state
ENGINE_ATTRS = frozenset({"outbox", "ctx", "current_snapshot_id"})


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    reason: Optional[str] = None      # the suppression's reason, if any

    def to_json(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "message": self.message, "suppressed": self.suppressed}
        if self.reason:
            d["reason"] = self.reason
        return d


@dataclass
class Suppression:
    rules: Tuple[str, ...]
    reason: Optional[str]
    line: int
    #: inclusive line range this suppression covers
    scope: Tuple[int, int]
    used: bool = False


class ClassInfo:
    """One class definition plus the derived facts passes consume."""

    def __init__(self, node: ast.ClassDef, module: "ModuleInfo"):
        self.node = node
        self.module = module
        self.name = node.name
        self.base_names: List[str] = []
        for b in node.bases:
            if isinstance(b, ast.Name):
                self.base_names.append(b.id)
            elif isinstance(b, ast.Attribute):
                self.base_names.append(b.attr)
        self.methods: Dict[str, ast.FunctionDef] = {}
        #: class-level simple assignments: name -> value expression
        self.class_assigns: Dict[str, ast.expr] = {}
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.methods[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        self.class_assigns[tgt.id] = stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                if isinstance(stmt.target, ast.Name):
                    self.class_assigns[stmt.target.id] = stmt.value
        self._flows: Dict[str, MethodFlow] = {}

    def flow(self, method: str) -> Optional["MethodFlow"]:
        """Dataflow summary of one of this class's own methods (cached)."""
        if method not in self.methods:
            return None
        if method not in self._flows:
            self._flows[method] = MethodFlow(self.methods[method])
        return self._flows[method]


class ModuleInfo:
    """One parsed source file."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.classes: Dict[str, ClassInfo] = {}
        self.functions: Dict[str, ast.FunctionDef] = {}
        for stmt in self.tree.body:
            if isinstance(stmt, ast.ClassDef):
                self.classes[stmt.name] = ClassInfo(stmt, self)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[stmt.name] = stmt
        self.suppressions: List[Suppression] = []
        self.bad_suppressions: List[int] = []      # lines lacking a reason
        self._parse_suppressions()
        #: attribute names assigned a container display/ctor ANYWHERE in
        #: this module (`self.frames = {}`, `ks.ring = {}`): the
        #: aliasing pass treats reads of these names as live containers
        self.container_attr_names: Set[str] = set()
        self._collect_container_attrs()

    # -- suppressions -------------------------------------------------------
    def _comment_lines(self) -> List[Tuple[int, str]]:
        """(line, comment text) for every real COMMENT token — tokenizing
        keeps jetlint directives quoted inside strings/docstrings inert."""
        out: List[Tuple[int, str]] = []
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(self.source).readline):
                if tok.type == tokenize.COMMENT:
                    out.append((tok.start[0], tok.string))
        except (tokenize.TokenError, IndentationError):
            pass
        return out

    def _parse_suppressions(self) -> None:
        header_scopes: List[Tuple[int, int, int]] = []   # (hdr_lo, hdr_hi, end)
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                body_start = node.body[0].lineno if node.body else node.lineno
                header_scopes.append(
                    (node.lineno, body_start, node.end_lineno or node.lineno))
        src_lines = self.source.splitlines()
        for i, text in self._comment_lines():
            m = SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            reason = m.group(2)
            if not reason:
                self.bad_suppressions.append(i)
                continue
            # a standalone comment line governs the NEXT line; a trailing
            # comment governs its own line
            standalone = (i <= len(src_lines)
                          and src_lines[i - 1].lstrip().startswith("#"))
            target = i + 1 if standalone else i
            scope = (i, target)
            for lo, body_start, end in header_scopes:
                # a suppression governing a def/class header line
                # (decorators through the signature) covers the whole body
                if lo <= target < body_start:
                    scope = (lo, end)
                    break
            self.suppressions.append(Suppression(rules, reason, i, scope))

    def suppression_for(self, rule: str, line: int) -> Optional[Suppression]:
        best = None
        for s in self.suppressions:
            if rule in s.rules and s.scope[0] <= line <= s.scope[1]:
                # prefer the narrowest covering scope (line-level beats
                # a whole-def suppression)
                if best is None or (s.scope[1] - s.scope[0]
                                    < best.scope[1] - best.scope[0]):
                    best = s
        return best

    # -- container attribute registry ---------------------------------------
    def _collect_container_attrs(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            else:
                continue
            if not _is_container_expr(node.value):
                continue
            for tgt in targets:
                if isinstance(tgt, ast.Attribute):
                    self.container_attr_names.add(tgt.attr)


def _is_container_expr(expr: ast.expr) -> bool:
    if isinstance(expr, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                         ast.SetComp, ast.DictComp)):
        return True
    if isinstance(expr, ast.Call):
        fn = expr.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        return name in CONTAINER_CTORS
    return False


# ---------------------------------------------------------------------------
# Per-method self-attribute dataflow
# ---------------------------------------------------------------------------


@dataclass
class MethodFlow:
    """Reads/writes of ``self.*`` within one method, with alias tracking.

    ``writes``/``reads`` are attribute names; ``self_calls`` the names of
    methods invoked on ``self`` (directly or through a bound-method
    alias); ``mutator_calls`` records (attr, method, line) for every
    container-mutating call that resolved to a self attribute; ``writes``
    includes those.  ``attr_calls`` is the superset registry: (attr,
    method, line) for EVERY method call whose receiver taints to a self
    attribute (``self.q.offer(x)``, ``iq.q.poll()`` with ``iq`` aliasing
    an element of ``self.in_queues``) — role analyses read producer/
    consumer usage off it without caring about mutation.  ``element_container_attrs`` holds attributes for
    which this method shows evidence that the *elements* are mutable
    containers (``self.x.setdefault(k, []).append(...)``,
    ``self.x[k] = []``).
    """

    node: ast.FunctionDef = None  # type: ignore[assignment]
    reads: Set[str] = field(default_factory=set)
    writes: Set[str] = field(default_factory=set)
    write_lines: Dict[str, int] = field(default_factory=dict)
    self_calls: Set[str] = field(default_factory=set)
    mutator_calls: List[Tuple[str, str, int]] = field(default_factory=list)
    attr_calls: List[Tuple[str, str, int]] = field(default_factory=list)
    element_container_attrs: Set[str] = field(default_factory=set)
    #: local name -> set of (attr, depth) this name may alias.  depth 0 =
    #: the attribute's value itself, 1 = an element/derived view of it.
    aliases: Dict[str, Set[Tuple[str, int]]] = field(default_factory=dict)
    #: self attrs (re)assigned a fresh container in this method
    container_resets: Set[str] = field(default_factory=set)
    #: self attrs shrunk here via `del self.x[...]` / `del self.x`
    shrinks: Set[str] = field(default_factory=set)

    def __init__(self, node: ast.FunctionDef):
        self.node = node
        self.reads = set()
        self.writes = set()
        self.write_lines = {}
        self.self_calls = set()
        self.mutator_calls = []
        self.attr_calls = []
        self.element_container_attrs = set()
        self.aliases = {}
        self.container_resets = set()
        self.shrinks = set()
        self._self_name = None
        args = node.args.posonlyargs + node.args.args
        if args:
            self._self_name = args[0].arg
        self._walk_body(node.body)
        # every mutator call through an alias is a write
        for attr, _m, line in self.mutator_calls:
            self.writes.add(attr)
            self.write_lines.setdefault(attr, line)

    # -- taint -------------------------------------------------------------
    def taints(self, expr: ast.expr) -> Set[Tuple[str, int]]:
        """(attr, depth) pairs ``expr`` may alias."""
        if isinstance(expr, ast.Attribute):
            base = expr.value
            if isinstance(base, ast.Name) and base.id == self._self_name:
                return {(expr.attr, 0)}
            inner = self.taints(base)
            # attribute-of-alias stays tainted by the same attrs (reading
            # `ks.ring` off an element of self.key_state still aliases
            # key_state's guts); depth saturates at 1
            return {(a, max(d, 1) if not (isinstance(base, ast.Name)
                                          and base.id == self._self_name)
                     else d) for a, d in inner}
        if isinstance(expr, ast.Name):
            return set(self.aliases.get(expr.id, ()))
        if isinstance(expr, ast.Subscript):
            return {(a, 1) for a, _d in self.taints(expr.value)}
        if isinstance(expr, ast.Call):
            # `self.method(...)` / `self.factory(...)`: the callee is a
            # callable attribute, the result is NOT derived from stored
            # container state — do not taint
            if isinstance(expr.func, ast.Attribute) \
                    and isinstance(expr.func.value, ast.Name) \
                    and expr.func.value.id == self._self_name:
                return set()
            # a call through a tainted callee (frames.get(k), or a bound
            # method alias) returns something derived from the container
            return {(a, 1) for a, _d in self.taints(expr.func)}
        if isinstance(expr, ast.IfExp):
            return self.taints(expr.body) | self.taints(expr.orelse)
        if isinstance(expr, ast.BoolOp):
            out: Set[Tuple[str, int]] = set()
            for v in expr.values:
                out |= self.taints(v)
            return out
        if isinstance(expr, (ast.Tuple, ast.List)):
            out = set()
            for e in expr.elts:
                out |= self.taints(e)
            return out
        if isinstance(expr, ast.Starred):
            return self.taints(expr.value)
        return set()

    def _attrs_of(self, expr: ast.expr) -> Set[str]:
        return {a for a, _d in self.taints(expr)}

    # -- statement walk ----------------------------------------------------
    def _walk_body(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self._walk_stmt(stmt)

    def _walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            self._scan_expr(stmt.value)
            for tgt in stmt.targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    # calls can hide inside the target
                    # (`self.x.setdefault(k, {})[j] = v`)
                    self._scan_expr(tgt.value)
                self._assign(tgt, stmt.value, stmt.lineno)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._scan_expr(stmt.value)
                self._assign(stmt.target, stmt.value, stmt.lineno)
        elif isinstance(stmt, ast.AugAssign):
            self._scan_expr(stmt.value)
            self._write_target(stmt.target, stmt.lineno)
            self._scan_expr(stmt.target)
        elif isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                self._write_target(tgt, stmt.lineno)
                inner = tgt.value if isinstance(
                    tgt, (ast.Subscript, ast.Attribute)) else tgt
                self.shrinks |= self._attrs_of(inner)
        elif isinstance(stmt, ast.Expr):
            self._scan_expr(stmt.value)
        elif isinstance(stmt, (ast.If,)):
            self._scan_expr(stmt.test)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter)
            self._bind_loop_target(stmt.target, stmt.iter)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self._scan_expr(stmt.test)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr)
            self._walk_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._walk_body(stmt.body)
            for h in stmt.handlers:
                self._walk_body(h.body)
            self._walk_body(stmt.orelse)
            self._walk_body(stmt.finalbody)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._scan_expr(stmt.value)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs: scan for reads/mutator calls with the outer
            # alias map (closures over self state)
            self._walk_body(stmt.body)
        elif isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self._scan_expr(stmt.exc)
        elif isinstance(stmt, ast.Assert):
            self._scan_expr(stmt.test)

    def _bind_loop_target(self, target: ast.expr, iter_expr: ast.expr) -> None:
        """Loop variables over a tainted iterable alias its elements."""
        taint = {(a, 1) for a, _d in self.taints(iter_expr)}
        for name in _target_names(target):
            self.aliases[name] = set(taint)

    def _assign(self, tgt: ast.expr, value: ast.expr, line: int) -> None:
        if isinstance(tgt, ast.Name):
            self.aliases[tgt.id] = set(self.taints(value))
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) \
                    and len(value.elts) == len(tgt.elts):
                for t, v in zip(tgt.elts, value.elts):
                    self._assign(t, v, line)
            else:
                taint = {(a, 1) for a, _d in self.taints(value)}
                for name in _target_names(tgt):
                    self.aliases[name] = set(taint)
        else:
            self._write_target(tgt, line)
            if isinstance(tgt, ast.Attribute) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == self._self_name \
                    and _is_container_expr(value):
                self.container_resets.add(tgt.attr)
            # `self.x[k] = []`: elements of x are mutable containers
            if isinstance(tgt, ast.Subscript) and _is_container_expr(value):
                self.element_container_attrs |= self._attrs_of(tgt.value)

    def _write_target(self, tgt: ast.expr, line: int) -> None:
        if isinstance(tgt, ast.Attribute):
            for attr in self._attrs_of(tgt.value) or set():
                self.writes.add(attr)
                self.write_lines.setdefault(attr, line)
            base = tgt.value
            if isinstance(base, ast.Name) and base.id == self._self_name:
                self.writes.add(tgt.attr)
                self.write_lines.setdefault(tgt.attr, line)
        elif isinstance(tgt, ast.Subscript):
            for attr in self._attrs_of(tgt.value):
                self.writes.add(attr)
                self.write_lines.setdefault(attr, line)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._write_target(e, line)

    # -- expression scan ---------------------------------------------------
    def _scan_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Attribute):
                base = node.value
                if isinstance(base, ast.Name) and base.id == self._self_name:
                    self.reads.add(node.attr)
            elif isinstance(node, ast.Call):
                self._scan_call(node)

    def _scan_call(self, call: ast.Call) -> None:
        fn = call.func
        if isinstance(fn, ast.Attribute):
            base_taint = self.taints(fn.value)
            if isinstance(fn.value, ast.Name) \
                    and fn.value.id == self._self_name:
                self.self_calls.add(fn.attr)
            else:
                for attr, _d in base_taint:
                    self.attr_calls.append((attr, fn.attr, call.lineno))
                if fn.attr in MUTATOR_METHODS:
                    for attr, _d in base_taint:
                        self.mutator_calls.append((attr, fn.attr, call.lineno))
            # `self.x.setdefault(k, []).append(...)`: elements of x are
            # mutable containers
            if fn.attr == "setdefault" and len(call.args) >= 2 \
                    and _is_container_expr(call.args[1]):
                for attr, _d in base_taint:
                    self.element_container_attrs.add(attr)
        elif isinstance(fn, ast.Name):
            # a call through a bound-method alias (`flush = self._flush`)
            for attr, depth in self.aliases.get(fn.id, ()):
                if depth == 0:
                    self.self_calls.add(attr)
        # `self.x[k] = []` handled in _assign; here catch
        # `self.x[k] = []`-style evidence inside expressions is N/A


def _target_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


# ---------------------------------------------------------------------------
# Cross-module registry + analysis context
# ---------------------------------------------------------------------------


class AnalysisContext:
    """All parsed modules plus the cross-file class registry."""

    def __init__(self, modules: List[ModuleInfo]):
        self.modules = modules
        #: unqualified class name -> ClassInfo (last definition wins; the
        #: analyzed tree has unique processor class names)
        self.registry: Dict[str, ClassInfo] = {}
        for mod in modules:
            self.registry.update(mod.classes)

    # -- inheritance helpers ------------------------------------------------
    def mro_chain(self, ci: ClassInfo) -> List[ClassInfo]:
        """The class plus every base resolvable by name, transitively."""
        out: List[ClassInfo] = []
        seen: Set[str] = set()
        stack = [ci]
        while stack:
            cur = stack.pop(0)
            if cur.name in seen:
                continue
            seen.add(cur.name)
            out.append(cur)
            for b in cur.base_names:
                base = self.registry.get(b)
                if base is not None:
                    stack.append(base)
        return out

    def is_processor(self, ci: ClassInfo) -> bool:
        """True when the class transitively subclasses ``Processor``.

        Bases that do not resolve in the registry fall back to a name
        heuristic (``...Processor`` / ``...Source`` / ``...Sink``) so a
        subclass of an un-analyzed base is still checked.
        """
        for cur in self.mro_chain(ci):
            for b in cur.base_names:
                if b == "Processor":
                    return True
                if b not in self.registry and (
                        b.endswith("Processor") or b.endswith("Source")
                        or b.endswith("Sink")):
                    return True
        return False

    def find_method(self, ci: ClassInfo, name: str
                    ) -> Optional[Tuple[ClassInfo, ast.FunctionDef]]:
        """Resolve a method along the registry-visible inheritance chain."""
        for cur in self.mro_chain(ci):
            if name in cur.methods:
                return cur, cur.methods[name]
        return None

    def declared_state(self, ci: ClassInfo, decl: str) -> FrozenSet[str]:
        """Union of ``EPHEMERAL_STATE`` / ``SNAPSHOT_STATE`` declarations
        along the inheritance chain."""
        out: Set[str] = set()
        for cur in self.mro_chain(ci):
            expr = cur.class_assigns.get(decl)
            if expr is not None:
                out |= _string_elements(expr)
        return frozenset(out)

    def reachable_flows(self, ci: ClassInfo, entries: Iterable[str]
                        ) -> Dict[str, Tuple[ClassInfo, MethodFlow]]:
        """Method name -> flow, for every method reachable from the entry
        methods via ``self.*()`` calls (inheritance-aware)."""
        out: Dict[str, Tuple[ClassInfo, MethodFlow]] = {}
        stack = list(entries)
        while stack:
            name = stack.pop()
            if name in out:
                continue
            hit = self.find_method(ci, name)
            if hit is None:
                continue
            owner, node = hit
            flow = owner.flow(node.name) if node.name in owner.methods else None
            if flow is None:
                continue
            out[name] = (owner, flow)
            stack.extend(flow.self_calls)
        return out


def import_aliases(mod: ModuleInfo) -> Dict[str, str]:
    """Local name -> dotted origin for every import in the module
    (``import time as _time`` -> ``{"_time": "time"}``; ``from time
    import sleep`` -> ``{"sleep": "time.sleep"}``)."""
    out: Dict[str, str] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def dotted_name(expr: ast.expr, aliases: Dict[str, str]) -> Optional[str]:
    """Resolve an attribute chain to a dotted path through the module's
    import aliases; None when the root is not an imported name."""
    parts: List[str] = []
    cur = expr
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    root = aliases.get(cur.id)
    if root is None:
        return None
    parts.append(root)
    return ".".join(reversed(parts))


WORKER_ENTRY = "_worker_main"


def child_spans(mod: ModuleInfo) -> List[Tuple[int, int]]:
    """(lineno, end_lineno) ranges of code that runs inside a forked worker
    process: the body of the module's ``_worker_main`` entry function plus
    every module-level function transitively reachable from it through
    plain-name calls.  Empty when the module has no worker entry — the
    ring-role pass then treats the whole module as single-role."""
    if WORKER_ENTRY not in mod.functions:
        return []
    spans: List[Tuple[int, int]] = []
    seen: Set[str] = set()
    stack = [WORKER_ENTRY]
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        fn = mod.functions.get(name)
        if fn is None:
            continue
        spans.append((fn.lineno, fn.end_lineno or fn.lineno))
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                if node.func.id in mod.functions:
                    stack.append(node.func.id)
    return spans


def in_spans(line: int, spans: List[Tuple[int, int]]) -> bool:
    return any(lo <= line <= hi for lo, hi in spans)


def _string_elements(expr: ast.expr) -> Set[str]:
    """String members of a set/tuple/list literal, possibly wrapped in a
    ``frozenset(...)`` / ``set(...)`` call."""
    if isinstance(expr, ast.Call) and expr.args:
        return _string_elements(expr.args[0])
    if isinstance(expr, (ast.Set, ast.Tuple, ast.List)):
        out: Set[str] = set()
        for e in expr.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
        return out
    return set()
