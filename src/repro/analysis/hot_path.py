"""Pass 3 — hot-path non-blocking.

Cooperative tasklets share one thread per core (paper §3): a single
``time.sleep``, lock acquisition, file/socket/subprocess call or
``print`` on the hot path stalls *every* vertex on that worker and blows
the 99.99th-percentile budget.  This pass walks the call graph
(interprocedural within a module) from

* the hot methods of every cooperative ``Processor`` subclass
  (``is_cooperative = False`` opts a class out — the engine gives those
  a dedicated thread), and
* ``call`` / ``run_iteration`` / ``step`` of every ``*Tasklet`` /
  ``*Worker`` class,

following ``self.*()`` calls (inheritance-aware) and calls to methods
that resolve unambiguously to exactly one class in the same module.
Known-safe calls (``time.perf_counter`` and friends) are allowlisted.

It also flags unbounded-growth allocation: a hot-path ``append`` / ``add``
/ ``extend`` / ``setdefault`` into a ``self.*`` container that no method
of the class ever shrinks, clears, deletes from, or reassigns — state
that can only grow has no place on a latency-bound path unless the
bound is argued in a suppression reason.

Rules: ``hot-path-blocking``, ``hot-path-unbounded-growth``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .model import (AnalysisContext, ClassInfo, ENGINE_ATTRS, Finding,
                    GROWTH_METHODS, ModuleInfo, MUTATOR_METHODS,
                    SHRINK_METHODS, dotted_name, import_aliases)

HOT_ENTRIES = ("process", "process_block", "on_watermark",
               "try_process_watermark", "complete", "complete_edge",
               "poll_async", "save_to_snapshot")
DRIVER_ENTRIES = ("call", "run_iteration", "step")

#: dotted-path prefixes that block (resolved through import aliases)
BLOCKING_PREFIXES = (
    "time.sleep", "subprocess.", "os.system", "os.popen", "os.wait",
    "socket.", "select.", "requests.", "urllib.", "http.client.",
)
#: attribute names that block regardless of receiver
BLOCKING_ATTRS = frozenset({"sleep", "acquire"})
#: blocking builtins
BLOCKING_BUILTINS = frozenset({"open", "input", "print"})
#: known-safe dotted paths (clock reads look like time.* but never block)
SAFE_CALLS = frozenset({
    "time.perf_counter", "time.perf_counter_ns", "time.monotonic",
    "time.monotonic_ns", "time.time", "time.time_ns",
    "time.process_time",
})


def _blocking_reason(call: ast.Call, aliases: Dict[str, str]
                     ) -> Optional[str]:
    fn = call.func
    dotted = dotted_name(fn, aliases)
    if dotted:
        if dotted in SAFE_CALLS:
            return None
        for pre in BLOCKING_PREFIXES:
            if dotted == pre or dotted.startswith(pre):
                return dotted
        if dotted in ("builtins.open", "builtins.print"):
            return dotted
    if isinstance(fn, ast.Attribute) and fn.attr in BLOCKING_ATTRS:
        return f".{fn.attr}()"
    if isinstance(fn, ast.Name) and fn.id in BLOCKING_BUILTINS \
            and fn.id not in aliases:
        return f"{fn.id}()"
    return None


def _method_owners(mod: ModuleInfo) -> Dict[str, List[ClassInfo]]:
    owners: Dict[str, List[ClassInfo]] = {}
    for ci in mod.classes.values():
        for m in ci.methods:
            owners.setdefault(m, []).append(ci)
    return owners


def _is_cooperative(ctx: AnalysisContext, ci: ClassInfo) -> bool:
    for cur in ctx.mro_chain(ci):
        expr = cur.class_assigns.get("is_cooperative")
        if isinstance(expr, ast.Constant):
            return bool(expr.value)
    return True


def _class_shrunk_attrs(ci: ClassInfo) -> Set[str]:
    out: Set[str] = set()
    for m in ci.methods:
        flow = ci.flow(m)
        out |= flow.shrinks
        if m not in ("__init__", "init"):
            # a fresh-container assignment bounds growth — except the
            # initial one in the constructor, which bounds nothing
            out |= flow.container_resets
        for attr, meth, _line in flow.mutator_calls:
            if meth in SHRINK_METHODS:
                out.add(attr)
    return out


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        aliases = import_aliases(mod)
        owners = _method_owners(mod)
        roots: List[Tuple[ClassInfo, str, str]] = []   # (class, method, why)
        for ci in mod.classes.values():
            if ci.name == "Processor":
                continue
            if ctx.is_processor(ci):
                if not _is_cooperative(ctx, ci):
                    continue
                for entry in HOT_ENTRIES:
                    hit = ctx.find_method(ci, entry)
                    if hit and hit[0].name != "Processor":
                        roots.append((ci, entry,
                                      f"cooperative {ci.name}.{entry}"))
            elif ci.name.endswith("Tasklet") or ci.name.endswith("Worker"):
                for entry in DRIVER_ENTRIES:
                    if entry in ci.methods:
                        roots.append((ci, entry, f"{ci.name}.{entry}"))

        seen_block: Set[Tuple[str, int]] = set()
        seen_growth: Set[Tuple[str, str]] = set()
        for root_ci, root_entry, why in roots:
            visited: Set[Tuple[str, str]] = set()
            stack: List[Tuple[ClassInfo, str]] = [(root_ci, root_entry)]
            while stack:
                ci, mname = stack.pop()
                hit = ctx.find_method(ci, mname)
                if hit is None or hit[0].name == "Processor":
                    continue
                owner, _node = hit
                key = (owner.name, mname)
                if key in visited:
                    continue
                visited.add(key)
                flow = owner.flow(mname)
                if flow is None:
                    continue
                # 1) blocking calls anywhere in the method body
                for call in ast.walk(flow.node):
                    if not isinstance(call, ast.Call):
                        continue
                    reason = _blocking_reason(call, aliases)
                    if reason is None:
                        continue
                    fkey = (owner.module.path, call.lineno)
                    if fkey in seen_block:
                        continue
                    seen_block.add(fkey)
                    findings.append(Finding(
                        "hot-path-blocking", owner.module.path, call.lineno,
                        f"blocking call `{reason}` reachable from {why} "
                        f"(in {owner.name}.{mname}); cooperative hot paths "
                        f"must never block the worker thread"))
                # 2) unbounded growth — `self.*` in the flow refers to the
                # owning class's instance, so shrink evidence and the
                # report both belong to the owner, not the BFS root
                if ctx.is_processor(owner):
                    shrunk = _class_shrunk_attrs(owner)
                    for attr, meth, line in flow.mutator_calls:
                        if meth not in GROWTH_METHODS or attr in shrunk \
                                or attr in ENGINE_ATTRS:
                            continue
                        gkey = (owner.name, attr)
                        if gkey in seen_growth:
                            continue
                        seen_growth.add(gkey)
                        findings.append(Finding(
                            "hot-path-unbounded-growth", owner.module.path,
                            line,
                            f"{owner.name}: self.{attr} only ever grows "
                            f"({meth} on the hot path, never shrunk or "
                            f"reset anywhere in the class); bound it or "
                            f"suppress with the reason it is bounded"))
                # 3) recurse: self calls + unambiguous same-module methods
                stack.extend((ci, c) for c in flow.self_calls)
                for call in ast.walk(flow.node):
                    if isinstance(call, ast.Call) \
                            and isinstance(call.func, ast.Attribute):
                        # container-op names (append/extend/add/...) are
                        # almost always builtin list/dict calls, not the
                        # same-named method of an unrelated class
                        mname2 = call.func.attr
                        if mname2 in MUTATOR_METHODS:
                            continue
                        cands = owners.get(mname2, [])
                        if len(cands) == 1 and cands[0].name != ci.name:
                            stack.append((cands[0], mname2))
    return findings
