"""Pass 4 — block-form purity and accepts_blocks agreement.

The columnar fast path hangs off two declarations that must stay
consistent with the code:

* ``block_form(scalar_fn, block_fn)`` attaches a whole-block variant the
  planner substitutes for the scalar function.  The block variant must
  be a *pure column expression* — attribute/subscript reads off the
  block (``blk.cols["kind"]``, ``blk.key``), comparisons, arithmetic,
  and whitelisted vector ops (``np.*``, array methods like ``astype``,
  safe builtins).  Python-level loops, mutation, or arbitrary calls
  inside a block form defeat the point (it runs per *block*, not per
  event, and may run on device buffers).

* ``accepts_blocks`` tells the tasklet whether to hand a processor
  whole :class:`EventBlock`\\ s or explode them into scalar events at
  the queue boundary.  A class that declares ``accepts_blocks = True``
  but never handles ``EventBlock`` drops data; one that handles
  ``EventBlock`` but never declares will never receive one (dead code
  that masks a perf regression).

Rules: ``block-form-impure``, ``block-form-mismatch``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional

from .model import AnalysisContext, ClassInfo, Finding, ModuleInfo, \
    dotted_name, import_aliases

SAFE_BUILTINS = frozenset({"len", "abs", "min", "max", "int", "float",
                           "bool", "round"})
#: ndarray / column methods a block form may call
SAFE_METHODS = frozenset({"astype", "copy", "view", "reshape", "any", "all",
                          "sum", "nonzero", "searchsorted", "get", "clip"})
SAFE_MODULE_ROOTS = ("numpy", "math")

PROCESS_ENTRIES = ("process", "process_block")


def _check_block_fn(fn_node: ast.AST, mod: ModuleInfo,
                    aliases: Dict[str, str], findings: List[Finding],
                    where: str) -> None:
    for node in ast.walk(fn_node):
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            findings.append(Finding(
                "block-form-impure", mod.path, node.lineno,
                f"{where}: Python-level loop inside a block form — block "
                f"forms must be whole-column expressions"))
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            findings.append(Finding(
                "block-form-impure", mod.path, node.lineno,
                f"{where}: per-element comprehension inside a block form — "
                f"use column ops (np.*) instead"))
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            tgts = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in tgts:
                if isinstance(t, (ast.Subscript, ast.Attribute)):
                    findings.append(Finding(
                        "block-form-impure", mod.path, node.lineno,
                        f"{where}: block form mutates its input "
                        f"(`{ast.unparse(t)} = ...`); blocks are shared "
                        f"downstream and must not be written in place"))
        elif isinstance(node, ast.Call):
            if _call_allowed(node, aliases):
                continue
            findings.append(Finding(
                "block-form-impure", mod.path, node.lineno,
                f"{where}: call `{ast.unparse(node.func)}` is not a "
                f"whitelisted column op (np.*, array methods "
                f"{sorted(SAFE_METHODS)[:4]}..., safe builtins)"))


def _call_allowed(call: ast.Call, aliases: Dict[str, str]) -> bool:
    fn = call.func
    dotted = dotted_name(fn, aliases)
    if dotted and dotted.split(".")[0] in SAFE_MODULE_ROOTS:
        return True
    if isinstance(fn, ast.Name):
        return fn.id in SAFE_BUILTINS
    if isinstance(fn, ast.Attribute):
        return fn.attr in SAFE_METHODS
    return False


def _resolve_fn(expr: ast.expr, mod: ModuleInfo) -> Optional[ast.AST]:
    if isinstance(expr, ast.Lambda):
        return expr
    if isinstance(expr, ast.Name):
        return mod.functions.get(expr.id)
    return None


def _declares_accepts_blocks(ctx: AnalysisContext, ci: ClassInfo) -> bool:
    """accepts_blocks declared anywhere in the chain EXCLUDING the base
    Processor default (class attr or a self-write in any method)."""
    for cur in ctx.mro_chain(ci):
        if cur.name == "Processor":
            continue
        if "accepts_blocks" in cur.class_assigns:
            return True
        for m in cur.methods:
            if "accepts_blocks" in cur.flow(m).writes:
                return True
    return False


def _static_accepts_true(ctx: AnalysisContext, ci: ClassInfo) -> bool:
    for cur in ctx.mro_chain(ci):
        if cur.name == "Processor":
            continue
        expr = cur.class_assigns.get("accepts_blocks")
        if isinstance(expr, ast.Constant):
            return expr.value is True
    return False


def _handles_blocks(ctx: AnalysisContext, ci: ClassInfo) -> bool:
    flows = ctx.reachable_flows(ci, PROCESS_ENTRIES)
    for _name, (owner, flow) in flows.items():
        if owner.name == "Processor":
            continue
        for node in ast.walk(flow.node):
            if isinstance(node, ast.Name) and node.id == "EventBlock":
                return True
            if isinstance(node, ast.Attribute) and node.attr == "EventBlock":
                return True
    return "process_block" in {n for c in ctx.mro_chain(ci)
                               if c.name != "Processor" for n in c.methods}


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        aliases = import_aliases(mod)
        # (a) purity of every block_form registration in the module
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = node.func.id if isinstance(node.func, ast.Name) else (
                node.func.attr if isinstance(node.func, ast.Attribute)
                else None)
            if fname != "block_form" or len(node.args) < 2:
                continue
            scalar_src = ast.unparse(node.args[0])
            fn_node = _resolve_fn(node.args[1], mod)
            where = f"block_form({scalar_src}, ...) at line {node.lineno}"
            if fn_node is None:
                findings.append(Finding(
                    "block-form-impure", mod.path, node.lineno,
                    f"{where}: block fn is not a lambda or same-module "
                    f"function — the checker cannot prove it pure"))
                continue
            _check_block_fn(fn_node, mod, aliases, findings, where)

        # (b) accepts_blocks declarations must agree with the code
        for ci in mod.classes.values():
            if ci.name == "Processor" or not ctx.is_processor(ci):
                continue
            handles = _handles_blocks(ctx, ci)
            declares = _declares_accepts_blocks(ctx, ci)
            if _static_accepts_true(ctx, ci) and not handles:
                findings.append(Finding(
                    "block-form-mismatch", mod.path, ci.node.lineno,
                    f"{ci.name} declares accepts_blocks=True but its "
                    f"process path never handles EventBlock — incoming "
                    f"blocks would be treated as opaque events"))
            elif handles and not declares:
                findings.append(Finding(
                    "block-form-mismatch", mod.path, ci.node.lineno,
                    f"{ci.name} handles EventBlock in process but never "
                    f"declares accepts_blocks — the tasklet explodes blocks "
                    f"before they arrive, so the block path is dead code"))
    return findings
