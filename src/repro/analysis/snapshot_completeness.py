"""Pass 1 — snapshot-completeness.

Every ``self.*`` attribute a :class:`Processor` subclass mutates on the
hot path (``process`` / ``process_block`` / ``on_watermark`` /
``try_process_watermark`` / ``complete`` / ``complete_edge`` /
``poll_async``, plus everything those reach via ``self.*()`` calls) must
either

* be referenced in ``save_to_snapshot`` **and** in
  ``restore_from_snapshot`` / ``finish_snapshot_restore``, or
* appear in the class's ``EPHEMERAL_STATE`` declaration (state that is
  legitimately rebuilt after a restart), or
* appear in ``SNAPSHOT_STATE`` (state the author asserts is snapshotted
  under a transformed name the reference scan cannot see).

This is the PR 4 / PR 7 bug class: state that silently fails to survive
the Chandy-Lamport cycle degrades exactly-once to at-least-once.

Rules: ``snapshot-missing-save``, ``snapshot-missing-restore``.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Set, Tuple

from .model import AnalysisContext, ClassInfo, ENGINE_ATTRS, Finding

HOT_ENTRIES = ("process", "process_block", "on_watermark",
               "try_process_watermark", "complete", "complete_edge",
               "poll_async")
SAVE_ENTRIES = ("save_to_snapshot",)
RESTORE_ENTRIES = ("restore_from_snapshot", "finish_snapshot_restore")


def _entry_refs(ctx: AnalysisContext, ci: ClassInfo,
                entries: Iterable[str], skip_root: bool) -> Set[str]:
    """Attribute names referenced (read or written) anywhere reachable
    from the given entry methods.  ``skip_root`` ignores methods that
    resolve to the base ``Processor`` no-op defaults."""
    refs: Set[str] = set()
    for _name, (owner, flow) in ctx.reachable_flows(ci, entries).items():
        if skip_root and owner.name == "Processor":
            continue
        refs |= flow.reads | flow.writes
    return refs


def _has_hook(ctx: AnalysisContext, ci: ClassInfo, name: str) -> bool:
    hit = ctx.find_method(ci, name)
    return hit is not None and hit[0].name != "Processor"


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        for ci in mod.classes.values():
            if ci.name == "Processor" or not ctx.is_processor(ci):
                continue
            # hot-path mutations: attr -> (module_path, line) of first write
            mutated: Dict[str, Tuple[str, int]] = {}
            for _name, (owner, flow) in ctx.reachable_flows(
                    ci, HOT_ENTRIES).items():
                for attr in flow.writes:
                    if attr in ENGINE_ATTRS or attr.startswith("__"):
                        continue
                    line = flow.write_lines.get(attr, flow.node.lineno)
                    mutated.setdefault(attr, (owner.module.path, line))
            if not mutated:
                continue

            ephemeral = ctx.declared_state(ci, "EPHEMERAL_STATE")
            external = ctx.declared_state(ci, "SNAPSHOT_STATE")
            has_save = _has_hook(ctx, ci, "save_to_snapshot")
            has_restore = any(_has_hook(ctx, ci, m) for m in RESTORE_ENTRIES)
            saved = _entry_refs(ctx, ci, SAVE_ENTRIES, skip_root=True)
            restored = _entry_refs(ctx, ci, RESTORE_ENTRIES, skip_root=True)

            for attr, (path, line) in sorted(mutated.items()):
                if attr in ephemeral or attr in external:
                    continue
                if attr not in saved:
                    hint = ("the class defines no save_to_snapshot"
                            if not has_save else
                            "save_to_snapshot never references it")
                    findings.append(Finding(
                        "snapshot-missing-save", path, line,
                        f"{ci.name}: self.{attr} is mutated on the hot path "
                        f"but {hint}; snapshot it or declare it in "
                        f"EPHEMERAL_STATE with a reason"))
                    continue
                if attr not in restored:
                    hint = ("the class defines no restore hook"
                            if not has_restore else
                            "restore_from_snapshot/finish_snapshot_restore "
                            "never reference it")
                    findings.append(Finding(
                        "snapshot-missing-restore", path, line,
                        f"{ci.name}: self.{attr} is saved to snapshots but "
                        f"{hint}; restored jobs would silently lose it"))
    return findings
