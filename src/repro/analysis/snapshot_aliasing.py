"""Pass 2 — snapshot-aliasing.

Flags mutable ``self.*`` containers (or their elements) passed to
``offer_to_snapshot`` / a snapshot writer's ``put`` without a copy.  The
snapshot protocol acks asynchronously: between a processor's barrier and
the job-wide commit the processor keeps running and keeps mutating its
live containers, so a payload that aliases live state is corrupted by
the time it is committed — the exact PR 6 bug shape (fixed back then by
deep-copying at the writer; this pass keeps processor code honest at the
source too, since ad-hoc writers and ack payloads do not all copy).

Hazards, through the method's alias map:

* ``self.frames`` itself (any attribute the class ever assigns a
  container literal/constructor);
* a loop/element alias of such an attribute, when the class shows
  evidence that its *elements* are containers
  (``self.x.setdefault(k, []).append(...)``, ``self.x[k] = {}``);
* an attribute read off such an element (``ks.ring``) whose name is
  assigned a container anywhere in the module (``self.ring = {}``).

Copy wrappers (``dict()/list()/set()/tuple()/sorted()/copy()/
deepcopy()/x.copy()``) and comprehensions build fresh containers and
stop the scan.

Rule: ``snapshot-aliasing``.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Set, Tuple

from .model import AnalysisContext, ClassInfo, Finding, MethodFlow

COPY_CALLS = frozenset({"list", "dict", "set", "tuple", "sorted",
                        "frozenset", "bytes", "copy", "deepcopy"})

#: snapshot payload sinks: call-name -> index of the value argument
SINK_ARG = {"offer_to_snapshot": 1, "put": 3, "put_many": 1}


def _is_copy_call(call: ast.Call) -> bool:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id in COPY_CALLS
    if isinstance(fn, ast.Attribute):
        return fn.attr in COPY_CALLS
    return False


def _class_container_attrs(ci: ClassInfo) -> Tuple[Set[str], Set[str]]:
    """(attrs assigned a fresh container anywhere in the class,
    attrs whose elements are known to be containers)."""
    containers: Set[str] = set()
    elements: Set[str] = set()
    for m in ci.methods:
        flow = ci.flow(m)
        containers |= flow.container_resets
        elements |= flow.element_container_attrs
    return containers, elements


def _hazards(expr: ast.expr, flow: MethodFlow, containers: Set[str],
             elements: Set[str], module_container_names: Set[str]
             ) -> Iterator[Tuple[ast.expr, str]]:
    """Yield (node, description) for live-container references inside a
    snapshot payload expression."""
    if isinstance(expr, ast.Call):
        if _is_copy_call(expr):
            return                       # fresh container: scan stops here
        for a in expr.args:
            yield from _hazards(a, flow, containers, elements,
                                module_container_names)
        return
    if isinstance(expr, (ast.ListComp, ast.SetComp, ast.DictComp,
                         ast.GeneratorExp)):
        return                           # comprehension builds fresh
    if isinstance(expr, ast.Compare):
        return                           # comparison result is a bool
    if isinstance(expr, ast.IfExp):
        # only the branches can flow into the payload, not the test
        yield from _hazards(expr.body, flow, containers, elements,
                            module_container_names)
        yield from _hazards(expr.orelse, flow, containers, elements,
                            module_container_names)
        return
    if isinstance(expr, ast.Attribute):
        taint = flow.taints(expr)
        for attr, depth in taint:
            if depth == 0 and attr in containers:
                yield expr, f"self.{attr}"
                return
            if depth >= 1 and expr.attr in module_container_names:
                yield expr, f"live `{expr.attr}` container of self.{attr}"
                return
        return
    if isinstance(expr, ast.Name):
        for attr, depth in flow.taints(expr):
            if depth == 0 and attr in containers:
                yield expr, f"self.{attr} (via local `{expr.id}`)"
                return
            if depth >= 1 and attr in elements:
                yield expr, (f"mutable element of self.{attr} "
                             f"(via local `{expr.id}`)")
                return
        return
    for child in ast.iter_child_nodes(expr):
        if isinstance(child, ast.expr):
            yield from _hazards(child, flow, containers, elements,
                                module_container_names)


def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        for ci in mod.classes.values():
            containers, elements = _class_container_attrs(ci)
            for mname in ci.methods:
                flow = ci.flow(mname)
                for call in ast.walk(flow.node):
                    if not isinstance(call, ast.Call) \
                            or not isinstance(call.func, ast.Attribute):
                        continue
                    arg_ix = SINK_ARG.get(call.func.attr)
                    if arg_ix is None or len(call.args) <= arg_ix:
                        continue
                    if call.func.attr != "offer_to_snapshot":
                        # bare `.put` is common; only treat it as a
                        # snapshot sink on a writer-named receiver
                        recv = ast.unparse(call.func.value)
                        if "writer" not in recv.lower():
                            continue
                    value = call.args[arg_ix]
                    for _node, desc in _hazards(
                            value, flow, containers, elements,
                            mod.container_attr_names):
                        findings.append(Finding(
                            "snapshot-aliasing", mod.path, call.lineno,
                            f"{ci.name}.{mname}: snapshot payload aliases "
                            f"{desc}; the processor keeps mutating it before "
                            f"the snapshot commits — wrap it in a copy "
                            f"(dict()/list()/deepcopy)"))
    return findings
