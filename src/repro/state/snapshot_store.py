"""Snapshot storage on top of the IMap service (paper §2.4, §4.4).

Jet stores each job snapshot in an IMap whose partitioning matches the
computation's key partitioning, so a processor's state snapshot lives on
the same member as the processor (primary) plus its backups.  Snapshots are
two-phase: entries accumulate under an *ongoing* id and become visible to
recovery only after :meth:`commit` (all tasklets acked the barrier).

Recovery speaks to the store through three hooks that this in-memory
base class implements trivially and the durable subclass
(:class:`~repro.state.durable_store.DurableSnapshotStore`) makes real:

* :meth:`recovery_chain` — candidate snapshot ids, newest first.  Here:
  at most the single committed id.  Durable: the on-disk retention chain.
* :meth:`verify` — integrity check before a restore is attempted.  Here:
  always passes (process memory does not rot within one process
  lifetime).  Durable: manifest + per-segment CRC32.
* :meth:`prepare_restore` — materialize the chosen snapshot for
  ``entries_for_partition``.  Here: a no-op (it is already the live
  IMap).  Durable: rebuild the IMap from verified disk segments.

The engine's ``Job._select_restore_snapshot`` walks the chain through
these hooks, so every backend and both store flavours share one recovery
path.
"""

from __future__ import annotations

import copy
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .imap import IMap, IMapService

#: value types that cannot alias live processor state
_ATOMIC = (int, float, str, bytes, bool, type(None))


def own_snapshot_value(value):
    """Snapshot-time defensive copy — the serialization a real IMap would
    perform.  Processors snapshot their live containers (frame rings,
    session maps) by reference and keep mutating them after the barrier;
    storing the reference lets post-barrier execution corrupt the
    committed snapshot (rewound scalar fields next to advanced dicts), so
    the writer must take ownership at ``put`` time."""
    if type(value) in _ATOMIC:
        return value
    return copy.deepcopy(value)


class SnapshotWriter:
    """Tasklets write through this; bound to one (job, snapshot) epoch.

    Entries are stored under ``(vertex, instance, key)``: two parallel
    instances of one vertex may legitimately hold state under the SAME
    state key (e.g. the per-node stage-1 window accumulators' partials for
    one (key, frame)), and without the instance discriminator the second
    ``put`` silently overwrote the first — restored state lost one
    instance's share.  Recovery strips the discriminator and hands every
    entry to the new owner, whose ``restore_from_snapshot`` merges shards
    of one key (the documented restore contract).
    """

    def __init__(self, store: "SnapshotStore", job_id: str):
        self.store = store
        self.job_id = job_id

    def put(self, snapshot_id: int, vertex: str, key, value, pid: int,
            instance: int = 0) -> None:
        imap = self.store._map(self.job_id, snapshot_id)
        imap.put_with_pid((vertex, instance, key), own_snapshot_value(value),
                          pid)

    def put_many(self, entries: Iterable[Tuple[int, str, Any, Any, int,
                                               int]]) -> int:
        """Bulk ingest of ``(snapshot_id, vertex, key, value, pid,
        instance)`` tuples — the cross-process commit path: worker
        processes buffer their barrier-aligned state locally and ship it to
        the coordinator in one message per (worker, snapshot); the
        coordinator lands everything here before committing.  Returns the
        entry count.  Values are stored as handed over (no defensive copy):
        entries that crossed a process boundary were copied by pickling in
        transit, and the child-side buffer already took ownership."""
        n = 0
        imaps: Dict[int, IMap] = {}
        for snapshot_id, vertex, key, value, pid, instance in entries:
            imap = imaps.get(snapshot_id)
            if imap is None:
                imap = imaps[snapshot_id] = self.store._map(self.job_id,
                                                            snapshot_id)
            imap.put_with_pid((vertex, instance, key), value, pid)
            n += 1
        return n


class SnapshotStore:
    def __init__(self, service: IMapService):
        self.service = service
        # job -> latest committed snapshot id
        self.committed: Dict[str, int] = {}
        # job -> {snapshot_id: {"offsets": {...}}} (source replay positions)
        self.meta: Dict[str, Dict[int, Dict[str, Any]]] = {}

    def _map(self, job_id: str, snapshot_id: int) -> IMap:
        return IMap(self.service, f"__jet.snapshot.{job_id}.{snapshot_id}")

    def writer(self, job_id: str) -> SnapshotWriter:
        return SnapshotWriter(self, job_id)

    # -- lifecycle -------------------------------------------------------------
    def commit(self, job_id: str, snapshot_id: int) -> None:
        prev = self.committed.get(job_id)
        self.committed[job_id] = snapshot_id
        # retire the previous snapshot's storage (Jet keeps exactly one,
        # alternating between two map names; dropping the old one is the
        # equivalent here)
        if prev is not None and prev != snapshot_id:
            self._map(job_id, prev).destroy()

    def latest_committed(self, job_id: str) -> Optional[int]:
        return self.committed.get(job_id)

    # -- recovery-chain hooks (see module docstring) ---------------------------
    def recovery_chain(self, job_id: str) -> List[int]:
        """Candidate snapshot ids for recovery, newest first."""
        sid = self.committed.get(job_id)
        return [] if sid is None else [sid]

    def verify(self, job_id: str, snapshot_id: int) -> Tuple[bool, str]:
        """(ok, reason) — in-memory snapshots have nothing to verify."""
        return True, ""

    def prepare_restore(self, job_id: str,
                        snapshot_id: int) -> Tuple[bool, str]:
        """Materialize ``snapshot_id`` for ``entries_for_partition``;
        (ok, reason).  The in-memory store already holds it live."""
        return True, ""

    def discover_jobs(self) -> List[str]:
        """Job ids with at least one committed snapshot."""
        return sorted(self.committed)

    def set_meta(self, job_id: str, snapshot_id: int, key: str, value) -> None:
        self.meta.setdefault(job_id, {}).setdefault(snapshot_id, {})[key] = value

    def get_meta(self, job_id: str, snapshot_id: int, key: str, default=None):
        return self.meta.get(job_id, {}).get(snapshot_id, {}).get(key, default)

    # -- recovery ---------------------------------------------------------------
    def entries_for_partition(self, job_id: str, snapshot_id: int,
                              pid: int) -> List[Tuple[str, Any, Any]]:
        """[(vertex, key, value)] for one partition of a committed snapshot.
        Multiple entries may share (vertex, key) — one per instance that
        held a shard of that key's state."""
        imap = self._map(job_id, snapshot_id)
        return [(vertex, key, value)
                for (vertex, _inst, key), value
                in imap.entries_for_partition(pid).items()]

    def vertex_entries(self, job_id: str, snapshot_id: int,
                       vertex: str) -> List[Tuple[Any, Any]]:
        imap = self._map(job_id, snapshot_id)
        return [(key, value) for (v, _inst, key), value in imap.items().items()
                if v == vertex]

    def size(self, job_id: str, snapshot_id: int) -> int:
        return len(self._map(job_id, snapshot_id))
