"""Partitioned, replicated, in-memory maps — the Jet state backend (§4.2).

:class:`IMapService` models the IMDG member stores of a cluster: every
member holds the *primary* copy of the partitions it owns plus *backup*
copies of partitions owned by others, per the :class:`PartitionTable`.
Writes go to the primary and replicate synchronously to the backups
(Hazelcast's default ``backup-count=1`` sync semantics).

Failure handling mirrors Figure 6 of the paper: when a member dies, each of
its partitions is *promoted* on the surviving member that held the first
backup copy, and fresh backups are re-materialized on other members.  Data
is lost only if every replica of a partition dies inside one failure event.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from .partition import PartitionTable

# store key: (map_name, partition_id) -> {key: value}
_Store = Dict[Tuple[str, int], Dict[Any, Any]]


class IMapService:
    def __init__(self, members: Iterable[int], partition_count: int = 271,
                 backup_count: int = 1):
        self.table = PartitionTable(list(members), partition_count,
                                    backup_count)
        self.partition_count = partition_count
        self.stores: Dict[int, _Store] = {m: {} for m in self.table.members}
        # telemetry
        self.migrated_partitions = 0
        self.promoted_partitions = 0

    # -- data plane --------------------------------------------------------------
    def write(self, map_name: str, pid: int, key, value) -> None:
        for member in self.table.replicas(pid):
            self.stores[member].setdefault((map_name, pid), {})[key] = value

    def read(self, map_name: str, pid: int, key, default=None):
        owner = self.table.owner(pid)
        return self.stores[owner].get((map_name, pid), {}).get(key, default)

    def remove(self, map_name: str, pid: int, key) -> None:
        for member in self.table.replicas(pid):
            part = self.stores[member].get((map_name, pid))
            if part is not None:
                part.pop(key, None)

    def entries(self, map_name: str, pid: int) -> Dict[Any, Any]:
        owner = self.table.owner(pid)
        return dict(self.stores[owner].get((map_name, pid), {}))

    def all_entries(self, map_name: str) -> Dict[Any, Any]:
        out: Dict[Any, Any] = {}
        for pid in range(self.partition_count):
            out.update(self.entries(map_name, pid))
        return out

    def drop_map(self, map_name: str) -> None:
        for store in self.stores.values():
            for k in [k for k in store if k[0] == map_name]:
                del store[k]

    def map_names(self) -> Set[str]:
        return {name for store in self.stores.values() for (name, _) in store}

    # -- membership / failover -----------------------------------------------------
    def kill_member(self, member: int) -> List[int]:
        """Remove a member; promote backups (Fig. 6). Returns the list of
        partitions whose data was lost entirely (all replicas on the dead
        member — only possible with backup_count == 0)."""
        if member not in self.stores:
            raise KeyError(f"member {member} not in cluster")
        dead_store = self.stores.pop(member)
        survivors = [m for m in self.table.members if m != member]
        lost: List[int] = []
        # partitions that had a replica on the dead member
        affected = [p for p in range(self.partition_count)
                    if member in self.table.replicas(p)]
        was_primary = {p for p in affected if self.table.owner(p) == member}
        plan = self.table.change_membership(survivors)
        # ensure every replica in the new table has the data
        for pid in range(self.partition_count):
            new_reps = self.table.replicas(pid)
            # find any survivor holding this partition's maps (old replica)
            source: Optional[int] = None
            for m in self.stores:
                if any(k[1] == pid for k in self.stores[m]):
                    source = m
                    break
            if source is None:
                if any(k[1] == pid for k in dead_store):
                    lost.append(pid)
                continue
            src_maps = {k: dict(v) for k, v in self.stores[source].items()
                        if k[1] == pid}
            for m in new_reps:
                for k, data in src_maps.items():
                    dst = self.stores[m].setdefault(k, {})
                    for kk, vv in data.items():
                        dst.setdefault(kk, vv)
            if pid in was_primary:
                self.promoted_partitions += 1
        # drop copies on members that are no longer replicas
        self._garbage_collect()
        return lost

    def add_member(self, member: int) -> int:
        """Join a member and rebalance; returns number of migrated
        partitions (tests assert ~1/n, the consistent-hashing property)."""
        if member in self.stores:
            raise KeyError(f"member {member} already in cluster")
        self.stores[member] = {}
        plan = self.table.change_membership(
            list(self.table.members) + [member])
        moved = 0
        for pid, (old_reps, new_reps) in plan.items():
            src = next((m for m in old_reps if m in self.stores
                        and m not in (member,)), None)
            if src is None:
                continue
            src_maps = {k: dict(v) for k, v in self.stores[src].items()
                        if k[1] == pid}
            for m in new_reps:
                if m == src:
                    continue
                for k, data in src_maps.items():
                    dst = self.stores[m].setdefault(k, {})
                    for kk, vv in data.items():
                        dst.setdefault(kk, vv)
            moved += 1
        self.migrated_partitions += moved
        self._garbage_collect()
        return moved

    def _garbage_collect(self) -> None:
        for m, store in self.stores.items():
            stale = [k for k in store if m not in self.table.replicas(k[1])]
            for k in stale:
                del store[k]

    # -- introspection ---------------------------------------------------------
    def bytes_estimate(self) -> int:
        import sys
        return sum(sys.getsizeof(v) for store in self.stores.values()
                   for part in store.values() for v in part.values())


class IMap:
    """A named, partitioned, replicated key-value map (the public face)."""

    def __init__(self, service: IMapService, name: str):
        self.service = service
        self.name = name

    def _pid(self, key) -> int:
        return hash(key) % self.service.partition_count

    def put(self, key, value) -> None:
        self.service.write(self.name, self._pid(key), key, value)

    def put_with_pid(self, key, value, pid: int) -> None:
        """Write under an explicit partition (snapshot routing)."""
        self.service.write(self.name, pid, key, value)

    def get(self, key, default=None):
        return self.service.read(self.name, self._pid(key), key, default)

    def remove(self, key) -> None:
        self.service.remove(self.name, self._pid(key), key)

    def entries_for_partition(self, pid: int) -> Dict[Any, Any]:
        return self.service.entries(self.name, pid)

    def items(self) -> Dict[Any, Any]:
        return self.service.all_entries(self.name)

    def __len__(self) -> int:
        return len(self.items())

    def destroy(self) -> None:
        self.service.drop_map(self.name)
