"""IMDG-style state backend: consistent-hash partitioning, replicated
in-memory maps, snapshot store, failover and rebalancing (paper §4)."""

from .partition import PartitionTable
from .imap import IMapService, IMap
from .snapshot_store import SnapshotStore
from .durable_store import DurableSnapshotStore

__all__ = ["PartitionTable", "IMapService", "IMap", "SnapshotStore",
           "DurableSnapshotStore"]
