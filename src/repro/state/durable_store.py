"""Durable snapshot chain: committed snapshots spilled to disk, verified
on the way back in.

The in-memory :class:`~repro.state.snapshot_store.SnapshotStore` keeps
exactly one committed snapshot per job and keeps it *in this process* —
coordinator death loses every committed epoch, and a snapshot that rots
(disk corruption, torn write) is restored bit-for-bit without anybody
noticing.  :class:`DurableSnapshotStore` upgrades ``commit`` into a
durability point and recovery into a *verified* walk down a retention
chain:

* **Spill on commit.**  When a snapshot commits, its entries are read
  out of the IMap (preserving their explicit partition ids — routing
  never re-derives ``hash(key)`` across process generations) and written
  to ``<root>/<job_id>/snap-<id>/`` as pickled **segments** of bounded
  entry count, each guarded by a CRC32 over its exact byte payload.

* **Torn-write safety.**  Every file lands via the classic protocol:
  write to a ``*.tmp`` sibling, ``fsync`` the file, ``os.replace`` into
  place, ``fsync`` the directory.  The ``MANIFEST.json`` — carrying the
  job id, snapshot id, per-segment name/size/CRC and the job's replay
  meta — is written **last**, so a snapshot exists on disk iff its
  manifest does: a spill killed at any byte leaves the previous chain
  entry untouched and the torn directory unreferenced (reported as
  "manifest missing" if recovery ever looks at it).

* **Retention chain.**  Instead of destroying the predecessor at commit,
  the last ``retain`` committed snapshots stay on disk, newest first
  (:meth:`recovery_chain`).  In-memory IMap storage still keeps only the
  newest (the base-class behaviour) — disk is the durable tier.

* **Verified restore.**  :meth:`verify` checks manifest identity and
  every segment's size + CRC32 without unpickling anything;
  :meth:`prepare_restore` re-verifies while loading and rebuilds the
  snapshot's IMap from disk.  The engine restores **from disk, never
  from live memory** (``Job._select_restore_snapshot``), so a corrupted
  newest snapshot is *detected* and recovery falls back down the chain
  to the newest entry that still verifies — the skipped ids and reasons
  land in the job's recovery log.

* **Cold start.**  :meth:`discover_jobs` + the chain are all
  ``JetCluster.recover_job`` needs to adopt a job after full process
  death: nothing about recovery depends on the coordinator that wrote
  the snapshots still being alive.

Checksum granularity is the segment (default ≤512 entries): one flipped
bit invalidates one segment, which invalidates the snapshot — state is
all-or-nothing per epoch, matching the Chandy-Lamport consistency unit.
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import time as _time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from .imap import IMapService
from .snapshot_store import SnapshotStore

#: bumped when the on-disk layout changes; a mismatch fails verification
FORMAT_VERSION = 1
MANIFEST_NAME = "MANIFEST.json"
_SNAP_PREFIX = "snap-"


class DurableSnapshotStore(SnapshotStore):
    """Disk-backed snapshot chain (see module docstring for the contract)."""

    def __init__(self, service: IMapService, root,
                 retain: int = 3, segment_entries: int = 512):
        super().__init__(service)
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        #: committed snapshots kept on disk per job (the fallback chain)
        self.retain = max(1, retain)
        #: max entries per segment file (the checksum granularity)
        self.segment_entries = max(1, segment_entries)
        # adopt whatever chains already exist under root (cold start):
        # newest on-disk id becomes the in-memory "latest committed" even
        # before verification — verification happens at restore time,
        # where a bad head falls back down the chain with a recorded
        # reason instead of being silently ignored here
        for job_id in self.discover_jobs():
            chain = self.recovery_chain(job_id)
            if chain:
                self.committed[job_id] = chain[0]

    # -- paths ---------------------------------------------------------------
    def job_dir(self, job_id: str) -> Path:
        return self.root / job_id

    def snapshot_dir(self, job_id: str, snapshot_id: int) -> Path:
        return self.job_dir(job_id) / f"{_SNAP_PREFIX}{snapshot_id:08d}"

    def manifest_path(self, job_id: str, snapshot_id: int) -> Path:
        return self.snapshot_dir(job_id, snapshot_id) / MANIFEST_NAME

    def segment_paths(self, job_id: str, snapshot_id: int) -> List[Path]:
        d = self.snapshot_dir(job_id, snapshot_id)
        if not d.is_dir():
            return []
        return sorted(p for p in d.iterdir()
                      if p.name.startswith("seg-") and p.suffix == ".bin")

    # -- discovery -----------------------------------------------------------
    def discover_jobs(self) -> List[str]:
        """Job ids that left at least one snapshot directory under root."""
        if not self.root.is_dir():
            return []
        return sorted(d.name for d in self.root.iterdir()
                      if d.is_dir() and any(
                          c.name.startswith(_SNAP_PREFIX)
                          for c in d.iterdir() if c.is_dir()))

    def recovery_chain(self, job_id: str) -> List[int]:
        """Snapshot ids on disk for ``job_id``, newest first.  Includes
        torn/corrupt directories — the chain is *candidates*; per-entry
        health is :meth:`verify`'s job, so a bad entry is skipped with a
        recorded reason rather than silently invisible."""
        jd = self.job_dir(job_id)
        if not jd.is_dir():
            return []
        sids = []
        for d in jd.iterdir():
            if d.is_dir() and d.name.startswith(_SNAP_PREFIX):
                try:
                    sids.append(int(d.name[len(_SNAP_PREFIX):]))
                except ValueError:
                    continue
        return sorted(sids, reverse=True)

    def manifest(self, job_id: str, snapshot_id: int) -> Optional[Dict]:
        """Parsed manifest, or None when missing/unreadable."""
        try:
            return json.loads(
                self.manifest_path(job_id, snapshot_id).read_text())
        except (OSError, ValueError):
            return None

    # -- lifecycle -----------------------------------------------------------
    def commit(self, job_id: str, snapshot_id: int) -> None:
        """Spill the snapshot to disk (durability point: returns only
        after the manifest rename + fsync), then retire in-memory and
        on-disk predecessors beyond the retention chain."""
        prev = self.committed.get(job_id)
        self._spill(job_id, snapshot_id)
        self.committed[job_id] = snapshot_id
        if prev is not None and prev != snapshot_id:
            # in-memory tier keeps only the newest (base-class behaviour);
            # the chain lives on disk
            self._map(job_id, prev).destroy()
        self._trim(job_id)

    def _spill(self, job_id: str, snapshot_id: int) -> None:
        imap = self._map(job_id, snapshot_id)
        entries: List[Tuple[int, Any, Any]] = []
        for pid in range(self.service.partition_count):
            for key, value in imap.entries_for_partition(pid).items():
                entries.append((pid, key, value))
        d = self.snapshot_dir(job_id, snapshot_id)
        if d.exists():
            # stale torn spill of this same id from a previous coordinator
            shutil.rmtree(d)
        d.mkdir(parents=True)
        segments = []
        step = self.segment_entries
        chunks = [entries[i:i + step] for i in range(0, len(entries), step)] \
            or [[]]
        for idx, chunk in enumerate(chunks):
            payload = pickle.dumps(chunk, protocol=pickle.HIGHEST_PROTOCOL)
            name = f"seg-{idx:04d}.bin"
            _write_atomic(d / name, payload)
            segments.append({"name": name, "bytes": len(payload),
                             "crc32": zlib.crc32(payload),
                             "entries": len(chunk)})
        manifest = {
            "format": FORMAT_VERSION,
            "job_id": job_id,
            "snapshot_id": snapshot_id,
            "entries": len(entries),
            "segments": segments,
            # replay meta (source frontiers live in the entries themselves;
            # this is the job-level adoption info for recover_job)
            "meta": self.meta.get(job_id, {}).get(snapshot_id, {}),
            "written_unix": _time.time(),
        }
        _write_atomic(d / MANIFEST_NAME,
                      json.dumps(manifest, indent=1, default=repr).encode())

    def _trim(self, job_id: str) -> None:
        for sid in self.recovery_chain(job_id)[self.retain:]:
            shutil.rmtree(self.snapshot_dir(job_id, sid),
                          ignore_errors=True)

    # -- verification / restore ---------------------------------------------
    def verify(self, job_id: str, snapshot_id: int) -> Tuple[bool, str]:
        """Cheap integrity check: manifest identity plus every segment's
        size and CRC32 over raw bytes — no unpickling."""
        d = self.snapshot_dir(job_id, snapshot_id)
        if not d.is_dir():
            return False, "snapshot directory missing"
        mpath = d / MANIFEST_NAME
        if not mpath.exists():
            return False, "manifest missing (torn spill or deleted)"
        try:
            manifest = json.loads(mpath.read_text())
        except (OSError, ValueError) as e:
            return False, f"manifest unreadable: {e}"
        if manifest.get("format") != FORMAT_VERSION:
            return False, (f"manifest format {manifest.get('format')!r} "
                           f"!= {FORMAT_VERSION}")
        if (manifest.get("job_id") != job_id
                or manifest.get("snapshot_id") != snapshot_id):
            return False, "manifest identity mismatch"
        for seg in manifest.get("segments", []):
            p = d / seg["name"]
            try:
                data = p.read_bytes()
            except OSError:
                return False, f"segment {seg['name']} missing"
            if len(data) != seg["bytes"]:
                return False, (f"segment {seg['name']} truncated "
                               f"({len(data)} != {seg['bytes']} bytes)")
            if zlib.crc32(data) != seg["crc32"]:
                return False, f"segment {seg['name']} checksum mismatch"
        return True, ""

    def load_entries(self, job_id: str,
                     snapshot_id: int) -> List[Tuple[int, Any, Any]]:
        """All ``(pid, key, value)`` entries of one on-disk snapshot,
        CRC-checked segment by segment.  Raises ``ValueError`` on any
        integrity violation (callers treat it as "skip this chain
        entry")."""
        manifest = self.manifest(job_id, snapshot_id)
        if manifest is None:
            raise ValueError("manifest missing or unreadable")
        d = self.snapshot_dir(job_id, snapshot_id)
        entries: List[Tuple[int, Any, Any]] = []
        for seg in manifest.get("segments", []):
            data = (d / seg["name"]).read_bytes()
            if zlib.crc32(data) != seg["crc32"]:
                raise ValueError(f"segment {seg['name']} checksum mismatch")
            entries.extend(pickle.loads(data))
        return entries

    def prepare_restore(self, job_id: str,
                        snapshot_id: int) -> Tuple[bool, str]:
        """Rebuild the snapshot's IMap from its on-disk segments.  Disk is
        the source of truth for every restore: live in-memory state of the
        same epoch is discarded first, so a snapshot that no longer
        verifies on disk can never be restored from a stale in-memory
        copy."""
        ok, reason = self.verify(job_id, snapshot_id)
        if not ok:
            return False, reason
        try:
            entries = self.load_entries(job_id, snapshot_id)
        except (OSError, ValueError, pickle.UnpicklingError) as e:
            return False, f"segment load failed: {e}"
        self._map(job_id, snapshot_id).destroy()
        imap = self._map(job_id, snapshot_id)
        for pid, key, value in entries:
            imap.put_with_pid(key, value, pid)
        manifest = self.manifest(job_id, snapshot_id)
        if manifest and manifest.get("meta"):
            self.meta.setdefault(job_id, {})[snapshot_id] = manifest["meta"]
        return True, ""


def _write_atomic(path: Path, payload: bytes) -> None:
    """tmp file + fsync + atomic rename + directory fsync: a reader never
    observes a half-written file under ``path``, only the old state or
    the complete new one."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(path.parent, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
