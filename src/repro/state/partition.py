"""Consistent-hash partition assignment (paper §4.3).

Partitions are assigned to members via a hash ring with virtual nodes
(Chord-style [Stoica et al.]): each member projects ``VNODES`` points onto
the ring; partition *p* lives on the first ``backup_count + 1`` distinct
members clockwise of ``hash(p)``.  Adding or removing one member therefore
moves only ~``1/n`` of the partitions — the "minimal migration" property the
paper leans on for elasticity.
"""

from __future__ import annotations

import hashlib
from bisect import bisect_right
from typing import Dict, List, Sequence, Tuple

VNODES = 64


def _hash64(data: str) -> int:
    return int.from_bytes(hashlib.blake2b(data.encode(), digest_size=8).digest(),
                          "big")


class PartitionTable:
    """partition id -> ordered replica list [primary, backup1, ...]."""

    def __init__(self, members: Sequence[int], partition_count: int = 271,
                 backup_count: int = 1):
        if not members:
            raise ValueError("need at least one member")
        self.partition_count = partition_count
        self.backup_count = backup_count
        self.members: List[int] = sorted(members)
        self.assignments: List[List[int]] = []
        self._rebuild()

    # -- ring ---------------------------------------------------------------
    def _ring(self) -> List[Tuple[int, int]]:
        pts = []
        for m in self.members:
            for v in range(VNODES):
                pts.append((_hash64(f"m{m}:v{v}"), m))
        pts.sort()
        return pts

    def _rebuild(self) -> None:
        ring = self._ring()
        hashes = [h for h, _ in ring]
        n_replicas = min(self.backup_count + 1, len(self.members))
        assignments = []
        for p in range(self.partition_count):
            h = _hash64(f"p{p}")
            idx = bisect_right(hashes, h) % len(ring)
            replicas: List[int] = []
            i = idx
            while len(replicas) < n_replicas:
                m = ring[i % len(ring)][1]
                if m not in replicas:
                    replicas.append(m)
                i += 1
            assignments.append(replicas)
        self.assignments = assignments

    # -- queries -------------------------------------------------------------
    def owner(self, pid: int) -> int:
        return self.assignments[pid][0]

    def replicas(self, pid: int) -> List[int]:
        return self.assignments[pid]

    def partitions_of(self, member: int, replica_index: int = 0) -> List[int]:
        return [p for p, reps in enumerate(self.assignments)
                if len(reps) > replica_index and reps[replica_index] == member]

    # -- membership changes ----------------------------------------------------
    def change_membership(self, members: Sequence[int]) -> Dict[int, Tuple[List[int], List[int]]]:
        """Recompute assignments for a new member list.

        Returns the migration plan: pid -> (old_replicas, new_replicas) for
        every partition whose replica list changed.
        """
        old = [list(r) for r in self.assignments]
        self.members = sorted(members)
        self._rebuild()
        plan = {}
        for p in range(self.partition_count):
            if old[p] != self.assignments[p]:
                plan[p] = (old[p], self.assignments[p])
        return plan
