"""Device-tier streaming engine: the TPU-native adaptation of Jet.

The whole dataflow graph compiles into ONE XLA program executed SPMD on
every chip (the tasklet model's "whole DAG on every core"), state is
sharded so partitioning-of-state == partitioning-of-compute, keyed
exchange is a reduce-scatter/all-to-all, and snapshots are consistent by
construction at step boundaries (see DESIGN.md §2).
"""

from .window import VectorWindowSpec, window_state_init
from .executor import StreamExecutor, StreamJobConfig

__all__ = ["VectorWindowSpec", "window_state_init", "StreamExecutor",
           "StreamJobConfig"]
