"""StreamExecutor: the device-tier Jet runtime.

One compiled ``step``: ingest an event batch -> (optional) keyed exchange
across the ``data`` mesh axis -> stage-1 pane accumulation -> stage-2
reduce-scatter combine -> window emission, plus a ``snapshot`` collective
that ring-replicates the sharded state to the next chip (the IMDG backup
replica, DESIGN.md §2).

Key design points mirroring the paper:

* whole DAG per chip — the step is ONE fused XLA program;
* partitioning of state == partitioning of compute — key bucket ``k``
  lives on data-shard ``k % n_shards``, and the stage-2 combine is a
  ``psum_scatter`` over ``data`` that deposits exactly the buckets each
  chip owns (two-stage aggregation as a single collective);
* credit-based backpressure — the host ingestion loop sizes each step's
  admission to ~3x the measured per-interval processing rate (the
  adaptive receive window, §3.3);
* snapshots are consistent cuts by construction (step boundary), stored
  as a ring-shifted replica on the neighbouring chip + an optional host
  copy in the IMap service.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .window import VectorWindowSpec, accumulate, emit, window_state_init

ACK_INTERVAL_S = 0.1
WINDOW_FILL_FACTOR = 3


@dataclasses.dataclass(frozen=True)
class StreamJobConfig:
    window: VectorWindowSpec
    batch_size: int = 4096          # events per step (global)
    snapshot_every: int = 0         # steps between snapshots (0 = off)
    #: keyed-exchange plan (SPMD only):
    #:  - "reduce": stage-1 accumulates FULL-width panes locally, one
    #:    psum_scatter combines+deposits (bytes ~ R*K/chip — wins when the
    #:    key space is small);
    #:  - "route": events all-to-all to their bucket owners first, panes
    #:    stay owner-local (bytes ~ events/chip — wins when R*K >> batch,
    #:    and is Jet's own exchange-operator plan).  Per-destination
    #:    capacity = 2x fair share; overflow counts into
    #:    ``dropped_conflict`` (backpressure's job to keep ~0).
    exchange: str = "reduce"
    route_capacity_factor: float = 2.0


class StreamExecutor:
    """Single-host executor (1 device) or SPMD over a mesh's data axis."""

    def __init__(self, cfg: StreamJobConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.n_shards = 1 if mesh is None else int(mesh.shape["data"])
        spec = cfg.window
        assert spec.n_key_buckets % self.n_shards == 0
        self._step = jax.jit(self._build_step(), donate_argnums=(0,))
        self._snapshot = jax.jit(self._build_snapshot(), donate_argnums=())
        self._restore = jax.jit(self._build_restore())
        # telemetry for the adaptive receive window
        self._processed_since_ack = 0
        self._last_ack = time.monotonic()
        self._receive_window = cfg.batch_size * WINDOW_FILL_FACTOR
        # per-field shardings for async host->device staging
        if mesh is None:
            self._batch_shardings = None
        else:
            self._batch_shardings = {
                "ts": NamedSharding(mesh, P("data")),
                "key": NamedSharding(mesh, P("data")),
                "value": NamedSharding(mesh, P("data")),
                "valid": NamedSharding(mesh, P("data")),
                "wm": NamedSharding(mesh, P()),
            }

    # ------------------------------------------------------------- build --
    def _shard_state(self, state):
        if self.mesh is None:
            return state
        specs = {"panes": P(None, "data"), "slot_frame": P(),
                 "watermark": P(), "next_emit": P(),
                 "dropped_late": P(), "dropped_conflict": P()}
        return {k: jax.device_put(
            v, NamedSharding(self.mesh, specs[k])) for k, v in state.items()}

    def init_state(self):
        return self._shard_state(window_state_init(self.cfg.window))

    def _build_step(self):
        spec = self.cfg.window
        n_shards = self.n_shards
        if self.mesh is None:
            def step1(state, batch):
                state = accumulate(spec, state, batch["ts"], batch["key"],
                                   batch["value"], batch["valid"],
                                   batch.get("wm"))
                return emit(spec, state)
            return step1

        mesh = self.mesh
        K_loc = spec.n_key_buckets // n_shards
        if self.cfg.exchange == "route":
            return self._build_step_route(mesh, K_loc)

        def local_step(state, batch):
            # stage 1: accumulate THIS shard's slice of the batch into
            # full-width panes (local partial results — Jet stage 1)
            st1 = {
                "panes": jnp.zeros((spec.ring_len, spec.n_key_buckets),
                                   state["panes"].dtype),
                "slot_frame": state["slot_frame"],
                "watermark": state["watermark"],
                "next_emit": state["next_emit"],
                "dropped_late": state["dropped_late"],
                "dropped_conflict": state["dropped_conflict"],
            }
            st1 = accumulate(spec, st1, batch["ts"], batch["key"],
                             batch["value"], batch["valid"],
                             batch.get("wm"))
            # watermark must coalesce across shards (min rule over what
            # every producer has seen — here every shard sees a slice of
            # the same paced source, so the min is the safe watermark)
            wm = jax.lax.pmin(st1["watermark"], "data")
            # stage 2: the keyed exchange + combine in ONE collective —
            # psum_scatter deposits the summed buckets on their owners
            partial = st1["panes"]                       # (R, K)
            mine = jax.lax.psum_scatter(partial, "data", scatter_dimension=1,
                                        tiled=True)      # (R, K/n)
            st2 = {
                "panes": state["panes"] + mine,
                "slot_frame": st1["slot_frame"],
                "watermark": wm,
                "next_emit": state["next_emit"],
                # counters are per-shard; aggregate so they stay replicated
                "dropped_late": jax.lax.psum(
                    st1["dropped_late"] - state["dropped_late"], "data")
                + state["dropped_late"],
                "dropped_conflict": jax.lax.psum(
                    st1["dropped_conflict"] - state["dropped_conflict"],
                    "data") + state["dropped_conflict"],
            }
            # slot_frame bookkeeping must be globally agreed
            st2["slot_frame"] = jax.lax.pmax(st2["slot_frame"], "data")
            loc_spec = dataclasses.replace(spec, n_key_buckets=K_loc)
            new_state, out = emit(loc_spec, st2)
            return new_state, out

        in_specs = ({"panes": P(None, "data"), "slot_frame": P(),
                     "watermark": P(), "next_emit": P(),
                     "dropped_late": P(), "dropped_conflict": P()},
                    {"ts": P("data"), "key": P("data"),
                     "value": P("data"), "valid": P("data"), "wm": P()})
        out_specs = (in_specs[0],
                     {"results": P(None, "data"), "window_ends": P(),
                      "valid": P()})

        def step_spmd(state, batch):
            return shard_map(local_step, mesh, in_specs,
                             out_specs)(state, batch)
        return step_spmd

    def _build_step_route(self, mesh, K_loc: int):
        """Route-then-accumulate exchange: events all-to-all to their
        bucket owners; panes are owner-local (R, K/n) — the exchange moves
        O(events) bytes instead of O(R*K) (DESIGN.md: Jet's exchange
        operator; the counting-sort positions are kernels/route.py's job
        on real TPU)."""
        spec = self.cfg.window
        n = self.n_shards
        B_loc = self.cfg.batch_size // n
        C = max(8, int(B_loc / n * self.cfg.route_capacity_factor))

        def local_step(state, batch):
            ts, key = batch["ts"], batch["key"]
            value, valid = batch["value"], batch["valid"]
            dest = jnp.where(valid, key // K_loc, n)           # (B_loc,)
            onehot = jax.nn.one_hot(dest, n, dtype=jnp.int32)
            pos = (jnp.cumsum(onehot, axis=0) - onehot)
            pos = jnp.take_along_axis(
                pos, jnp.minimum(dest, n - 1)[:, None], 1)[:, 0]
            keep = valid & (pos < C)
            n_overflow = jnp.sum(valid & ~keep, dtype=jnp.int32)
            d = jnp.where(keep, dest, n - 1)                   # clamp
            p = jnp.minimum(pos, C - 1)

            def scatter(x, fill):
                buf = jnp.full((n, C) + x.shape[1:], fill, x.dtype)
                return buf.at[d, p].set(jnp.where(
                    keep.reshape(keep.shape + (1,) * (x.ndim - 1)), x, fill))

            s_ts = scatter(ts, 0)
            s_key = scatter(key, 0)
            s_val = scatter(value, 0.0)
            s_ok = scatter(keep, False)
            r_ts = jax.lax.all_to_all(s_ts, "data", 0, 0, tiled=True)
            r_key = jax.lax.all_to_all(s_key, "data", 0, 0, tiled=True)
            r_val = jax.lax.all_to_all(s_val, "data", 0, 0, tiled=True)
            r_ok = jax.lax.all_to_all(s_ok, "data", 0, 0, tiled=True)
            first = jax.lax.axis_index("data") * K_loc
            loc_spec = dataclasses.replace(spec, n_key_buckets=K_loc)
            st = dict(state)
            st = accumulate(loc_spec, st, r_ts.reshape(-1),
                            (r_key.reshape(-1) - first), r_val.reshape(-1),
                            r_ok.reshape(-1), batch.get("wm"))
            # watermark frontier comes from the PRE-ROUTE local slice,
            # trailing by the bounded-out-of-orderness allowance; coalesce
            # with pmin (hint-only mode skips the data frontier entirely)
            if spec.frontier_from_data:
                frontier = jnp.max(jnp.where(valid, ts, -1)).astype(
                    jnp.int32) - jnp.int32(spec.wm_lag)
                wm = jnp.maximum(frontier, state["watermark"])
            else:
                wm = state["watermark"]
            wm = jax.lax.pmin(wm, "data")
            if batch.get("wm") is not None:
                wm = jnp.maximum(wm, jnp.asarray(batch["wm"], jnp.int32))
            st["watermark"] = wm
            st["slot_frame"] = jax.lax.pmax(st["slot_frame"], "data")
            # counters replicate via psum of per-shard deltas
            ring_delta = st["dropped_conflict"] - state["dropped_conflict"]
            st["dropped_conflict"] = state["dropped_conflict"] + \
                jax.lax.psum(ring_delta + n_overflow, "data")
            st["dropped_late"] = state["dropped_late"] + jax.lax.psum(
                st["dropped_late"] - state["dropped_late"], "data")
            return emit(loc_spec, st)

        in_specs = ({"panes": P(None, "data"), "slot_frame": P(),
                     "watermark": P(), "next_emit": P(),
                     "dropped_late": P(), "dropped_conflict": P()},
                    {"ts": P("data"), "key": P("data"),
                     "value": P("data"), "valid": P("data"), "wm": P()})
        out_specs = (in_specs[0],
                     {"results": P(None, "data"), "window_ends": P(),
                      "valid": P()})

        def step_spmd(state, batch):
            return shard_map(local_step, mesh, in_specs,
                             out_specs)(state, batch)
        return step_spmd

    # ------------------------------------------------------- snapshots --
    def _build_snapshot(self):
        """Ring-replicate the sharded panes to the next data shard — the
        in-memory backup replica (no disk), exactly Jet's IMDG scheme."""
        if self.mesh is None:
            return lambda state: jax.tree.map(jnp.copy, state)
        mesh = self.mesh
        n = self.n_shards
        perm = [(i, (i + 1) % n) for i in range(n)]

        def snap(state):
            def local(panes):
                return jax.lax.ppermute(panes, "data", perm)
            backup = shard_map(local, mesh, P(None, "data"),
                               P(None, "data"))(state["panes"])
            return dict(state, panes=backup)
        return snap

    def _build_restore(self):
        """Recover a lost shard's panes from its ring neighbour."""
        if self.mesh is None:
            return lambda backup: backup
        mesh = self.mesh
        n = self.n_shards
        perm = [((i + 1) % n, i) for i in range(n)]

        def restore(backup_state):
            def local(panes):
                return jax.lax.ppermute(panes, "data", perm)
            panes = shard_map(local, mesh, P(None, "data"),
                              P(None, "data"))(backup_state["panes"])
            return dict(backup_state, panes=panes)
        return restore

    # ---------------------------------------------------------- elastic --
    def migrate_state(self, state, target: "StreamExecutor"):
        """Elastic rescale: re-lay the sharded window state out on the
        target executor's mesh (key buckets re-partition block-wise; the
        collectives stay correct because ownership is layout-defined)."""
        host = jax.tree.map(lambda x: jax.device_get(x), state)
        return target._shard_state(host)

    # ------------------------------------------------------------- run --
    def step(self, state, batch, valid_count: Optional[int] = None):
        """One compiled step.  Pass ``valid_count`` (host-side event count,
        known at staging time) to keep the call fully asynchronous — without
        it the admission telemetry forces a device sync per step."""
        out = self._step(state, batch)
        if valid_count is None:
            valid = batch["valid"]
            valid_count = int(valid.sum() if isinstance(valid, np.ndarray)
                              else jnp.sum(valid))
        self._processed_since_ack += valid_count
        return out

    def stage_batch(self, batch: Dict) -> Tuple[Dict, int]:
        """Begin the host->device transfer of ``batch`` without blocking.

        The copy overlaps whatever step is currently executing (async
        dispatch), which is what pipelines ingestion against compute.
        Returns ``(device_batch, valid_count)`` — the count is taken on the
        host *before* the transfer so the hot loop never syncs.
        """
        count = int(np.asarray(batch["valid"]).sum())
        shardings = self._batch_shardings
        staged = {}
        for k, v in batch.items():
            if v is None:
                staged[k] = v
            elif shardings is not None and k in shardings:
                staged[k] = jax.device_put(np.asarray(v), shardings[k])
            else:
                staged[k] = jax.device_put(np.asarray(v))
        return staged, count

    def snapshot(self, state):
        return self._snapshot(state)

    def restore(self, backup):
        return self._restore(backup)

    # adaptive receive window (paper §3.3): how many events the source may
    # admit before the next ack
    def admissible(self) -> int:
        now = time.monotonic()
        if now - self._last_ack >= ACK_INTERVAL_S:
            rate = self._processed_since_ack
            if rate > 0:
                target = rate * WINDOW_FILL_FACTOR
                self._receive_window = max(
                    self.cfg.batch_size,
                    (self._receive_window + target) // 2)
            self._processed_since_ack = 0
            self._last_ack = now
        return self._receive_window

    # ------------------------------------------------------------ bench --
    #: device-held step outputs are converted to host arrays in chunks of
    #: this many steps, bounding live buffers without a per-step sync
    COLLECT_CHUNK = 64

    def run_stream(self, event_gen: Callable[[int, int], Dict],
                   n_steps: int, collect: bool = True):
        """Drive ``n_steps`` steps; returns (state, results list).

        The loop is pipelined: batch ``i+1`` is staged host->device while
        step ``i`` executes, and step outputs stay on device (futures)
        until a chunk boundary — no per-step host synchronization.
        """
        state = self.init_state()
        results = []
        pending_outs = []

        def _harvest():
            for out in pending_outs:
                valid = np.asarray(out["valid"])
                if valid.any():
                    results.append(
                        (np.asarray(out["window_ends"])[valid],
                         np.asarray(out["results"])[valid.nonzero()[0]]))
            pending_outs.clear()

        B = self.cfg.batch_size
        snap_every = self.cfg.snapshot_every
        nxt, nxt_count = self.stage_batch(event_gen(0, B))
        for i in range(n_steps):
            batch, count = nxt, nxt_count
            if i + 1 < n_steps:
                # pipelining: next batch's transfer overlaps this step
                nxt, nxt_count = self.stage_batch(event_gen((i + 1) * B, B))
            state, out = self.step(state, batch, valid_count=count)
            if snap_every and (i + 1) % snap_every == 0:
                self._last_backup = self.snapshot(state)
            if collect:
                pending_outs.append(out)
                if len(pending_outs) >= self.COLLECT_CHUNK:
                    _harvest()
        _harvest()
        return state, results
