"""Vectorized sliding-window aggregation (device tier).

Events arrive as fixed-size array batches ``{ts, key, value, valid}``.
Keys are hashed into ``n_key_buckets``; per (bucket, frame) partial
accumulators live in a ring of ``ring_len`` frame slots — the same
pane-based plan as the host tier (core/window.py), vectorized:

* **accumulate** (Jet stage 1): the batch scatters into the (K, R) pane
  matrix (``segment-sum`` here; the MXU-tiled one-hot-matmul version is
  the Pallas kernel in ``kernels/window_agg`` — DESIGN.md "scatter-add ->
  one-hot matmul"),
* **combine + emit** (Jet stage 2): when the watermark crosses a slide
  boundary, the window result per key is ``panes_ring @ window_mask`` —
  one matvec per emitted window.

Frame/window convention: frame ``f`` covers event time
``[f*slide, (f+1)*slide)``; the window whose LAST frame is ``L`` covers
frames ``[L-F+1, L]`` and its end is ``w_end = (L+1)*slide``; it emits
once the watermark reaches ``w_end``.

All shapes are static; a step emits at most ``max_windows_per_step``
windows, each tagged valid/invalid; events that arrive after their last
window emitted are dropped and counted (``dropped_late``), events whose
ring slot is still occupied by a live older frame are dropped and counted
(``dropped_conflict`` — bounded by pacing ingestion against emission,
which is the executor's credit-based backpressure job).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VectorWindowSpec:
    size_ms: int
    slide_ms: int
    n_key_buckets: int = 1024
    max_windows_per_step: int = 4
    ring_margin: int = 4

    @property
    def frames_per_window(self) -> int:
        assert self.size_ms % self.slide_ms == 0
        return self.size_ms // self.slide_ms

    @property
    def ring_len(self) -> int:
        return self.frames_per_window + self.ring_margin


def window_state_init(spec: VectorWindowSpec, dtype=jnp.float32) -> Dict:
    return {
        # per (frame slot, key bucket) partial aggregate — slot-major so
        # the accumulate scatter lands without a transpose and emission is
        # one (E, R) @ (R, K) matmul
        "panes": jnp.zeros((spec.ring_len, spec.n_key_buckets), dtype),
        # frame id stored in each ring slot (-1 = empty)
        "slot_frame": jnp.full((spec.ring_len,), -1, jnp.int32),
        "watermark": jnp.asarray(-1, jnp.int32),
        # next window end (ms) to emit; -1 = not yet initialised
        "next_emit": jnp.asarray(-1, jnp.int32),
        "dropped_late": jnp.asarray(0, jnp.int32),
        "dropped_conflict": jnp.asarray(0, jnp.int32),
    }


def accumulate(spec: VectorWindowSpec, state: Dict, ts, key_bucket, value,
               valid, wm_hint=None) -> Dict:
    """Jet stage 1, vectorized pane accumulation.

    ``wm_hint``: optional scalar watermark heartbeat (idle-source marker):
    advances event time without carrying data."""
    K, R, F = spec.n_key_buckets, spec.ring_len, spec.frames_per_window
    frame = (ts // spec.slide_ms).astype(jnp.int32)
    slot = frame % R

    # lateness: frames below min_frame have had their last window emitted
    ne = state["next_emit"]
    min_frame = jnp.where(ne < 0, jnp.int32(-(2**30)),
                          ne // spec.slide_ms - F)
    live = valid & (frame >= min_frame)
    n_late = jnp.sum(valid & ~live, dtype=jnp.int32)

    # ring-slot conflicts: slot occupied by a DIFFERENT still-live frame
    slot_frame = state["slot_frame"]
    occupant = slot_frame[slot]
    conflict = live & (occupant >= 0) & (occupant != frame)
    n_conflict = jnp.sum(conflict, dtype=jnp.int32)
    live = live & ~conflict

    combined = slot * K + key_bucket.astype(jnp.int32)
    contrib = jnp.where(live, value, 0.0).astype(state["panes"].dtype)
    panes = state["panes"].reshape(R * K).at[combined].add(
        contrib, mode="drop").reshape(R, K)

    # record which frame now lives in each touched slot (scatter-max;
    # measured 25x faster than the one-hot formulation at R~100)
    slot_frame = slot_frame.at[jnp.where(live, slot, R)].max(
        jnp.where(live, frame, -1), mode="drop")

    wm = jnp.maximum(state["watermark"],
                     jnp.max(jnp.where(valid, ts, -1)).astype(jnp.int32))
    if wm_hint is not None:
        wm = jnp.maximum(wm, jnp.asarray(wm_hint, jnp.int32))
    return dict(state, panes=panes, slot_frame=slot_frame, watermark=wm,
                dropped_late=state["dropped_late"] + n_late,
                dropped_conflict=state["dropped_conflict"] + n_conflict)


def emit(spec: VectorWindowSpec, state: Dict
         ) -> Tuple[Dict, Dict[str, jnp.ndarray]]:
    """Jet stage 2, vectorized: emit up to ``max_windows_per_step`` window
    results with end <= watermark; evict the frame each emission retires."""
    K, R, F = spec.n_key_buckets, spec.ring_len, spec.frames_per_window
    slide = spec.slide_ms
    E = spec.max_windows_per_step

    wm = state["watermark"]
    # initialise next_emit from the first frame present
    first_frame = jnp.min(jnp.where(state["slot_frame"] >= 0,
                                    state["slot_frame"], 2**30))
    ne0 = jnp.where(state["next_emit"] < 0,
                    (first_frame + 1) * slide,
                    state["next_emit"])

    # all E candidate windows in ONE matmul: masks (E, R) @ panes (R, K)
    panes, slot_frame = state["panes"], state["slot_frame"]
    w_ends = ne0 + jnp.arange(E, dtype=jnp.int32) * slide
    ready = (w_ends <= wm) & (ne0 < 2**30)                      # (E,)
    L = w_ends // slide - 1                                     # (E,)
    ring_f = slot_frame                                         # (R,)
    in_win = ((ring_f[None, :] > (L - F)[:, None])
              & (ring_f[None, :] <= L[:, None])
              & (ring_f[None, :] >= 0) & ready[:, None])
    masks = jnp.where(in_win, 1.0, 0.0).astype(panes.dtype)     # (E, R)
    results = masks @ panes                                     # (E, K)
    # evict every frame retired by an emitted window (single pass)
    evict = jnp.any((ring_f[None, :] == (L - F + 1)[:, None])
                    & ready[:, None], axis=0) & (ring_f >= 0)
    panes = jnp.where(evict[:, None], 0.0, panes)
    slot_frame = jnp.where(evict, -1, slot_frame)
    n_emitted = jnp.sum(ready, dtype=jnp.int32)
    new_next = jnp.where(ne0 < 2**30, ne0 + n_emitted * slide,
                         state["next_emit"])
    out_state = dict(state, panes=panes, slot_frame=slot_frame,
                     next_emit=new_next)
    return out_state, {"results": results, "window_ends": w_ends,
                       "valid": ready}


def step(spec: VectorWindowSpec, state: Dict, batch: Dict
         ) -> Tuple[Dict, Dict]:
    """One fused accumulate+emit step (the whole-DAG-per-chip tasklet)."""
    state = accumulate(spec, state, batch["ts"], batch["key"],
                       batch["value"], batch["valid"], batch.get("wm"))
    return emit(spec, state)
