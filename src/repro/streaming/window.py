"""Vectorized sliding-window aggregation (device tier).

Events arrive as fixed-size array batches ``{ts, key, value, valid}``.
Keys are hashed into ``n_key_buckets``; per (bucket, frame) partial
accumulators live in a ring of ``ring_len`` frame slots — the same
pane-based plan as the host tier (core/window.py), vectorized:

* **accumulate** (Jet stage 1): the batch scatters into the (K, R) pane
  matrix (``segment-sum`` here; the MXU-tiled one-hot-matmul version is
  the Pallas kernel in ``kernels/window_agg`` — DESIGN.md "scatter-add ->
  one-hot matmul"),
* **combine + emit** (Jet stage 2): when the watermark crosses a slide
  boundary, the window result per key is ``panes_ring @ window_mask`` —
  one matvec per emitted window.

Frame/window convention: frame ``f`` covers event time
``[f*slide, (f+1)*slide)``; the window whose LAST frame is ``L`` covers
frames ``[L-F+1, L]`` and its end is ``w_end = (L+1)*slide``; it emits
once the watermark reaches ``w_end``.

All shapes are static; a step emits up to ``max_windows_per_step`` windows
per emission *round* and loops rounds (bounded ``lax.while_loop``) until
the emission front catches the watermark or the per-step output buffer
(``max_windows_per_step * emit_rounds`` rows) fills; empty windows — no
live frame in range — are skipped in O(1) by fast-forwarding the front,
so an idle source followed by a burst (or a large ``wm`` heartbeat jump)
cannot leave emission permanently behind.  Events that arrive after their
last window emitted are dropped and counted (``dropped_late``), events
whose ring slot is still occupied by a live older frame are dropped and
counted (``dropped_conflict`` — bounded by pacing ingestion against
emission, which is the executor's credit-based backpressure job).

``wm_lag`` is the bounded-out-of-orderness allowance (the host tier's
``EventTimePolicy.lag``): the data-driven watermark frontier is
``max(ts) - wm_lag``, so cross-batch disorder up to ``wm_lag`` of event
time is admitted instead of silently dropped as late — ordered and
disordered runs with ``wm_lag >= max_skew_ms`` produce identical results,
the same disorder-equivalence guarantee the host tier gives.
``frontier_from_data=False`` disables the data-driven frontier entirely:
the watermark then advances only on explicit ``wm`` hints, which is how
the host bridge (core/device_window.py) drives emission from the host's
own coalesced watermarks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

#: sentinel for "no frame / uninitialised emission front" (int32-safe)
_FAR = 2**30


@dataclasses.dataclass(frozen=True)
class VectorWindowSpec:
    size_ms: int
    slide_ms: int
    n_key_buckets: int = 1024
    max_windows_per_step: int = 4
    ring_margin: int = 4
    #: bounded out-of-orderness allowance subtracted from the data-driven
    #: watermark frontier (0 keeps the legacy max(ts) frontier)
    wm_lag: int = 0
    #: False = the watermark advances only on explicit ``wm`` hints (the
    #: host bridge mode: host watermarks are already lagged at the source)
    frontier_from_data: bool = True
    #: max emission rounds per step (0 = auto: ceil(ring_len / E), enough
    #: output rows to retire every live frame's next window in one step)
    emit_rounds: int = 0

    @property
    def frames_per_window(self) -> int:
        assert self.size_ms % self.slide_ms == 0
        return self.size_ms // self.slide_ms

    @property
    def ring_len(self) -> int:
        # the watermark lag keeps frames live for wm_lag/slide extra
        # slides past the emission front: size the ring for it, or the
        # admitted disorder would bleed straight into ring conflicts
        lag_frames = -(-self.wm_lag // self.slide_ms) if self.wm_lag else 0
        return self.frames_per_window + self.ring_margin + lag_frames

    @property
    def emit_rounds_resolved(self) -> int:
        if self.emit_rounds > 0:
            return self.emit_rounds
        return -(-self.ring_len // self.max_windows_per_step)

    @property
    def emit_buffer_rows(self) -> int:
        """Rows in a step's emission output (``results``/``window_ends``/
        ``valid`` leading dimension)."""
        return self.max_windows_per_step * self.emit_rounds_resolved


def window_state_init(spec: VectorWindowSpec, dtype=jnp.float32) -> Dict:
    return {
        # per (frame slot, key bucket) partial aggregate — slot-major so
        # the accumulate scatter lands without a transpose and emission is
        # one (E, R) @ (R, K) matmul
        "panes": jnp.zeros((spec.ring_len, spec.n_key_buckets), dtype),
        # frame id stored in each ring slot (-1 = empty)
        "slot_frame": jnp.full((spec.ring_len,), -1, jnp.int32),
        "watermark": jnp.asarray(-1, jnp.int32),
        # next window end (ms) to emit; -1 = not yet initialised
        "next_emit": jnp.asarray(-1, jnp.int32),
        "dropped_late": jnp.asarray(0, jnp.int32),
        "dropped_conflict": jnp.asarray(0, jnp.int32),
    }


def accumulate(spec: VectorWindowSpec, state: Dict, ts, key_bucket, value,
               valid, wm_hint=None) -> Dict:
    """Jet stage 1, vectorized pane accumulation.

    ``wm_hint``: optional scalar watermark heartbeat (idle-source marker):
    advances event time without carrying data."""
    K, R, F = spec.n_key_buckets, spec.ring_len, spec.frames_per_window
    frame = (ts // spec.slide_ms).astype(jnp.int32)
    slot = frame % R

    # lateness: frames below min_frame have had their last window emitted
    ne = state["next_emit"]
    min_frame = jnp.where(ne < 0, jnp.int32(-(2**30)),
                          ne // spec.slide_ms - F)
    live = valid & (frame >= min_frame)
    n_late = jnp.sum(valid & ~live, dtype=jnp.int32)

    # ring-slot conflicts: slot occupied by a DIFFERENT still-live frame
    slot_frame = state["slot_frame"]
    occupant = slot_frame[slot]
    conflict = live & (occupant >= 0) & (occupant != frame)
    n_conflict = jnp.sum(conflict, dtype=jnp.int32)
    live = live & ~conflict

    combined = slot * K + key_bucket.astype(jnp.int32)
    contrib = jnp.where(live, value, 0.0).astype(state["panes"].dtype)
    panes = state["panes"].reshape(R * K).at[combined].add(
        contrib, mode="drop").reshape(R, K)

    # record which frame now lives in each touched slot (scatter-max;
    # measured 25x faster than the one-hot formulation at R~100)
    slot_frame = slot_frame.at[jnp.where(live, slot, R)].max(
        jnp.where(live, frame, -1), mode="drop")

    wm = state["watermark"]
    if spec.frontier_from_data:
        # bounded out-of-orderness: the frontier trails the running-max
        # timestamp by wm_lag, so cross-batch disorder within the
        # allowance is admitted instead of dropped as late
        frontier = jnp.max(jnp.where(valid, ts, -1)).astype(jnp.int32) \
            - jnp.int32(spec.wm_lag)
        wm = jnp.maximum(wm, frontier)
    if wm_hint is not None:
        wm = jnp.maximum(wm, jnp.asarray(wm_hint, jnp.int32))
    return dict(state, panes=panes, slot_frame=slot_frame, watermark=wm,
                dropped_late=state["dropped_late"] + n_late,
                dropped_conflict=state["dropped_conflict"] + n_conflict)


def emit(spec: VectorWindowSpec, state: Dict
         ) -> Tuple[Dict, Dict[str, jnp.ndarray]]:
    """Jet stage 2, vectorized: emit window results with end <= watermark;
    evict the frame each emission retires.

    Emission runs in rounds of ``max_windows_per_step`` windows (one
    ``(E, R) @ (R, K)`` matmul per round) inside a bounded
    ``lax.while_loop`` that stops when the front passes the watermark or
    the output buffer (``emit_buffer_rows`` rows) fills.  Between rounds
    the front *fast-forwards over empty windows* — window ends no live
    frame participates in — so a watermark jump across an idle gap (idle
    source then burst, or a ``wm`` heartbeat) costs O(1) instead of one
    round per skipped window: emission can no longer fall permanently
    behind and bleed ``dropped_conflict``.
    """
    K, R, F = spec.n_key_buckets, spec.ring_len, spec.frames_per_window
    slide = spec.slide_ms
    E = spec.max_windows_per_step
    EB = spec.emit_buffer_rows

    wm = state["watermark"]
    panes0, slot_frame0 = state["panes"], state["slot_frame"]
    # first window end strictly beyond the watermark: reaching it means
    # emission is fully caught up
    caught = (wm // slide + 1) * slide

    def fast_forward(ne, slot_frame):
        """Smallest window end >= ne containing a live frame; if none is
        at or below the watermark, jump to ``caught`` (every window in
        between is empty — skipping it emits exactly nothing)."""
        live = slot_frame >= 0
        # frame f participates in windows ending (f+1)*slide..(f+F)*slide
        cand = jnp.where(live & ((slot_frame + F) * slide >= ne),
                         jnp.maximum(ne, (slot_frame + 1) * slide), _FAR)
        nxt = jnp.min(cand)
        return jnp.where(ne >= _FAR, ne,
                         jnp.where(nxt <= wm, nxt,
                                   jnp.maximum(ne, caught)))

    # initialise next_emit from the first frame present
    first_frame = jnp.min(jnp.where(slot_frame0 >= 0, slot_frame0, _FAR))
    ne0 = jnp.where(state["next_emit"] < 0,
                    jnp.where(first_frame < _FAR,
                              (first_frame + 1) * slide,
                              jnp.int32(_FAR)),
                    state["next_emit"])
    ne0 = fast_forward(ne0, slot_frame0)

    res0 = jnp.zeros((EB, panes0.shape[1]), panes0.dtype)
    ends0 = jnp.zeros((EB,), jnp.int32)
    val0 = jnp.zeros((EB,), bool)

    def cond(carry):
        ne, _panes, _sf, _res, _ends, _val, count = carry
        return (ne <= wm) & (ne < _FAR) & (count + E <= EB)

    def body(carry):
        ne, panes, slot_frame, res, ends, val, count = carry
        # E candidate windows in ONE matmul: masks (E, R) @ panes (R, K)
        w_ends = ne + jnp.arange(E, dtype=jnp.int32) * slide
        ready = w_ends <= wm                                    # (E,)
        L = w_ends // slide - 1                                 # (E,)
        ring_f = slot_frame                                     # (R,)
        in_win = ((ring_f[None, :] > (L - F)[:, None])
                  & (ring_f[None, :] <= L[:, None])
                  & (ring_f[None, :] >= 0) & ready[:, None])
        masks = jnp.where(in_win, 1.0, 0.0).astype(panes.dtype)  # (E, R)
        results = masks @ panes                                  # (E, K)
        # evict every frame retired by an emitted window (single pass)
        evict = jnp.any((ring_f[None, :] == (L - F + 1)[:, None])
                        & ready[:, None], axis=0) & (ring_f >= 0)
        panes = jnp.where(evict[:, None], 0.0, panes)
        slot_frame = jnp.where(evict, -1, slot_frame)
        n_emitted = jnp.sum(ready, dtype=jnp.int32)
        # the ready rows are a prefix of the E candidates (w_ends are
        # ascending), so advancing the cursor by n_emitted lets the next
        # round overwrite only the not-ready tail
        res = jax.lax.dynamic_update_slice(res, results, (count, 0))
        ends = jax.lax.dynamic_update_slice(ends, w_ends, (count,))
        val = jax.lax.dynamic_update_slice(val, ready, (count,))
        count = count + n_emitted
        ne = fast_forward(ne + n_emitted * slide, slot_frame)
        return ne, panes, slot_frame, res, ends, val, count

    ne_f, panes, slot_frame, res, ends, val, _count = jax.lax.while_loop(
        cond, body,
        (ne0, panes0, slot_frame0, res0, ends0, val0, jnp.int32(0)))

    new_next = jnp.where(ne_f < _FAR, ne_f, state["next_emit"])
    out_state = dict(state, panes=panes, slot_frame=slot_frame,
                     next_emit=new_next)
    return out_state, {"results": res, "window_ends": ends, "valid": val}


def step(spec: VectorWindowSpec, state: Dict, batch: Dict
         ) -> Tuple[Dict, Dict]:
    """One fused accumulate+emit step (the whole-DAG-per-chip tasklet)."""
    state = accumulate(spec, state, batch["ts"], batch["key"],
                       batch["value"], batch["valid"], batch.get("wm"))
    return emit(spec, state)
