"""Activation sharding constraints.

GSPMD propagation alone can resolve a sharding conflict by replicating the
*batch* (it did: un-constrained, the embedding gather made it all-gather
activations and run the whole net with a replicated batch — 77 GiB/device).
The launcher registers the mesh here; the model then pins activations at
three anchor points (post-embed, scan carry, logits).  Without a registered
mesh (CPU smoke tests) every constraint is a no-op.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def _axis_ok(mesh: Mesh, dim: int, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else axes
    n = 1
    for a in axes:
        if a not in mesh.axis_names:
            return False
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def constrain(x, *spec):
    """with_sharding_constraint against the registered mesh; axes that are
    absent from the mesh or do not divide the dim are dropped."""
    if _MESH is None:
        return x
    axes = []
    for dim, a in zip(x.shape, spec):
        if isinstance(a, tuple):
            a = tuple(s for s in a if s in _MESH.axis_names)
            a = a if a and _axis_ok(_MESH, dim, a) else None
            if a is not None and len(a) == 1:
                a = a[0]
        elif a is not None and not _axis_ok(_MESH, dim, a):
            a = None
        axes.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*axes)))


BATCH = ("pod", "data")


def constrain_batch_seq(x):
    """(B, S, D) residual-stream activations: batch over (pod, data) and
    the *sequence* over ``model`` (Megatron-style sequence parallelism).

    The scan-over-layers saves one carry per layer for the backward pass;
    with the sequence replicated across the model axis those saves were
    36 GiB/device for internlm2-20b — SP shards them 16-way.  Attention
    and the FFN re-gather the sequence internally where they need it
    (qkv projections / TP matmuls), which is exactly the Megatron-SP
    all-gather/reduce-scatter pair."""
    if x.ndim == 3 and x.shape[1] > 1:
        return constrain(x, BATCH, "model", None)
    rest = [None] * (x.ndim - 1)
    return constrain(x, BATCH, *rest)


def constrain_logits(x):
    """(B, S, V) logits: batch over (pod, data), vocab over model."""
    return constrain(x, BATCH, None, "model")


def _model_size() -> int:
    if _MESH is None or "model" not in _MESH.axis_names:
        return 1
    return _MESH.shape["model"]


def constrain_heads(x):
    """(B, S, H, dh) q/k/v: heads on ``model`` when they divide it, else
    fall back to sequence-sharding (llava's 56 heads on a 16-wide axis)."""
    if x.ndim != 4:
        return x
    if x.shape[2] % _model_size() == 0:
        return constrain(x, BATCH, None, "model", None)
    return constrain(x, BATCH, "model", None, None)


def constrain_scores(x):
    """(B, H, Sq, Sk) attention scores: heads on ``model`` with a
    query-sequence fallback — without this, a non-dividing head count made
    GSPMD replicate the scores (56 GiB/device for llava-next-34b)."""
    if x.shape[1] % _model_size() == 0:
        return constrain(x, BATCH, "model", None, None)
    return constrain(x, BATCH, None, "model", None)
