from .rules import (batch_axes, batch_spec, cache_sharding, param_sharding,
                    spec_to_sharding, state_sharding)

__all__ = ["batch_axes", "batch_spec", "cache_sharding", "param_sharding",
           "spec_to_sharding", "state_sharding"]
