"""Sharding rules: FSDP + TP (+ EP) parameter layouts, batch/sequence
activation layouts, and KV/SSM cache layouts.

Strategy (baseline recorded in EXPERIMENTS.md §Roofline):

* weights — tensor-parallel on ``model`` along heads / experts / ffn /
  vocab, and FSDP on ``data`` along the other large dim.  Optimizer moments
  mirror the parameters (ZeRO-3 for free).
* activations — batch on ``(pod, data)``.
* caches — batch on ``(pod, data)`` when it divides, otherwise the
  *sequence* dim shards on ``data`` (sequence-parallel cache for
  ``long_500k``'s global_batch=1); heads on ``model`` with a head-dim
  fallback for small-kv-head archs (qwen2 has kv=2 < 16).

Every axis assignment is divisibility-checked against the mesh; an axis
that does not divide is dropped (replicated) rather than invalid.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """axes if they divide dim (trying progressively smaller prefixes for
    tuple axes), else None."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if dim % mesh.shape[axes] == 0 else None
    axes = tuple(a for a in axes if a in mesh.axis_names)
    while axes:
        if dim % _axis_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[:-1]
    return None


def _spec(mesh: Mesh, shape: Sequence[int], *dim_axes) -> P:
    """Build a PartitionSpec, dropping non-dividing axes."""
    assert len(dim_axes) == len(shape), (shape, dim_axes)
    return P(*[_fit(mesh, d, a) for d, a in zip(shape, dim_axes)])


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):  # pragma: no cover
            names.append(k.name)
    return tuple(names)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

_FSDP = "data"      # FSDP shards the non-TP large dim over data
_TP = "model"


def _param_spec(mesh: Mesh, names: Tuple[str, ...], shape,
                fsdp: bool = True) -> P:
    """Rule table keyed by the leaf parameter name.

    ``fsdp=False`` (serving): weights shard on ``model`` only — bf16
    inference weights fit HBM 16-way sharded, and FSDP gathers per decoded
    token made rwkv6 decode collective-bound (measured ~640 MB/token of
    f32 weight all-gathers)."""
    leaf = names[-1]
    in_groups = "groups" in names
    core = shape[1:] if in_groups else shape     # drop stacked-layer dim

    def wrap(spec: P) -> P:
        spec = P(None, *spec) if in_groups else spec
        if not fsdp:
            spec = P(*[None if a == _FSDP else a for a in spec])
        return spec

    n = len(core)
    # embed/lm_head: vocab-sharded ONLY.  Sharding their d_model dim over
    # "data" makes the embedding gather / tied-head matmul conflict with
    # the batch's data axis, and GSPMD resolves that by replicating the
    # batch through the entire network (measured: 77 GiB/device).
    if leaf == "embed":
        return wrap(_spec(mesh, core, _TP, None))
    if leaf == "lm_head":
        return wrap(_spec(mesh, core, None, _TP))
    if leaf in ("wq", "wk", "wv", "wg", "wr", "in_proj", "cm_wk", "cm_wr",
                "tm_w1", "td_w1"):
        if n == 2:
            return wrap(_spec(mesh, core, _FSDP, _TP))
    if leaf in ("wo", "out_proj", "cm_wv", "dt_proj", "td_w2"):
        if n == 2:
            return wrap(_spec(mesh, core, _TP, _FSDP))
    if leaf in ("w_gate", "w_up"):
        if n == 2:   # dense MLP (D, F)
            return wrap(_spec(mesh, core, _FSDP, _TP))
        # MoE (E, D, F): expert-parallel when E divides the model axis,
        # otherwise TP inside each expert
        if core[0] % mesh.shape[_TP] == 0:
            return wrap(_spec(mesh, core, _TP, _FSDP, None))
        return wrap(_spec(mesh, core, None, _FSDP, _TP))
    if leaf == "w_down":
        if n == 2:   # dense MLP (F, D)
            return wrap(_spec(mesh, core, _TP, _FSDP))
        # MoE (E, F, D): align with the shard_map specs in models/moe.py
        if core[0] % mesh.shape[_TP] == 0:
            return wrap(_spec(mesh, core, _TP, _FSDP, None))
        return wrap(_spec(mesh, core, None, _TP, _FSDP))
    if leaf == "router":
        return wrap(P(*[None] * n))  # small; shard_map wants it replicated
    if leaf in ("conv_w", "x_proj", "A_log"):
        return wrap(_spec(mesh, core, _TP, None))
    if leaf in ("conv_b", "dt_bias", "D"):
        return wrap(_spec(mesh, core, _TP))
    if leaf == "u":
        return wrap(_spec(mesh, core, _TP, None))
    # default: shard the largest dim over data if it is big and divides
    if core and max(core) >= 4096:
        big = core.index(max(core))
        axes = [None] * n
        axes[big] = _FSDP
        return wrap(_spec(mesh, core, *axes))
    return wrap(P(*[None] * n))


def param_sharding(mesh: Mesh, params_shape, fsdp: bool = True) -> Any:
    """NamedSharding pytree matching an (eval_shape'd) params pytree."""
    def f(path, leaf):
        spec = _param_spec(mesh, _path_names(path), leaf.shape, fsdp=fsdp)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, params_shape)


def state_sharding(mesh: Mesh, state_shape) -> Any:
    """Train state = {params, opt{mu,nu,count}, step}: moments mirror the
    parameter shardings (ZeRO-3)."""
    def f(path, leaf):
        names = _path_names(path)
        if names[-1] in ("count", "step") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # strip the leading state key ("params" / "opt"+"mu"/"nu")
        core = tuple(n for n in names
                     if n not in ("params", "opt", "mu", "nu", "step"))
        spec = _param_spec(mesh, core, leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, state_shape)


# ---------------------------------------------------------------------------
# activations / batches
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, shape, seq_dim: Optional[int] = None) -> P:
    """Shard dim 0 (batch) over (pod, data); if batch cannot shard and a
    sequence dim is given, shard the sequence instead (SP)."""
    ba = _fit(mesh, shape[0], batch_axes(mesh))
    axes = [None] * len(shape)
    if ba is not None and shape[0] >= _axis_size(mesh, batch_axes(mesh)):
        axes[0] = ba
    elif seq_dim is not None:
        axes[seq_dim] = _fit(mesh, shape[seq_dim], "data")
    return P(*axes)


def batch_sharding(mesh: Mesh, tree) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, leaf.shape)), tree)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def _cache_spec(mesh: Mesh, names: Tuple[str, ...], shape) -> P:
    """Cache leaves all carry a leading groups dim.

    k/v: (G, B, S, Hk, dh);  h: (G, B, di, N);  conv: (G, B, K-1, di);
    wkv: (G, B, H, dh, dh);  *_shift: (G, B, D)."""
    leaf = names[-1]
    ba = batch_axes(mesh)
    G, B = shape[0], shape[1]
    batch_ok = B % _axis_size(mesh, ba) == 0 and B >= _axis_size(mesh, ba)
    b_axis = ba if batch_ok else None
    if leaf in ("k", "v"):
        # sequence-sharded over `model` (plus `data` when the batch can't
        # shard): decode attends to the local context chunk and combines
        # with small psums.  Sharding heads/head-dim instead forces a
        # full-cache reshard when GQA kv heads expand (measured 2+ GiB of
        # all-gather per decoded token).
        S = shape[2]
        seq_axes = _TP if batch_ok else ("data", "model")
        return _spec(mesh, shape, None, b_axis, seq_axes, None, None)
    if leaf in ("k_scale", "v_scale"):
        # (G, B, S, Hk): follow the quantized cache's sequence sharding
        seq_axes = _TP if batch_ok else ("data", "model")
        return _spec(mesh, shape, None, b_axis, seq_axes, None)
    if leaf == "h":
        return _spec(mesh, shape, None, b_axis,
                     _TP if batch_ok else ("data", "model"), None)
    if leaf == "conv":
        return _spec(mesh, shape, None, b_axis, None,
                     _TP if batch_ok else ("data", "model"))
    if leaf == "wkv":
        if batch_ok:
            return _spec(mesh, shape, None, b_axis, _TP, None, None)
        return _spec(mesh, shape, None, None, _TP, "data", None)
    if leaf in ("tm_shift", "cm_shift"):
        return _spec(mesh, shape, None, b_axis,
                     _TP if batch_ok else ("data", "model"))
    # unknown cache leaf: batch only
    axes = [None] * len(shape)
    if batch_ok:
        axes[1] = ba
    return _spec(mesh, shape, *axes)


def cache_sharding(mesh: Mesh, cache_shape) -> Any:
    def f(path, leaf):
        return NamedSharding(mesh,
                             _cache_spec(mesh, _path_names(path), leaf.shape))
    return jax.tree_util.tree_map_with_path(f, cache_shape)


def spec_to_sharding(mesh: Mesh, tree_of_specs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
