"""Model configuration: one dataclass covering the dense / MoE / SSM /
hybrid families of the assigned architecture pool."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: int = 0                # 0 -> d_model // n_heads
    attention: str = "full"          # full | swa
    swa_window: int = 4096
    qkv_bias: bool = False
    rope_theta: float = 10_000.0

    # norms
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparametric_ln
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden (0 -> d_ff)
    capacity_factor: float = 1.25
    moe_every: int = 1               # MoE replaces the FFN every k-th layer

    # hybrid (Jamba): one attention layer per `attn_period` layers,
    # the rest are Mamba mixers
    attn_period: int = 0             # 0 -> pure attention (or pure ssm)

    # SSM (mamba / rwkv6)
    ssm_kind: str = ""               # "" | mamba | rwkv6
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    rwkv_head_size: int = 64

    # modality frontend (stubbed per task spec: the dry-run feeds
    # precomputed embeddings for audio / vision)
    modality: str = "text"           # text | audio_stub | vlm_stub

    tie_embeddings: bool = False
    max_seq_len: int = 532_480

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per layer in one scan group.

        dense/moe: ("attn",); ssm: (ssm_kind,); hybrid: a group of
        ``attn_period`` mixers with the attention layer in the middle
        (Jamba places it at index 4 of each 8-layer block)."""
        if self.family == "ssm":
            return (self.ssm_kind,)
        if self.family == "hybrid" and self.attn_period > 1:
            group = ["mamba"] * self.attn_period
            group[self.attn_period // 2] = "attn"
            return tuple(group)
        return ("attn",)

    def ffn_kinds(self) -> Tuple[str, ...]:
        """FFN kind per layer within one scan group ("mlp" | "moe")."""
        group = len(self.layer_kinds())
        kinds = []
        for i in range(group):
            kinds.append("moe" if (self.n_experts > 0
                                   and (i % self.moe_every
                                        == self.moe_every - 1
                                        or self.moe_every == 1))
                         else "mlp")
        return tuple(kinds)

    @property
    def n_groups(self) -> int:
        g = len(self.layer_kinds())
        assert self.n_layers % g == 0, (self.name, self.n_layers, g)
        return self.n_layers // g

    # -- parameter counting (for roofline MODEL_FLOPS) ---------------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        dh = self.head_dim_
        counts = {"embed": V * D, "head": 0 if self.tie_embeddings else D * V}
        total_layer, active_layer = 0, 0
        for kind, ffn in zip(self.layer_kinds() * self.n_groups,
                             self.ffn_kinds() * self.n_groups):
            p = 0
            if kind == "attn":
                H, Hk = self.n_heads, self.n_kv_heads
                p += D * (H * dh) + 2 * D * (Hk * dh) + (H * dh) * D
                if self.qkv_bias:
                    p += (H + 2 * Hk) * dh
            elif kind == "mamba":
                di = self.expand * D
                p += (D * 2 * di            # in_proj
                      + di * self.d_conv    # conv
                      + di * (self.d_state * 2 + di // 16 + 1)  # B,C,dt
                      + di * self.d_state   # A
                      + di                  # D skip
                      + di * D)             # out_proj
            elif kind == "rwkv6":
                dh_r = self.rwkv_head_size
                p += 4 * D * D + D * D      # r,k,v,g,out
                p += 2 * (D * 32 * 5 + D)   # ddlerp loras (approx)
                p += 2 * D * D + D * int(3.5 * D)  # channel mix
            f = 0
            if ffn == "moe":
                fe = self.moe_d_ff or F
                f_all = self.n_experts * 3 * D * fe + D * self.n_experts
                f_act = self.top_k * 3 * D * fe + D * self.n_experts
            else:
                f_all = f_act = 3 * D * F
            total_layer += p + f_all
            active_layer += p + f_act
        counts["layers_total"] = total_layer
        counts["layers_active"] = active_layer
        counts["total"] = counts["embed"] + counts["head"] + total_layer
        counts["active"] = counts["embed"] + counts["head"] + active_layer
        return counts

    # -- smoke-test reduction -------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        group = len(self.layer_kinds())
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=group if self.family == "hybrid" else 2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128,
            moe_d_ff=64 if self.n_experts else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            vocab_size=256,
            d_state=8,
            expand=2,
            rwkv_head_size=16,
            swa_window=32,
            max_seq_len=128,
        )
