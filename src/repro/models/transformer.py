"""Transformer assembly: scan-over-layer-groups, remat, KV/SSM caches.

Layers are stacked into *groups* (``cfg.layer_kinds()``): homogeneous
architectures have a 1-layer group scanned ``n_layers`` times; Jamba scans
4 groups of [7x Mamba + 1x attention].  Group parameters are stacked on a
leading axis and consumed by ``lax.scan`` — the compiled HLO contains each
distinct block once, which keeps dry-run compile time and HLO size bounded
for the 48-layer configs.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from ..sharding.constraints import (constrain_batch_seq, constrain_logits)
from .layers import apply_norm, init_mlp, init_norm, mlp, normal_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, kind: str, ffn_kind: str, cfg, dtype) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": init_norm(cfg, dtype)}
    if kind == "attn":
        p["mixer"] = attn_mod.init_attention(k1, cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = mamba_mod.init_mamba(k1, cfg, dtype)
    elif kind == "rwkv6":
        p["mixer"] = rwkv_mod.init_rwkv6(k1, cfg, dtype)
        p["norm2"] = init_norm(cfg, dtype)
        return p  # rwkv6 channel-mix plays the FFN role
    else:  # pragma: no cover
        raise ValueError(kind)
    p["norm2"] = init_norm(cfg, dtype)
    p["ffn"] = (moe_mod.init_moe(k2, cfg, dtype) if ffn_kind == "moe"
                else init_mlp(k2, cfg, dtype))
    return p


def init_group(key, cfg, dtype):
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    keys = jax.random.split(key, len(kinds))
    return {f"b{i}": init_block(keys[i], kinds[i], ffns[i], cfg, dtype)
            for i in range(len(kinds))}


def init_params(cfg, key, dtype=jnp.float32):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    group_keys = jax.random.split(k_layers, cfg.n_groups)
    stacked = jax.vmap(lambda k: init_group(k, cfg, dtype))(group_keys)
    params = {
        "embed": normal_init(k_emb, (cfg.vocab_size, cfg.d_model),
                             cfg.d_model ** -0.5, dtype),
        "groups": stacked,
        "final_norm": init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(
            k_head, (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5,
            dtype)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def apply_block(bp, x, kind: str, ffn_kind: str, cfg, compute_dtype, *,
                positions=None, cache=None, pos=None,
                collect_cache: bool = False, kv_pad_to: int = 0):
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(bp["norm1"], x, cfg)
    if kind == "attn":
        y, new_cache = attn_mod.attention(
            bp["mixer"], h, cfg, positions=positions,
            compute_dtype=compute_dtype, cache=cache, pos=pos,
            return_kv=collect_cache, kv_pad_to=kv_pad_to)
        x = x + y
    elif kind == "mamba":
        y, new_cache = mamba_mod.mamba(bp["mixer"], h, cfg, compute_dtype,
                                       cache=cache,
                                       return_state=collect_cache)
        x = x + y
    elif kind == "rwkv6":
        st = cache or {}
        y, wkv, tm_shift = rwkv_mod.time_mix(
            bp["mixer"], h, cfg, compute_dtype,
            state=st.get("wkv"), shift_state=st.get("tm_shift"))
        x = x + y
        h2 = apply_norm(bp["norm2"], x, cfg)
        y2, cm_shift = rwkv_mod.channel_mix(
            bp["mixer"], h2, cfg, compute_dtype,
            shift_state=st.get("cm_shift"))
        x = x + y2
        new_cache = None
        if cache is not None or collect_cache:
            new_cache = {"wkv": wkv,
                         "tm_shift": tm_shift,
                         "cm_shift": cm_shift}
            if cache is not None:
                new_cache = {k: v.astype(cache[k].dtype)
                             for k, v in new_cache.items()}
        return x, aux, new_cache
    else:  # pragma: no cover
        raise ValueError(kind)
    h2 = apply_norm(bp["norm2"], x, cfg)
    if ffn_kind == "moe":
        y2, aux = moe_mod.moe_ffn(bp["ffn"], h2, cfg, compute_dtype)
    else:
        y2 = mlp(bp["ffn"], h2, compute_dtype)
    return x + y2, aux, new_cache


def _group_fn(cfg, compute_dtype, positions, x, gp, gcache=None, pos=None,
              collect_cache=False, kv_pad_to=0, remat_blocks=False):
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {} if (gcache is not None or collect_cache) else None
    for i, (kind, ffn_kind) in enumerate(zip(kinds, ffns)):
        bc = gcache.get(f"b{i}") if gcache is not None else None

        def blk(bp, x, _kind=kind, _ffn=ffn_kind, _bc=bc):
            y, aux, nc = apply_block(bp, x, _kind, _ffn, cfg,
                                     compute_dtype, positions=positions,
                                     cache=_bc, pos=pos,
                                     collect_cache=collect_cache,
                                     kv_pad_to=kv_pad_to)
            return y, aux, nc

        if remat_blocks and gcache is None and not collect_cache:
            # hierarchical remat: during a group's backward only ONE
            # block's recomputed forward is live (Jamba's 8-block group
            # held 7 Mamba layers' intermediates at once: 64 GiB/device).
            # prevent_cse=True: XLA CSE would merge the inner recompute
            # back into the outer checkpoint's forward, undoing the win.
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=True)
        x, aux, nc = blk(gp[f"b{i}"], x)
        aux_total = aux_total + aux
        if new_cache is not None:
            new_cache[f"b{i}"] = nc if nc is not None else {}
    return x, aux_total, new_cache


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(cfg, params, *, tokens=None, embeds=None, positions=None,
            compute_dtype=jnp.bfloat16,
            remat_policy: str = "nothing") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits[B,S,V], moe_aux_loss)."""
    if embeds is None:
        x = params["embed"].astype(compute_dtype)[tokens]
    else:
        x = embeds.astype(compute_dtype)
    x = constrain_batch_seq(x)
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)

    remat_on = remat_policy in ("nothing", "dots")

    def body(x, gp):
        y, aux, _ = _group_fn(cfg, compute_dtype, positions, x, gp,
                              remat_blocks=remat_on and len(
                                  cfg.layer_kinds()) > 1)
        return constrain_batch_seq(y), aux

    if remat_policy == "nothing":
        policy = jax.checkpoint_policies.nothing_saveable
    elif remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = None
    if policy is not None:
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, params["groups"])
    x = apply_norm(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    logits = constrain_logits((x @ head).astype(jnp.float32))
    return logits, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# prefill (full sequence -> last-token logits + cache for decode)
# ---------------------------------------------------------------------------


def prefill(cfg, params, *, tokens=None, embeds=None,
            compute_dtype=jnp.bfloat16, kv_pad_to: int = 0,
            remat_policy: str = "nothing"):
    """Serving prefill: run the full sequence, return (last_logits[B,V],
    cache) with the cache laid out exactly as :func:`init_cache`/decode
    expect (the realistic prefill contract: attention fills the KV cache,
    SSM layers hand over their final recurrent state)."""
    if embeds is None:
        x = params["embed"].astype(compute_dtype)[tokens]
    else:
        x = embeds.astype(compute_dtype)
    x = constrain_batch_seq(x)
    B, S, D = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, gp):
        y, _, gcache = _group_fn(cfg, compute_dtype, positions, x, gp,
                                 collect_cache=True, kv_pad_to=kv_pad_to)
        return constrain_batch_seq(y), gcache

    # no remat: prefill is forward-only, nothing to rematerialize
    x, caches = jax.lax.scan(body, x, params["groups"])
    x = apply_norm(params["final_norm"], x[:, -1:, :], cfg)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    logits = (x[:, 0, :] @ head).astype(jnp.float32)
    return logits, caches


# ---------------------------------------------------------------------------
# decode (one token against the cache)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kinds = cfg.layer_kinds()

    def one_group(_):
        gc = {}
        for i, kind in enumerate(kinds):
            if kind == "attn":
                gc[f"b{i}"] = attn_mod.init_cache(cfg, batch, max_seq, dtype)
            elif kind == "mamba":
                gc[f"b{i}"] = mamba_mod.init_mamba_cache(cfg, batch, dtype)
            elif kind == "rwkv6":
                gc[f"b{i}"] = rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
        return gc

    return jax.vmap(one_group)(jnp.arange(cfg.n_groups))


def decode_step(cfg, params, cache, token, pos,
                compute_dtype=jnp.bfloat16):
    """token: (B,) int32; pos: scalar int32 (current length).
    Returns (logits[B,V], new_cache)."""
    x = params["embed"].astype(compute_dtype)[token][:, None, :]   # (B,1,D)
    x = constrain_batch_seq(x)

    def body(x, inp):
        gp, gcache = inp
        y, _, new_cache = _group_fn(cfg, compute_dtype, None, x, gp,
                                    gcache=gcache, pos=pos)
        return constrain_batch_seq(y), new_cache

    x, new_cache = jax.lax.scan(body, x, (params["groups"], cache))
    x = apply_norm(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    logits = (x[:, 0, :] @ head).astype(jnp.float32)
    return logits, new_cache
