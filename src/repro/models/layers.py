"""Shared layers: norms, rotary embeddings, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, scale: float, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_norm(cfg, dtype):
    if cfg.norm == "nonparametric_ln":
        return {}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(params, x, cfg):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True)
                              + cfg.norm_eps)
        return (x * params["scale"].astype(jnp.float32)).astype(dt)
    mean = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), -1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
    if cfg.norm == "nonparametric_ln":  # OLMo: no learned affine
        return x.astype(dt)
    return (x * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def init_mlp(key, cfg, dtype, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = D ** -0.5, F ** -0.5
    return {"w_gate": normal_init(k1, (D, F), s_in, dtype),
            "w_up": normal_init(k2, (D, F), s_in, dtype),
            "w_down": normal_init(k3, (F, D), s_out, dtype)}


def mlp(params, x, compute_dtype):
    """SwiGLU feed-forward."""
    x = x.astype(compute_dtype)
    h = (jax.nn.silu(x @ params["w_gate"].astype(compute_dtype))
         * (x @ params["w_up"].astype(compute_dtype)))
    return h @ params["w_down"].astype(compute_dtype)


def chunked_time_scan(step, init, xs, chunk: int = 256):
    """``lax.scan`` over time with per-chunk rematerialization.

    A plain scan saves its carry at every step for the backward pass —
    for recurrent mixers that is O(T) state (34 GiB/device for Jamba's
    Mamba layers at S=4096).  Scanning over chunks whose bodies are
    ``jax.checkpoint``-ed saves the carry only at chunk boundaries and
    recomputes inside: O(T/chunk + chunk) instead of O(T).
    ``xs`` leaves are time-major (T, ...)."""
    T = jax.tree_util.tree_leaves(xs)[0].shape[0]
    c = min(chunk, T)
    while T % c:
        c //= 2
    n = T // c
    xs_c = jax.tree.map(lambda x: x.reshape(n, c, *x.shape[1:]), xs)

    def outer(carry, x_chunk):
        return jax.lax.scan(step, carry, x_chunk)

    outer = jax.checkpoint(
        outer, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False)
    carry, ys = jax.lax.scan(outer, init, xs_c)
    ys = jax.tree.map(lambda y: y.reshape(T, *y.shape[2:]), ys)
    return carry, ys
