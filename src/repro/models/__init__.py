"""Model zoo: GQA transformers, MoE, RWKV-6, Mamba, hybrids — pure JAX,
sharding-annotated, scan-over-layers."""

from .config import ModelConfig
from . import lm

__all__ = ["ModelConfig", "lm"]
