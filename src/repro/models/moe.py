"""Mixture-of-Experts FFN: top-k routing with per-expert capacity
(GShard/Switch-style token dropping).

Two execution paths:

* **no mesh registered** (CPU smoke tests): dense gather/scatter dispatch
  over the global token set.
* **mesh registered** (the production path): a ``shard_map`` over the full
  mesh.  Routing runs *locally per data shard* (no global cumsum — the
  global-token formulation made GSPMD materialize (E, C_global, D) buffers
  replicated per device, 92 GiB measured).  Experts are sharded on the
  ``model`` axis: expert-parallel when ``E % model_size == 0`` (phi-3.5,
  jamba), otherwise tensor-parallel inside every expert on the ffn dim
  (mixtral's 8 experts on a 16-wide axis).  FSDP-sharded expert weights
  are all-gathered over ``data`` just before use and the partial outputs
  are ``psum``-ed over ``model`` — the exact two-stage
  local-combine/global-combine plan Jet uses for keyed exchange
  (DESIGN.md §2: tokens are events, experts are key partitions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from ..sharding import constraints
from .layers import normal_init


def init_moe(key, cfg, dtype):
    D = cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = D ** -0.5, F ** -0.5
    return {"router": normal_init(ks[0], (D, E), s_in, jnp.float32),
            "w_gate": normal_init(ks[1], (E, D, F), s_in, dtype),
            "w_up": normal_init(ks[2], (E, D, F), s_in, dtype),
            "w_down": normal_init(ks[3], (E, F, D), s_out, dtype)}


def capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8 lanes


def _route(xt, router, cfg, C: int):
    """Local routing: returns (gates, flat_e, pos_c, keep, probs)."""
    T = xt.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    # bf16 matmul with fp32 accumulation: avoids materializing an fp32
    # copy of every token (measured 268 MB/layer at jamba scale); the
    # (T, E) logits stay fp32 for a stable softmax/top-k
    logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    flat_e = expert_idx.reshape(-1)                       # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
    keep = pos < C
    return gate_vals, expert_idx, flat_e, jnp.minimum(pos, C - 1), keep, probs


def _aux_loss(probs, expert_idx, E):
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    return E * jnp.sum(f_e * p_e)


def _expert_mlp(xe, w1, w3, w2, compute_dtype):
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                w1.astype(compute_dtype)))
         * jnp.einsum("ecd,edf->ecf", xe, w3.astype(compute_dtype)))
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(compute_dtype))


# ---------------------------------------------------------------------------
# dense path (no mesh: smoke tests / single device)
# ---------------------------------------------------------------------------


def _moe_dense(params, x, cfg, compute_dtype):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity(T, cfg)
    xt = x.reshape(T, D).astype(compute_dtype)
    gates, expert_idx, flat_e, pos_c, keep, probs = _route(
        xt, params["router"], cfg, C)
    token_of = jnp.arange(T * K, dtype=jnp.int32) // K
    contrib = xt[token_of] * keep[:, None].astype(compute_dtype)
    xe = jnp.zeros((E, C, D), compute_dtype).at[flat_e, pos_c].add(contrib)
    ye = _expert_mlp(xe, params["w_gate"], params["w_up"],
                     params["w_down"], compute_dtype)
    y_slots = ye[flat_e, pos_c]
    w = (gates.reshape(-1) * keep).astype(compute_dtype)
    y = (y_slots * w[:, None]).reshape(T, K, D).sum(1)
    return y.reshape(B, S, D), _aux_loss(probs, expert_idx, E)


# ---------------------------------------------------------------------------
# shard_map path (production)
# ---------------------------------------------------------------------------


def _moe_sharded(params, x, cfg, compute_dtype, mesh):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    msize = mesh.shape["model"]
    ep = E % msize == 0                       # expert-parallel feasible?
    # serving weights are bf16 and model-sharded only: no FSDP dim, no
    # per-layer weight all-gathers (decode was paying 620 MB/token)
    fsdp = params["w_gate"].dtype != jnp.bfloat16
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_ok = B % _prod(mesh, batch) == 0 and B >= _prod(mesh, batch)
    x_spec = P(batch if b_ok else None, None, None)
    dsh = "data" if fsdp else None
    if ep:
        w1_spec = w3_spec = P("model", dsh, None)      # (E@m, D@fsdp, F)
        w2_spec = P("model", dsh, None)                # (E@m, F@fsdp, D)
    else:
        w1_spec = w3_spec = P(None, dsh, "model")      # (E, D@fsdp, F@m)
        w2_spec = P(None, "model", dsh)                # (E, F@m, D@fsdp)

    def local(router, w1, w3, w2, xb):
        Bl, Sl, _ = xb.shape
        T = Bl * Sl
        C = capacity(T, cfg)
        xt = xb.reshape(T, D).astype(compute_dtype)
        gates, expert_idx, flat_e, pos_c, keep, probs = _route(
            xt, router, cfg, C)
        token_of = jnp.arange(T * K, dtype=jnp.int32) // K
        if ep:
            E_loc = E // msize
            first = jax.lax.axis_index("model") * E_loc
            el = flat_e - first
            mine = (el >= 0) & (el < E_loc) & keep
            el_c = jnp.clip(el, 0, E_loc - 1)
            contrib = xt[token_of] * mine[:, None].astype(compute_dtype)
            xe = jnp.zeros((E_loc, C, D), compute_dtype).at[
                el_c, pos_c].add(contrib)
            if fsdp:  # materialize full D / F dims just before use
                w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
                w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
                w2 = jax.lax.all_gather(w2, "data", axis=1, tiled=True)
            ye = _expert_mlp(xe, w1, w3, w2, compute_dtype)
            y_slots = ye[el_c, pos_c]
            wgt = (gates.reshape(-1) * mine).astype(compute_dtype)
        else:
            contrib = xt[token_of] * keep[:, None].astype(compute_dtype)
            xe = jnp.zeros((E, C, D), compute_dtype).at[
                flat_e, pos_c].add(contrib)
            if fsdp:
                w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
                w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
                w2 = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
            ye = _expert_mlp(xe, w1, w3, w2, compute_dtype)  # partial on F
            y_slots = ye[flat_e, pos_c]
            wgt = (gates.reshape(-1) * keep).astype(compute_dtype)
        y = (y_slots * wgt[:, None]).reshape(T, K, D).sum(1)
        y = jax.lax.psum(y, "model")
        aux = _aux_loss(probs, expert_idx, E)
        if batch:
            aux = jax.lax.pmean(aux, batch)
        return y.reshape(Bl, Sl, D), aux

    f = shard_map(local, mesh,
                  (P(None, None), w1_spec, w3_spec, w2_spec, x_spec),
                  (x_spec, P()))
    return f(params["router"], params["w_gate"], params["w_up"],
             params["w_down"], x)


def _prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def moe_ffn(params, x, cfg, compute_dtype):
    """x: (B, S, D) -> ((B, S, D), aux load-balancing loss)."""
    mesh = constraints.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return _moe_dense(params, x, cfg, compute_dtype)
    return _moe_sharded(params, x, cfg, compute_dtype, mesh)
