"""RWKV-6 "Finch" mixer: data-dependent decay linear attention + channel
mix (Peng et al., arXiv:2404.05892).

State per head is a (head_dim x head_dim) matrix updated multiplicatively
by the data-dependent decay ``w`` — an O(1)-per-token streaming recurrence.
Training scans over time; decode is a single state update.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import chunked_time_scan, normal_init

LORA_DIM = 32
DECAY_LORA = 64


def _heads(cfg):
    H = cfg.d_model // cfg.rwkv_head_size
    return H, cfg.rwkv_head_size


def init_rwkv6(key, cfg, dtype):
    D = cfg.d_model
    H, dh = _heads(cfg)
    F = cfg.d_ff
    ks = jax.random.split(key, 12)
    s = D ** -0.5
    return {
        # time-mix ddlerp
        "maa_x": jnp.zeros((D,), dtype),
        "maa": jnp.zeros((5, D), dtype),                 # w,k,v,r,g
        "tm_w1": normal_init(ks[0], (D, 5 * LORA_DIM), s, dtype),
        "tm_w2": normal_init(ks[1], (5, LORA_DIM, D), LORA_DIM ** -0.5,
                             dtype),
        # data-dependent decay
        "w0": jnp.full((D,), -6.0, dtype),
        "td_w1": normal_init(ks[2], (D, DECAY_LORA), s, dtype),
        "td_w2": normal_init(ks[3], (DECAY_LORA, D), DECAY_LORA ** -0.5,
                             dtype),
        "u": normal_init(ks[4], (H, dh), 0.1, dtype),    # bonus (time_faaaa)
        "wr": normal_init(ks[5], (D, D), s, dtype),
        "wk": normal_init(ks[6], (D, D), s, dtype),
        "wv": normal_init(ks[7], (D, D), s, dtype),
        "wg": normal_init(ks[8], (D, D), s, dtype),
        "wo": normal_init(ks[9], (D, D), s, dtype),
        "ln_x_scale": jnp.ones((D,), dtype),
        "ln_x_bias": jnp.zeros((D,), dtype),
        # channel-mix
        "cm_maa_k": jnp.zeros((D,), dtype),
        "cm_maa_r": jnp.zeros((D,), dtype),
        "cm_wk": normal_init(ks[10], (D, F), s, dtype),
        "cm_wv": normal_init(ks[11], (F, D), F ** -0.5, dtype),
        "cm_wr": normal_init(jax.random.fold_in(key, 99), (D, D), s, dtype),
    }


def _shift(x, state):
    """x_{t-1} with ``state`` as the t=-1 input. x: (B,S,D)."""
    if x.shape[1] == 1:
        return state[:, None, :]
    prev = jnp.concatenate([state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _group_norm(y, scale, bias, H, eps=1e-5):
    """Per-head layernorm of (B, S, H*dh)."""
    B, S, D = y.shape
    yh = y.reshape(B, S, H, D // H).astype(jnp.float32)
    mean = yh.mean(-1, keepdims=True)
    var = ((yh - mean) ** 2).mean(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, S, D) * scale.astype(jnp.float32)
            + bias.astype(jnp.float32))


def time_mix(params, x, cfg, compute_dtype, state=None, shift_state=None):
    """Returns (y, new_wkv_state, new_shift_state).

    state: (B, H, dh, dh) wkv state; shift_state: (B, D) last input."""
    B, S, D = x.shape
    H, dh = _heads(cfg)
    x = x.astype(compute_dtype)
    if shift_state is None:
        shift_state = jnp.zeros((B, D), compute_dtype)
    xx = _shift(x, shift_state.astype(compute_dtype)) - x
    xxx = x + xx * params["maa_x"].astype(compute_dtype)
    lora = jnp.tanh(xxx @ params["tm_w1"].astype(compute_dtype))
    lora = lora.reshape(B, S, 5, LORA_DIM)
    mods = jnp.einsum("bsfl,fld->bsfd", lora,
                      params["tm_w2"].astype(compute_dtype))    # (B,S,5,D)
    maa = params["maa"].astype(compute_dtype)                    # (5, D)
    xw, xk, xv, xr, xg = [x + xx * (maa[i] + mods[:, :, i, :])
                          for i in range(5)]
    w = (params["w0"].astype(jnp.float32)
         + (jnp.tanh(xw @ params["td_w1"].astype(compute_dtype))
            @ params["td_w2"].astype(compute_dtype)).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w))                                    # (B,S,D)
    r = (xr @ params["wr"].astype(compute_dtype)).reshape(B, S, H, dh)
    k = (xk @ params["wk"].astype(compute_dtype)).reshape(B, S, H, dh)
    v = (xv @ params["wv"].astype(compute_dtype)).reshape(B, S, H, dh)
    g = jax.nn.silu(xg @ params["wg"].astype(compute_dtype))
    u = params["u"].astype(jnp.float32)                          # (H, dh)
    wh = w.reshape(B, S, H, dh)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp      # (B,H,dh) each
        kv = jnp.einsum("bhi,bhj->bhij", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y_t = jnp.einsum("bhi,bhij->bhj", r_t.astype(jnp.float32),
                         s + u[None, :, :, None] * kv)
        s = w_t.astype(jnp.float32)[..., None] * s + kv
        return s, y_t

    if state is None:
        state = jnp.zeros((B, H, dh, dh), jnp.float32)
    if S == 1:
        state, y = step(state, (r[:, 0], k[:, 0], v[:, 0], wh[:, 0]))
        y = y[:, None]
    else:
        state, ys = chunked_time_scan(
            step, state,
            (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
             jnp.moveaxis(v, 1, 0), jnp.moveaxis(wh, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                               # (B,S,H,dh)
    y = _group_norm(y.reshape(B, S, D), params["ln_x_scale"],
                    params["ln_x_bias"], H)
    y = (y.astype(compute_dtype) * g) @ params["wo"].astype(compute_dtype)
    return y, state, x[:, -1, :]


def channel_mix(params, x, cfg, compute_dtype, shift_state=None):
    B, S, D = x.shape
    x = x.astype(compute_dtype)
    if shift_state is None:
        shift_state = jnp.zeros((B, D), compute_dtype)
    xx = _shift(x, shift_state.astype(compute_dtype)) - x
    xk = x + xx * params["cm_maa_k"].astype(compute_dtype)
    xr = x + xx * params["cm_maa_r"].astype(compute_dtype)
    k = jnp.square(jax.nn.relu(xk @ params["cm_wk"].astype(compute_dtype)))
    kv = k @ params["cm_wv"].astype(compute_dtype)
    y = jax.nn.sigmoid(xr @ params["cm_wr"].astype(compute_dtype)) * kv
    return y, x[:, -1, :]


def init_rwkv_cache(cfg, batch: int, dtype):
    H, dh = _heads(cfg)
    D = cfg.d_model
    return {"wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "tm_shift": jnp.zeros((batch, D), dtype),
            "cm_shift": jnp.zeros((batch, D), dtype)}
