"""Mamba selective SSM mixer (Jamba's recurrent layer).

Training/prefill runs the recurrence with ``lax.scan`` over time; decode is
a single-step state update — the streaming-state form that makes SSM layers
ideal Jet processors (O(1) state per step, DESIGN.md §3).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import chunked_time_scan, normal_init


def _dims(cfg):
    di = cfg.expand * cfg.d_model
    dt_rank = max(1, di // 16)
    return di, dt_rank


def init_mamba(key, cfg, dtype):
    D = cfg.d_model
    di, R = _dims(cfg)
    N, Kc = cfg.d_state, cfg.d_conv
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": normal_init(ks[0], (D, 2 * di), D ** -0.5, dtype),
        "conv_w": normal_init(ks[1], (di, Kc), Kc ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": normal_init(ks[2], (di, R + 2 * N), di ** -0.5, dtype),
        "dt_proj": normal_init(ks[3], (R, di), R ** -0.5, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus(-4.6) ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": normal_init(ks[4], (di, D), di ** -0.5, dtype),
    }
    return p


def _ssm_inputs(params, x, cfg, compute_dtype, conv_state=None):
    """Shared projections. x: (B, S, D) -> (xs, z, dt, Bs, Cs, new_conv)."""
    B, S, D = x.shape
    di, R = _dims(cfg)
    N, Kc = cfg.d_state, cfg.d_conv
    xz = x.astype(compute_dtype) @ params["in_proj"].astype(compute_dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di)
    # causal depthwise conv of width Kc
    if conv_state is None:
        xp = jnp.pad(xs, ((0, 0), (Kc - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(compute_dtype), xs], axis=1)
    w = params["conv_w"].astype(compute_dtype)
    y = params["conv_b"].astype(compute_dtype)
    conv = sum(xp[:, k:k + S, :] * w[:, k] for k in range(Kc)) + y
    new_conv = xp[:, -(Kc - 1):, :] if Kc > 1 else None
    xc = jax.nn.silu(conv)
    proj = xc @ params["x_proj"].astype(compute_dtype)
    dt, Bs, Cs = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(compute_dtype)
                         + params["dt_bias"].astype(compute_dtype))
    return xc, z, dt, Bs, Cs, new_conv


def mamba(params, x, cfg, compute_dtype,
          cache: Optional[dict] = None,
          return_state: bool = False) -> Tuple[jnp.ndarray,
                                               Optional[dict]]:
    """cache = {"h": (B, di, N), "conv": (B, Kc-1, di)} for decode;
    ``return_state`` (prefill) returns the final state in cache layout."""
    B, S, D = x.shape
    N = cfg.d_state
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (di, N)
    D_skip = params["D"].astype(jnp.float32)

    if cache is None:
        xc, z, dt, Bs, Cs, conv_tail = _ssm_inputs(params, x, cfg,
                                                   compute_dtype)

        def step(h, inp):
            xc_t, dt_t, B_t, C_t = inp        # (B,di), (B,di), (B,N), (B,N)
            dt32 = dt_t.astype(jnp.float32)
            dA = jnp.exp(dt32[..., None] * A)                  # (B, di, N)
            dBx = (dt32 * xc_t.astype(jnp.float32))[..., None] \
                * B_t.astype(jnp.float32)[:, None, :]
            h = h * dA + dBx
            y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
            return h, y

        h0 = jnp.zeros((B, cfg.expand * D, N), jnp.float32)
        xs_t = jnp.moveaxis(xc, 1, 0)
        h_last, ys = chunked_time_scan(
            step, h0, (xs_t, jnp.moveaxis(dt, 1, 0),
                       jnp.moveaxis(Bs, 1, 0), jnp.moveaxis(Cs, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                              # (B, S, di)
        y = y + xc.astype(jnp.float32) * D_skip
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(compute_dtype)
        out = y @ params["out_proj"].astype(compute_dtype)
        if return_state:
            return out, {"h": h_last, "conv": conv_tail}
        return out, None

    # -- decode: single step -------------------------------------------------------
    xc, z, dt, Bs, Cs, new_conv = _ssm_inputs(
        params, x, cfg, compute_dtype, conv_state=cache["conv"])
    xc_t, dt_t = xc[:, 0], dt[:, 0]
    B_t, C_t = Bs[:, 0], Cs[:, 0]
    dt32 = dt_t.astype(jnp.float32)
    dA = jnp.exp(dt32[..., None] * A)
    dBx = (dt32 * xc_t.astype(jnp.float32))[..., None] \
        * B_t.astype(jnp.float32)[:, None, :]
    h = cache["h"].astype(jnp.float32) * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
    y = y + xc_t.astype(jnp.float32) * D_skip
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None, :]
    y = y.astype(compute_dtype) @ params["out_proj"].astype(compute_dtype)
    return y, {"h": h.astype(cache["h"].dtype),
               "conv": new_conv.astype(cache["conv"].dtype)}


def init_mamba_cache(cfg, batch: int, dtype):
    di, _ = _dims(cfg)
    return {"h": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype)}
