"""Grouped-query attention: full / sliding-window, chunked online-softmax
for long sequences, and single-token decode against a KV cache.

The chunked path is the pure-jnp counterpart of the Pallas flash kernels in
``repro.kernels``: a ``lax.scan`` over KV chunks carrying the online-softmax
running (max, denom, out) — memory O(S·chunk) instead of O(S²), which is
what lets the 32k-prefill shapes fit per-device HBM in the dry-run.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.constraints import (constrain, constrain_heads,
                                    constrain_scores)
from .layers import apply_rope, normal_init

NEG_INF = -1e30
# use the chunked (online-softmax) path for S > threshold (measured: at
# S=4096 the chunked path's saved online-softmax carries cost MORE than
# the dense path's rematerialized score tensors)
CHUNK_THRESHOLD = 4096
KV_CHUNK = 1024

BATCH = ("pod", "data")


def _expand_kv(k, G: int):
    """Repeat kv heads to the full query-head count.

    GQA saves memory in the *cache*, not in compute; expanding for the
    matmul keeps a single head dim (H = n_heads), which shards cleanly on
    the ``model`` axis — sharding the split (kv_head, group) dims made
    GSPMD replicate the score tensors (measured 51 GiB/device)."""
    if G == 1:
        return k
    return jnp.repeat(k, G, axis=2)


def init_attention(key, cfg, dtype):
    D, dh = cfg.d_model, cfg.head_dim_
    H, Hk = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = D ** -0.5
    p = {"wq": normal_init(ks[0], (D, H * dh), s, dtype),
         "wk": normal_init(ks[1], (D, Hk * dh), s, dtype),
         "wv": normal_init(ks[2], (D, Hk * dh), s, dtype),
         "wo": normal_init(ks[3], (H * dh, D), (H * dh) ** -0.5, dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((Hk * dh,), dtype)
        p["bv"] = jnp.zeros((Hk * dh,), dtype)
    return p


def _project_qkv(params, x, cfg, compute_dtype):
    B, S, D = x.shape
    dh, H, Hk = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    x = x.astype(compute_dtype)
    q = x @ params["wq"].astype(compute_dtype)
    k = x @ params["wk"].astype(compute_dtype)
    v = x @ params["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    return (q.reshape(B, S, H, dh), k.reshape(B, S, Hk, dh),
            v.reshape(B, S, Hk, dh))


def _sdpa_decode(q, k, v, q_pos, k_pos, cfg):
    """Single-token GQA attention against a sequence-sharded cache.

    No kv expansion and no head sharding: the only sharded dim is the
    cache sequence, so the softmax reductions and the PV contraction
    partial-reduce over it with small psums (B,H,dh)-sized — the
    sequence-parallel flash-decode schedule."""
    B, Sq, H, dh = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    mask = (k_pos <= q_pos[0])
    if cfg.attention == "swa":
        mask &= (q_pos[0] - k_pos) < cfg.swa_window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v.dtype), v)
    return out.reshape(B, 1, H, dh)


def _sdpa_full(q, k, v, q_pos, k_pos, cfg):
    """Dense causal (+ SWA) attention. q: (B,Sq,H,dh), k/v: (B,Sk,Hk,dh)."""
    B, Sq, H, dh = q.shape
    G = H // k.shape[2]
    k = constrain_heads(_expand_kv(k, G))
    v = constrain_heads(_expand_kv(v, G))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = constrain_scores(scores * (dh ** -0.5))
    mask = k_pos[None, :] <= q_pos[:, None]                    # causal
    if cfg.attention == "swa":
        mask &= (q_pos[:, None] - k_pos[None, :]) < cfg.swa_window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def _sdpa_chunked(q, k, v, q_pos, k_pos, cfg, chunk=KV_CHUNK):
    """Online-softmax over KV chunks; memory O(Sq * chunk) per head."""
    B, Sq, H, dh = q.shape
    G = H // k.shape[2]
    Sk = k.shape[1]
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    Hk = k.shape[2]
    kc = k.reshape(B, n_chunks, chunk, Hk, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hk, dh).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)
    q = constrain_heads(q * (dh ** -0.5))

    def step(carry, inputs):
        m, l, o = carry          # (B,H,Sq), (B,H,Sq), (B,H,Sq,dh)
        k_i, v_i, p_i = inputs   # (B,chunk,Hk,dh), ..., (chunk,)
        k_i = constrain_heads(_expand_kv(k_i, G))
        v_i = constrain_heads(_expand_kv(v_i, G))
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_i,
                       preferred_element_type=jnp.float32)
        mask = p_i[None, :] <= q_pos[:, None]
        if cfg.attention == "swa":
            mask &= (q_pos[:, None] - p_i[None, :]) < cfg.swa_window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_i = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_i)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = (o * alpha[..., None]
                 + jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_i.dtype), v_i)
                 .astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    o0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, pc))
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 2, 1, 3)                  # (B, Sq, H, dh)
    return out.astype(q.dtype)


def attention(params, x, cfg, *, positions, compute_dtype,
              cache: Optional[dict] = None, pos=None,
              chunked: Optional[bool] = None,
              return_kv: bool = False, kv_pad_to: int = 0
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence (train/prefill) or single-token decode attention.

    Train/prefill: ``positions`` (S,) int32; returns (y, None) — or, with
    ``return_kv``, (y, cache) where cache is laid out exactly as
    :func:`init_cache` expects (SWA: rolling slots; optionally padded to
    ``kv_pad_to``) so decode can continue from a prefill.
    Decode: ``cache`` = {"k","v"} of (B, S_max, Hk, dh), ``pos`` scalar =
    current length; x is (B, 1, D); returns (y, new_cache).
    """
    B, S, D = x.shape
    q, k, v = _project_qkv(params, x, cfg, compute_dtype)
    q = constrain_heads(q)
    if cache is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        use_chunked = (S > CHUNK_THRESHOLD) if chunked is None else chunked
        sdpa = _sdpa_chunked if use_chunked else _sdpa_full
        out = sdpa(q, k, v, positions, positions, cfg)
        y = out.reshape(B, S, -1) @ params["wo"].astype(compute_dtype)
        if not return_kv:
            return y, None
        kc, vc = k, v
        if cfg.attention == "swa" and S >= cfg.swa_window:
            # rolling-slot layout: decode writes position p at slot p % W,
            # so the last W prefill positions S-W+i (i in [0, W)) must land
            # at slot (S-W+i) % W = (S%W + i) % W — a roll by S % W.  (This
            # is exactly what a token-by-token decode would have produced;
            # verified bit-identical in test_swa_prefill_cache_rolls_*.)
            W = cfg.swa_window
            r = S % W
            kc = jnp.roll(kc[:, -W:], r, axis=1)
            vc = jnp.roll(vc[:, -W:], r, axis=1)
        if kv_pad_to and kv_pad_to > kc.shape[1]:
            padding = ((0, 0), (0, kv_pad_to - kc.shape[1]), (0, 0), (0, 0))
            kc = jnp.pad(kc, padding)
            vc = jnp.pad(vc, padding)
        return y, {"k": kc, "v": vc}
    # -- decode ---------------------------------------------------------------
    q = apply_rope(q, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)
    k = apply_rope(k, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)
    S_max = cache["k"].shape[1]
    quantized = cache["k"].dtype == jnp.int8
    if cfg.attention == "swa" and S_max <= cfg.swa_window:
        # rolling cache: slot = pos % window
        slot = jnp.mod(pos, S_max)
    else:
        slot = pos
    if quantized:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                              (0, slot, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                              (0, slot, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, slot, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, slot, 0)),
        }
        new_k = _dequantize_kv(new_cache["k"], new_cache["k_scale"],
                               compute_dtype)
        new_v = _dequantize_kv(new_cache["v"], new_cache["v_scale"],
                               compute_dtype)
    else:
        new_k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": new_k, "v": new_v}
    if cfg.attention == "swa" and S_max <= cfg.swa_window:
        idx = jnp.arange(S_max)
        k_pos = jnp.where(idx <= slot, pos - slot + idx,
                          pos - slot - S_max + idx)
        k_pos = jnp.where(k_pos < 0, 2**30, k_pos)
    else:
        k_pos = jnp.arange(S_max)
    q_pos = jnp.full((1,), pos, jnp.int32)
    out = _sdpa_decode(q, new_k.astype(compute_dtype),
                       new_v.astype(compute_dtype), q_pos, k_pos, cfg)
    y = out.reshape(B, 1, -1) @ params["wo"].astype(compute_dtype)
    return y, new_cache


def init_cache(cfg, batch: int, max_seq: int, dtype):
    """KV cache. ``dtype=jnp.int8`` enables quantized storage with one
    fp16 scale per (position, kv head) — decode is memory-roofline-bound
    on reading the cache, so int8 halves the dominant term (§Perf)."""
    dh, Hk = cfg.head_dim_, cfg.n_kv_heads
    if cfg.attention == "swa":
        max_seq = min(max_seq, cfg.swa_window)
    cache = {"k": jnp.zeros((batch, max_seq, Hk, dh), dtype),
             "v": jnp.zeros((batch, max_seq, Hk, dh), dtype)}
    if dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((batch, max_seq, Hk), jnp.float16)
        cache["v_scale"] = jnp.zeros((batch, max_seq, Hk), jnp.float16)
    return cache


def _quantize_kv(x):
    """(B, S, Hk, dh) -> int8 values + per-(pos, head) fp16 scales."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)
