"""LM task heads: loss, train/serve step builders."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import transformer

MOE_AUX_COEF = 0.01
IGNORE_INDEX = -100


def init_params(cfg, key, dtype=jnp.float32):
    return transformer.init_params(cfg, key, dtype)


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return transformer.init_cache(cfg, batch, max_seq, dtype)


def cross_entropy(logits, labels):
    """logits (B,S,V) fp32; labels (B,S) int32 with IGNORE_INDEX masking.

    Written so the vocab dim stays sharded under pjit: the gold logit is
    extracted with an iota-compare-select reduction (fuses into the reduce;
    no gather) instead of ``take_along_axis`` (which forces an all-gather
    of the full vocab dim — 13+ GiB/device at internlm2 scale)."""
    mask = labels != IGNORE_INDEX
    safe = jnp.where(mask, labels, 0)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    shifted = logits - m[..., None]
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == safe[..., None], shifted, 0.0), axis=-1)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(cfg, params, batch, compute_dtype=jnp.bfloat16,
            remat_policy="nothing"):
    logits, aux = transformer.forward(
        cfg, params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        compute_dtype=compute_dtype, remat_policy=remat_policy)
    loss = cross_entropy(logits, batch["labels"])
    return loss + MOE_AUX_COEF * aux, {"ce_loss": loss, "moe_aux": aux}


def make_train_step(cfg, optimizer, compute_dtype=jnp.bfloat16,
                    remat_policy="nothing", grad_transform=None,
                    microbatches: int = 1):
    """Returns step(state, batch) -> (state, metrics).

    ``state`` = {"params", "opt", "step"}.  ``grad_transform`` hooks in
    distributed tricks (gradient compression, clipping) before the update.
    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split into M sequential microbatches scanned with full remat — peak
    activation memory scales ~1/M at the cost of M smaller matmuls (the
    standard fit-knob for the large train_4k cells)."""
    from ..sharding.constraints import constrain, get_mesh, BATCH

    def _constrain_grads(g):
        """Pin gradient shardings to the parameter layout: the embedding
        gradient otherwise materializes UNSHARDED (V, D) f32 per device
        (the scatter-add cotangent of the lookup) — 1-2.3 GiB x several
        copies at internlm/jamba scale."""
        mesh = get_mesh()
        if mesh is None:
            return g
        from ..sharding.rules import param_sharding
        return jax.lax.with_sharding_constraint(g, param_sharding(mesh, g))

    def grads_of(params, batch):
        def lf(p):
            return loss_fn(cfg, p, batch, compute_dtype, remat_policy)
        (loss, metrics), g = jax.value_and_grad(lf, has_aux=True)(params)
        return (loss, metrics), _constrain_grads(g)

    def step(state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(state["params"], batch)
        else:
            M = microbatches

            def split(x):
                x = x.reshape(M, x.shape[0] // M, *x.shape[1:])
                return constrain(x, None, BATCH, *([None] * (x.ndim - 2)))

            mb = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def micro(carry, b):
                acc, loss_acc, aux_acc = carry
                (loss, metrics), g = grads_of(state["params"], b)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss,
                        aux_acc + metrics["moe_aux"]), None

            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss_sum / M
            metrics = {"ce_loss": loss, "moe_aux": aux_sum / M}
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt = optimizer.update(state["params"],
                                               grads, state["opt"])
        metrics = dict(metrics, loss=loss,
                       grad_norm=global_norm(grads))
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def make_serve_step(cfg, compute_dtype=jnp.bfloat16, greedy=True):
    """Returns decode(params, cache, token, pos) -> (next_token, cache)."""

    def serve(params, cache, token, pos):
        logits, new_cache = transformer.decode_step(
            cfg, params, cache, token, pos, compute_dtype)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return serve


def make_prefill(cfg, compute_dtype=jnp.bfloat16):
    """Full-sequence forward for the prefill shapes (no cache write —
    the benchmark measures the attention/ffn compute itself)."""

    def prefill(params, tokens=None, embeds=None):
        logits, _ = transformer.forward(cfg, params, tokens=tokens,
                                        embeds=embeds,
                                        compute_dtype=compute_dtype,
                                        remat_policy="none")
        return logits

    return prefill


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
