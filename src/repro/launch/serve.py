"""Serving driver: batched request decoding through the streaming runtime.

Requests (prompts) arrive as events; the server runs continuous batched
decode with a Jet-style ingestion loop — credit-based admission, per-step
snapshot hooks for the KV/SSM cache, and request/response bookkeeping::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import lm
from ..sharding import constraints


class BatchedLMServer:
    """Continuous-batching decode loop over a fixed slot count."""

    def __init__(self, cfg, params, batch_slots: int = 8,
                 max_seq: int = 512, compute_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.serve = jax.jit(lm.make_serve_step(cfg, compute_dtype),
                             donate_argnums=(1,))
        self.cache = lm.init_cache(cfg, batch_slots, max_seq, compute_dtype)
        self.tokens = jnp.zeros((batch_slots,), jnp.int32)
        # slot bookkeeping (host side)
        self.free: List[int] = list(range(batch_slots))
        self.active: Dict[int, dict] = {}
        self.pos = 0
        self.completed: List[dict] = []

    def submit(self, request_id, prompt: List[int], max_new: int) -> bool:
        """Admit a request if a slot is free (credit-based admission)."""
        if not self.free:
            return False
        slot = self.free.pop()
        self.active[slot] = {"id": request_id, "prompt": list(prompt),
                             "out": [], "max_new": max_new, "fed": 0}
        return True

    def step(self) -> None:
        """One global decode step: each active slot either consumes its
        next prompt token (sequential prefill) or appends a generation."""
        feed = np.array(self.tokens)  # writable host copy
        for slot, req in self.active.items():
            if req["fed"] < len(req["prompt"]):
                feed[slot] = req["prompt"][req["fed"]]
        next_tok, self.cache = self.serve(
            self.params, self.cache, jnp.asarray(feed),
            jnp.int32(self.pos))
        self.pos += 1
        out = np.asarray(next_tok)
        done = []
        for slot, req in self.active.items():
            if req["fed"] < len(req["prompt"]):
                req["fed"] += 1
                if req["fed"] == len(req["prompt"]):
                    req["out"].append(int(out[slot]))
            else:
                req["out"].append(int(out[slot]))
            if len(req["out"]) >= req["max_new"]:
                done.append(slot)
        for slot in done:
            req = self.active.pop(slot)
            self.completed.append(req)
            self.free.append(slot)
        self.tokens = next_tok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed),
                            jnp.float32)
    server = BatchedLMServer(cfg, params, batch_slots=args.slots,
                             max_seq=args.prompt_len + args.max_new
                             + args.requests * 4 + 8)
    rng = np.random.RandomState(args.seed)
    pending = [(i, rng.randint(0, cfg.vocab_size,
                               args.prompt_len).tolist())
               for i in range(args.requests)]
    t0 = time.time()
    steps = 0
    while pending or server.active:
        while pending and server.submit(pending[0][0], pending[0][1],
                                        args.max_new):
            pending.pop(0)
        server.step()
        steps += 1
        if steps > 100_000:
            raise RuntimeError("server did not drain")
    dt = time.time() - t0
    n_tok = sum(len(r["out"]) for r in server.completed)
    print(f"served {len(server.completed)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s, {steps} steps)")
    return server.completed


if __name__ == "__main__":
    main()
