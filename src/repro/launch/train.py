"""Training driver.

CPU-scale end-to-end runs (the examples) and production-mesh launches use
the same entry point::

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 100 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` swaps in the smoke-scale config; omit it (and add
``--mesh 16x16``) on real hardware.  Restart-ability: the data pipeline is
a pure function of the step (replayable source), so
``--resume`` + checkpoint gives exactly-once training semantics.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import lm
from ..runtime.checkpoint import CheckpointManager
from ..runtime.data import SyntheticLMData
from ..runtime.optimizer import AdamW
from ..sharding import constraints
from ..sharding.rules import batch_sharding, state_sharding


def build_mesh(spec: str):
    if not spec:
        return None
    from .mesh import make_production_mesh, make_smoke_mesh
    if spec == "16x16":
        return make_production_mesh()
    if spec == "2x16x16":
        return make_production_mesh(multi_pod=True)
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("data", "model")[:len(dims)] if len(dims) == 2 else ("data",)
    return make_smoke_mesh(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--schedule-steps", type=int, default=0,
                    help="LR schedule horizon (default: --steps); set it "
                         "when a run will be resumed past --steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = build_mesh(args.mesh)
    if mesh is not None:
        constraints.set_mesh(mesh)

    horizon = args.schedule_steps or args.steps
    opt = AdamW(lr=args.lr, warmup_steps=max(2, horizon // 20),
                total_steps=horizon)
    step_fn = lm.make_train_step(cfg, opt,
                                 compute_dtype=jnp.float32 if args.reduced
                                 else jnp.bfloat16,
                                 microbatches=args.microbatches)

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed),
                            jnp.float32)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if mesh is not None:
        sh = state_sharding(mesh, jax.eval_shape(lambda: state))
        state = jax.tree.map(jax.device_put, state, sh)

    data = SyntheticLMData(
        cfg.vocab_size, args.batch, args.seq, seed=args.seed,
        embed_dim=cfg.d_model if cfg.modality == "vlm_stub" else None)

    ckpt = CheckpointManager(args.ckpt_dir, async_save=True) \
        if args.ckpt_dir else None
    start = 0
    if ckpt is not None and args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start = int(state["step"])
        print(f"resumed from step {start}")

    jitted = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch_at(step).items()}
        state, metrics = jitted(state, batch)
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            tok_s = args.batch * args.seq * args.log_every / max(dt, 1e-9)
            print(f"step {step + 1:5d}  loss {loss:8.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):8.3f}  "
                  f"{tok_s:9.0f} tok/s", flush=True)
            t0 = time.time()
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, step + 1)
    if ckpt is not None:
        ckpt.save(state, args.steps)
        ckpt.wait()
    constraints.set_mesh(None)
    return losses


if __name__ == "__main__":
    main()
