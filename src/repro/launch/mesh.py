"""Production mesh definition.

A *function*, not a module-level constant, so importing this module never
touches JAX device state.  The single-pod mesh is 16x16 = 256 chips (one
TPU v5e pod); the multi-pod mesh adds a leading ``pod`` axis (2 pods = 512
chips) over which data parallelism (and checkpoint failure domains)
extend.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist; "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)
