"""Per-(architecture x input-shape) cell builders for the dry-run, the
trainer and the server.

``build_cell(arch, shape, mesh)`` returns a :class:`Cell`: the step
function, ShapeDtypeStruct stand-ins for every input (weak-type-correct,
shardable, no device allocation), matching in/out shardings, and the donate
policy.  ``decode_*``/``long_*`` shapes lower ``serve_step`` (one token
against a seq_len cache), ``prefill_*`` lowers the cache-filling prefill,
``train_*`` lowers a full train step (fwd + bwd + sharded AdamW update).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ShapeSpec, get_config
from ..models import lm
from ..models.config import ModelConfig
from ..runtime.optimizer import AdamW
from ..sharding.rules import (batch_sharding, cache_sharding, param_sharding,
                              state_sharding)


#: gradient-accumulation factor per arch for train_4k — chosen so the
#: baseline fits 16 GiB/chip HBM (v5e); recorded with each dry-run result
MICROBATCHES = {
    "jamba-v0.1-52b": 8,      # M=16 only helps 6% (single-pod-only anyway)
    "mixtral-8x7b": 2,
    "phi3.5-moe-42b-a6.6b": 2,
    "internlm2-20b": 4,       # 17.1 -> 13.3 GiB/chip (hillclimb A)
    "llava-next-34b": 2,
    "minitron-4b": 4,         # 16.8 -> 13.3 GiB/chip (hillclimb A)
}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable                    # jit-able step function
    args: Tuple[Any, ...]           # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any              # None -> let the partitioner choose
    donate_argnums: Tuple[int, ...]
    cfg: ModelConfig
    static_meta: dict


def _batch_struct(cfg: ModelConfig, spec: ShapeSpec):
    B, S = spec.global_batch, spec.seq_len
    if cfg.modality == "vlm_stub" and spec.kind != "decode":
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def build_cell(arch: str, shape_name: str, mesh,
               remat_policy: str = "nothing",
               cache_dtype=jnp.bfloat16) -> Cell:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    meta = {"seq_len": spec.seq_len, "global_batch": spec.global_batch,
            "cache_dtype": str(jnp.dtype(cache_dtype))}

    if spec.kind == "train":
        opt = AdamW()
        mb = MICROBATCHES.get(arch, 1)
        meta["microbatches"] = mb
        step = lm.make_train_step(cfg, opt, compute_dtype=jnp.bfloat16,
                                  remat_policy=remat_policy,
                                  microbatches=mb)
        params_s = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
        opt_s = jax.eval_shape(opt.init, params_s)
        state_s = {"params": params_s, "opt": opt_s,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        batch_s = _batch_struct(cfg, spec)
        in_sh = (state_sharding(mesh, state_s), batch_sharding(mesh, batch_s))
        return Cell(arch, shape_name, "train", step, (state_s, batch_s),
                    in_sh, None, (0,), cfg, meta)

    params_s = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    # serving: model-parallel only (no FSDP) — see rules._param_spec
    p_sh = param_sharding(mesh, params_s, fsdp=False)

    if spec.kind == "prefill":
        from ..models import transformer

        def prefill_fn(params, batch):
            return transformer.prefill(
                cfg, params, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), compute_dtype=jnp.bfloat16)

        batch_s = _batch_struct(cfg, spec)
        batch_s.pop("labels")
        cache_s = jax.eval_shape(
            lambda: lm.init_cache(cfg, spec.global_batch, spec.seq_len,
                                  jnp.bfloat16))
        out_sh = (NamedSharding(mesh, P()), cache_sharding(mesh, cache_s))
        in_sh = (p_sh, batch_sharding(mesh, batch_s))
        return Cell(arch, shape_name, "prefill", prefill_fn,
                    (params_s, batch_s), in_sh, out_sh, (), cfg, meta)

    # decode: one token against a seq_len cache
    serve = lm.make_serve_step(cfg, compute_dtype=jnp.bfloat16)
    B = spec.global_batch
    cache_s = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, spec.seq_len, cache_dtype))
    c_sh = cache_sharding(mesh, cache_s)
    token_s = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = batch_sharding(mesh, token_s)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = (p_sh, c_sh, tok_sh, NamedSharding(mesh, P()))
    out_sh = (tok_sh, c_sh)
    return Cell(arch, shape_name, "decode", serve,
                (params_s, cache_s, token_s, pos_s), in_sh, out_sh, (1,),
                cfg, meta)


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    return jitted.lower(*cell.args)
