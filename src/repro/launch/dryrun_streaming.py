import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's OWN workload on the production mesh: the
device-tier Q5 step (keyed exchange via psum_scatter + pane accumulation +
window emission) and its ring-replication snapshot, lowered and compiled
for the 16x16 pod (and optionally 2x16x16).

    PYTHONPATH=src python -m repro.launch.dryrun_streaming [--multi-pod]
"""

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from ..compat import cost_analysis_dict
from ..streaming import StreamExecutor, StreamJobConfig, VectorWindowSpec
from .dryrun import OUT_DIR, collective_bytes
from .mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--keys", type=int, default=1 << 20)
    ap.add_argument("--batch", type=int, default=1 << 20)
    ap.add_argument("--exchange", default="reduce",
                    choices=["reduce", "route"])
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    n_chips = 512 if args.multi_pod else 256
    # paper-extreme Q5: 10 s window, 10 ms slide, 1M key buckets, 1M-event
    # global batches (≈ the paper's 1M events/second at one batch/second,
    # or 100x that at one batch per 10 ms slide)
    spec = VectorWindowSpec(size_ms=10_000, slide_ms=10,
                            n_key_buckets=args.keys,
                            max_windows_per_step=2, ring_margin=24)
    ex = StreamExecutor(StreamJobConfig(window=spec, batch_size=args.batch,
                                        exchange=args.exchange),
                        mesh=mesh)
    state_s = jax.eval_shape(ex.init_state)
    batch_s = {"ts": jax.ShapeDtypeStruct((args.batch,), jnp.int32),
               "key": jax.ShapeDtypeStruct((args.batch,), jnp.int32),
               "value": jax.ShapeDtypeStruct((args.batch,), jnp.float32),
               "valid": jax.ShapeDtypeStruct((args.batch,), bool),
               "wm": jax.ShapeDtypeStruct((), jnp.int32)}
    t0 = time.time()
    with mesh:
        lowered = jax.jit(ex._build_step(), donate_argnums=(0,)).lower(
            state_s, batch_s)
        compiled = lowered.compile()
        snap_lowered = jax.jit(ex._build_snapshot()).lower(state_s)
        snap_compiled = snap_lowered.compile()
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    snap_coll = collective_bytes(snap_compiled.as_text())
    result = {
        "arch": f"jet-q5-stream-{args.exchange}",
        "shape": f"b{args.batch}-k{args.keys}",
        "mesh": mesh_name, "chips": n_chips, "kind": "stream_step",
        "remat": "-", "tag": "paper-technique",
        "meta": {"window_ms": spec.size_ms, "slide_ms": spec.slide_ms,
                 "key_buckets": args.keys, "batch": args.batch},
        "lower_s": 0.0, "compile_s": round(time.time() - t0, 1),
        "memory": {"argument_bytes": mem.argument_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "alias_bytes": mem.alias_size_in_bytes},
        "flops": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "collective_bytes": coll,
        "snapshot_collective_bytes": snap_coll["total"],
        "hlo_bytes": len(compiled.as_text()),
    }
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out = OUT_DIR / f"jet-q5-stream-{args.exchange}__{mesh_name}.json"
    out.write_text(json.dumps(result, indent=1))
    print(f"[stream dryrun {mesh_name} {args.exchange}] "
          f"compile={result['compile_s']}s "
          f"flops/chip={result['flops']:.3e} "
          f"coll={coll['total'] / 1e6:.2f}MB "
          f"snapshot_coll={snap_coll['total'] / 1e6:.2f}MB "
          f"temp/chip={mem.temp_size_in_bytes / 2**20:.1f}MiB")


if __name__ == "__main__":
    main()
