import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape) cell on the production meshes and extract the roofline terms.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results (memory analysis, HLO flops/bytes, per-collective byte counts) are
appended to ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` — the
roofline table in EXPERIMENTS.md is generated from these files by
``benchmarks/roofline.py``.
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from ..compat import cost_analysis_dict
from ..configs import SHAPES, applicable_cells
from .mesh import make_production_mesh
from .specs import build_cell, lower_cell

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# HLO collective ops whose operand bytes count against the ICI roofline
COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?(\.\d+)?\s*=?\s")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape literal like f32[128,256]."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str, scan_multipliers=None) -> dict:
    """Sum result-shape bytes of every collective op, by kind.

    Uses the *result* shape of each collective instruction (for an
    all-reduce the result size equals the contribution moved per chip up to
    ring-algorithm constant factors; this is the standard dry-run proxy).

    CPU-backend caveat (documented in EXPERIMENTS.md): instructions inside
    a ``while`` (scan) body are counted ONCE here; the roofline script
    applies the statically-known trip counts (``scan_multipliers`` maps
    computation-name substrings to multipliers) when deriving per-step
    traffic.  We also report the per-computation breakdown so that
    correction is possible downstream.
    """
    per_kind = {}
    per_comp = {}
    # global multiline pass: tuple-result collectives (a multi-operand
    # all-to-all prints its tuple shape across several lines)
    pat = re.compile(
        r"%[\w\.\-]+\s*=\s*"
        r"(\([^()]*\)|[\w\[\],\s\{\}]+?)\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start|-done)?(?:\.\d+)?\(",
        re.DOTALL)
    for m in pat.finditer(hlo_text):
        shape_part, kind = m.groups()
        total = sum(_shape_bytes(s)
                    for s in re.findall(r"\w+\[[\d,]*\]", shape_part))
        per_kind[kind] = per_kind.get(kind, 0) + total
        # attribute to the nearest enclosing computation header above
        header = hlo_text.rfind("\n%", 0, m.start())
        comp = "entry"
        if header >= 0:
            hm = re.match(r"%([\w\.\-]+)", hlo_text[header + 1:header + 120])
            if hm and "=" not in hlo_text[header:header + 120].split("(")[0]:
                comp = hm.group(1)
        per_comp[comp] = per_comp.get(comp, 0) + total
    per_kind["total"] = sum(per_kind.values())
    per_kind["by_computation"] = per_comp
    return per_kind


def run_cell(arch: str, shape: str, multi_pod: bool,
             remat_policy: str = "nothing",
             tag: str = "", cache_int8: bool = False) -> dict:
    import jax.numpy as jnp
    from ..sharding import constraints
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, remat_policy=remat_policy,
                      cache_dtype=jnp.int8 if cache_int8 else jnp.bfloat16)
    constraints.set_mesh(mesh)
    try:
        with mesh:
            lowered = lower_cell(cell)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        constraints.set_mesh(None)
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips,
        "kind": cell.kind, "remat": remat_policy, "tag": tag,
        "meta": cell.static_meta,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "flops": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "collective_bytes": coll,
        "hlo_bytes": len(hlo),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="nothing",
                    choices=["nothing", "dots", "none"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = applicable_cells(args.arch)
        if args.shape:
            cells = [(a, s) for a, s in cells if s == args.shape]
    else:
        if not (args.arch and args.shape):
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            out = OUT_DIR / (f"{arch}__{shape}__{mesh_name}"
                             f"{'' if args.tag == 'baseline' else '__' + args.tag}.json")
            if args.skip_existing and out.exists():
                print(f"[skip] {out.name}")
                continue
            print(f"[dryrun] {arch} x {shape} on {mesh_name} "
                  f"(remat={args.remat}, tag={args.tag})", flush=True)
            try:
                res = run_cell(arch, shape, mp, args.remat, args.tag)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mesh_name, repr(e)))
                continue
            out.write_text(json.dumps(res, indent=1))
            print(f"  flops={res['flops']:.3e} "
                  f"bytes={res['bytes_accessed']:.3e} "
                  f"coll={res['collective_bytes']['total']:.3e} "
                  f"temp/dev={res['memory']['temp_bytes']/2**30:.2f}GiB "
                  f"compile={res['compile_s']}s", flush=True)
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
