"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def window_agg_ref(keys, slots, values, valid, n_key_buckets: int,
                   ring_len: int):
    """One-hot matmul formulation, evaluated directly in jnp."""
    vals = jnp.where(valid, values, 0.0).astype(jnp.float32)
    onehot_k = jax.nn.one_hot(jnp.where(valid, keys, -1), n_key_buckets,
                              dtype=jnp.float32)
    onehot_r = jax.nn.one_hot(jnp.where(valid, slots, -1), ring_len,
                              dtype=jnp.float32)
    return jnp.einsum("nk,nr->kr", onehot_k, onehot_r * vals[:, None])


def route_counts_ref(pids, valid, n_partitions: int):
    onehot = jax.nn.one_hot(jnp.where(valid, pids, -1), n_partitions,
                            dtype=jnp.int32)
    return jnp.sum(onehot, axis=0).astype(jnp.int32)


def decode_attention_ref(q, k, v, pos):
    """GQA decode: q (B,H,dh), k/v (B,Hk,S,dh), H = Hk*G; positions <= pos."""
    B, H, dh = q.shape
    Hk, S = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, dh)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    mask = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, dh)
