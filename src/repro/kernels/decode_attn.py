"""Pallas TPU kernel: flash-decode — single-token GQA attention against a
long KV cache with online softmax over KV chunks.

The serving hot loop (decode_32k / long_500k shapes): G query heads per kv
head attend to S cached keys.  The kernel is GQA-native — kv heads are a
grid dimension and the G grouped query rows ride together in one VMEM tile,
so the cache is never expanded (the jnp path's ``_expand_kv`` materializes
G copies; measured 2+ GiB/token at internlm scale before the sharding fix).
KV chunks are the minormost grid dim, carrying the running online-softmax
(max, denom, out) in VMEM scratch; scores of size S never materialize.

Layout: q (B, Hk, G, dh); k/v (B, Hk, S, dh) head-major so a chunk block
is a contiguous (CS, dh) VMEM tile; positions > pos are masked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
CS = 512          # kv chunk


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, CS: int):
    ct = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(ct == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (CS, dh)
    v = v_ref[0, 0].astype(jnp.float32)           # (CS, dh)
    pos = pos_ref[0]
    base = ct * CS
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, CS)
    idx = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx <= pos, s, NEG_INF)
    m_prev = m_ref[...]                           # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                        # (G, CS)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ct == n_chunks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, *, chunk: int = CS,
                     interpret: bool = True):
    """GQA flash-decode.

    q: (B, H, dh) with H = Hk * G;  k/v: (B, Hk, S, dh);  pos: scalar int32
    (attend to positions <= pos).  Returns (B, H, dh) f32.
    The dh**-0.5 scaling is applied here (on q, once)."""
    B, H, dh = q.shape
    Hk, S = k.shape[1], k.shape[2]
    assert H % Hk == 0, (H, Hk)
    G = H // Hk
    cs = min(chunk, S)
    assert S % cs == 0
    qg = (q * (dh ** -0.5)).reshape(B, Hk, G, dh).astype(q.dtype)
    pos_arr = jnp.full((1,), pos, jnp.int32)
    grid = (B, Hk, S // cs)
    out = pl.pallas_call(
        functools.partial(_kernel, CS=cs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (0,)),
            pl.BlockSpec((1, 1, G, dh), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, cs, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, cs, dh), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),      # running max
            pltpu.VMEM((G, 1), jnp.float32),      # running denom
            pltpu.VMEM((G, dh), jnp.float32),     # running out
        ],
        interpret=interpret,
    )(pos_arr, qg, k, v)
    return out.reshape(B, H, dh)
