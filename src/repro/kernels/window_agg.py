"""Pallas TPU kernel: keyed pane aggregation as one-hot matmuls.

The Jet stage-1 accumulate (events -> per-(key-bucket, frame-slot) partial
aggregates) is a scatter-add on CPU/GPU.  TPUs have no fast scatter; the
TPU-native formulation builds two one-hot matrices per event tile and
contracts them on the MXU:

    out[k, r] = sum_n onehot_k[n, k] * onehot_r[n, r] * value[n]
              = (onehot_k)^T @ (onehot_r * value[:, None])

Grid: (K / BK) key tiles x (N / BN) event tiles; the event dimension is
minormost so each key tile accumulates across event tiles in its output
block (revisited blocks stay resident in VMEM).  BK is a multiple of the
128-lane MXU width; R (the frame ring, <= ~32) rides along as the second
matmul dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK = 128     # key-bucket tile (MXU-aligned)
BN = 1024    # event tile


def _kernel(key_ref, slot_ref, val_ref, out_ref, *, R: int, BK: int):
    kt = pl.program_id(0)
    nt = pl.program_id(1)

    @pl.when(nt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = key_ref[...]                      # (BN,) int32
    slots = slot_ref[...]                    # (BN,) int32
    vals = val_ref[...]                      # (BN,) f32 (0 where invalid)

    k_base = kt * BK
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], BK), 1)
    onehot_k = jnp.where(keys[:, None] == k_base + k_iota, 1.0, 0.0
                         ).astype(jnp.float32)                # (BN, BK)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], R), 1)
    onehot_rv = jnp.where(slots[:, None] == r_iota, 1.0, 0.0
                          ).astype(jnp.float32) * vals[:, None]  # (BN, R)
    out_ref[...] += jax.lax.dot_general(
        onehot_k, onehot_rv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (BK, R)


def window_agg(keys, slots, values, valid, n_key_buckets: int, ring_len: int,
               block_k: int = BK, block_n: int = BN,
               interpret: bool = True):
    """keys/slots: (N,) int32; values/valid: (N,). Returns (K, R) f32.

    Non-tile-multiple shapes are handled by padding: the event axis pads
    with ``valid=False`` rows (value forced to 0 below, so they contribute
    nothing) and the key axis pads to the next tile multiple with buckets
    no event points at; the padded key rows are sliced off the result.
    """
    N = keys.shape[0]
    K, R = n_key_buckets, ring_len
    if N == 0:
        return jnp.zeros((K, R), jnp.float32)
    bn = min(block_n, N)
    bk = min(block_k, K)
    n_pad = (-N) % bn
    if n_pad:
        keys = jnp.concatenate([keys, jnp.zeros((n_pad,), keys.dtype)])
        slots = jnp.concatenate([slots, jnp.zeros((n_pad,), slots.dtype)])
        values = jnp.concatenate(
            [values, jnp.zeros((n_pad,), values.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((n_pad,), bool)])
        N += n_pad
    k_pad = (-K) % bk
    K_padded = K + k_pad
    vals = jnp.where(valid, values, 0.0).astype(jnp.float32)
    # out-of-range guard: invalid events point at a bucket that exists but
    # carry value 0, so they contribute nothing
    keys = jnp.where(valid, keys, 0).astype(jnp.int32)
    slots = jnp.where(valid, slots, 0).astype(jnp.int32)
    grid = (K_padded // bk, N // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, R=R, BK=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda kt, nt: (nt,)),
            pl.BlockSpec((bn,), lambda kt, nt: (nt,)),
            pl.BlockSpec((bn,), lambda kt, nt: (nt,)),
        ],
        out_specs=pl.BlockSpec((bk, R), lambda kt, nt: (kt, 0)),
        out_shape=jax.ShapeDtypeStruct((K_padded, R), jnp.float32),
        interpret=interpret,
    )(keys, slots, vals)
    return out[:K] if k_pad else out
