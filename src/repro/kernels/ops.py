"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and should be set
False on real TPU hardware; the flag is threaded through so the same call
sites serve both.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attn import decode_attention as _decode_attention
from .route import route_counts as _route_counts, route_offsets
from .window_agg import window_agg as _window_agg


@functools.partial(jax.jit,
                   static_argnames=("n_key_buckets", "ring_len",
                                    "interpret"))
def window_agg(keys, slots, values, valid, n_key_buckets: int,
               ring_len: int, interpret: bool = True):
    return _window_agg(keys, slots, values, valid, n_key_buckets, ring_len,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_partitions", "interpret"))
def route_counts(pids, valid, n_partitions: int, interpret: bool = True):
    return _route_counts(pids, valid, n_partitions, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, pos, interpret: bool = True):
    return _decode_attention(q, k, v, pos, interpret=interpret)
