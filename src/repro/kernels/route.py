"""Pallas TPU kernel: partition routing histogram.

Jet's exchange operator must know how many events go to each partition
before building the all-to-all (counting sort).  Histogramming is a
scatter-add on CPU; here it is the same one-hot reduction as window_agg
(matvec against ones) on the MXU:

    counts[p] = sum_n (pid[n] == p)

Grid: (P / BP) partition tiles x (N / BN) event tiles, event dim minormost
so each partition tile accumulates across event tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP = 128
BN = 2048


def _kernel(pid_ref, out_ref, *, BP: int):
    pt = pl.program_id(0)
    nt = pl.program_id(1)

    @pl.when(nt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pids = pid_ref[...]                                       # (BN,)
    base = pt * BP
    iota = jax.lax.broadcasted_iota(jnp.int32, (pids.shape[0], BP), 1)
    onehot = jnp.where(pids[:, None] == base + iota, 1.0, 0.0
                       ).astype(jnp.float32)                  # (BN, BP)
    out_ref[...] += jnp.sum(onehot, axis=0).astype(jnp.int32)


def route_counts(pids, valid, n_partitions: int,
                 block_p: int = BP, block_n: int = BN,
                 interpret: bool = True):
    """pids: (N,) int32 partition ids. Returns (P,) int32 counts."""
    N = pids.shape[0]
    P = n_partitions
    bn = min(block_n, N)
    bp = min(block_p, P)
    assert N % bn == 0 and P % bp == 0
    pids = jnp.where(valid, pids, -1).astype(jnp.int32)   # -1 matches nothing
    return pl.pallas_call(
        functools.partial(_kernel, BP=bp),
        grid=(P // bp, N // bn),
        in_specs=[pl.BlockSpec((bn,), lambda pt, nt: (nt,))],
        out_specs=pl.BlockSpec((bp,), lambda pt, nt: (pt,)),
        out_shape=jax.ShapeDtypeStruct((P,), jnp.int32),
        interpret=interpret,
    )(pids)


def route_offsets(pids, valid, n_partitions: int, **kw):
    """counts + exclusive-prefix offsets (the all-to-all send layout)."""
    counts = route_counts(pids, valid, n_partitions, **kw)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    return counts, offsets
