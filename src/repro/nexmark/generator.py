"""Deterministic NEXMark event generator (paper §7.1 configuration).

* 10,000 distinct keys for persons and auctions, drawn pseudo-randomly,
* configurable aggregate rate (events/second) — event time is the *ideal*
  emission instant ``ts_ms = seq * 1000 / rate``,
* the standard NEXMark mix: 1 person : 3 auctions : 46 bids per 50 events,
* pure function of ``seq`` -> replayable by construction.
"""

from __future__ import annotations

from typing import Any, Tuple

from .model import Auction, Bid, CITIES, Person, US_STATES

PERSON_PROPORTION = 1
AUCTION_PROPORTION = 3
BID_PROPORTION = 46
TOTAL_PROPORTION = PERSON_PROPORTION + AUCTION_PROPORTION + BID_PROPORTION


def _mix64(x: int) -> int:
    """splitmix64 finalizer: cheap deterministic pseudo-randomness."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


class NexmarkGenerator:
    """Callable ``gen(seq) -> (ts_ms, key, value)`` for the paced source."""

    def __init__(self, rate: float, n_keys: int = 10_000,
                 auction_filter_mod: int = 123):
        self.rate = rate
        self.n_keys = n_keys
        self.auction_filter_mod = auction_filter_mod

    def timestamp_ms(self, seq: int) -> int:
        return int(seq * 1000 / self.rate)

    def __call__(self, seq: int) -> Tuple[int, Any, Any]:
        ts = int(seq * 1000 / self.rate)
        # splitmix64 inlined: this is called once per generated event
        x = (seq + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        r = x ^ (x >> 31)
        slot = seq % TOTAL_PROPORTION
        if slot >= PERSON_PROPORTION + AUCTION_PROPORTION:
            # bids are 46/50 of the stream: branch for them first
            n = self.n_keys
            aid = r % n
            return ts, aid, Bid(aid, (r >> 16) % n,
                                100 + ((r >> 32) % 9900), ts)
        if slot < PERSON_PROPORTION:
            pid = r % self.n_keys
            v = Person(pid, f"person-{pid}", f"p{pid}@example.com",
                       CITIES[r % len(CITIES)],
                       US_STATES[(r >> 8) % len(US_STATES)], ts)
            return ts, pid, v
        aid = r % self.n_keys
        seller = (r >> 16) % self.n_keys
        v = Auction(aid, seller, (r >> 24) % 10, 100 + r % 900,
                    ts + 60_000, ts)
        return ts, aid, v


def fill_journal(journal, generator: NexmarkGenerator, n_events: int) -> None:
    """Pre-materialize events into a replayable journal (FT tests)."""
    for seq in range(n_events):
        ts, key, value = generator(seq)
        journal.append(ts, key, value)
