"""Deterministic NEXMark event generator (paper §7.1 configuration).

* 10,000 distinct keys for persons and auctions, drawn pseudo-randomly,
* configurable aggregate rate (events/second) — event time is the *ideal*
  emission instant ``ts_ms = seq * 1000 / rate``,
* the standard NEXMark mix: 1 person : 3 auctions : 46 bids per 50 events,
* pure function of ``seq`` -> replayable by construction.

Both generators expose a columnar form, ``gen_block(seqs) ->
EventBlock``: splitmix64 over a uint64 sequence vector produces the
identical (ts, key, value) triples as the scalar ``__call__``, with the
model objects materialized lazily (``payload_fn`` rebuilds the exact
object from the stored ``seq`` column only on the per-event fallback
path).  Blocks carry auxiliary columns ``kind`` (0 person / 1 auction /
2 bid), ``seq``, and ``bidder`` for vectorized stage functions.
"""

from __future__ import annotations

from typing import Any, Tuple

import numpy as np

from ..core.events import EventBlock
from .model import Auction, Bid, CITIES, Person, US_STATES

PERSON_PROPORTION = 1
AUCTION_PROPORTION = 3
BID_PROPORTION = 46
TOTAL_PROPORTION = PERSON_PROPORTION + AUCTION_PROPORTION + BID_PROPORTION

KIND_PERSON, KIND_AUCTION, KIND_BID = 0, 1, 2

_U64 = np.uint64
_MASK64 = _U64(0xFFFFFFFFFFFFFFFF)


def _mix64(x: int) -> int:
    """splitmix64 finalizer: cheap deterministic pseudo-randomness."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


def _mix64_vec(x: np.ndarray) -> np.ndarray:
    """splitmix64 over a uint64 vector (wrapping arithmetic is native)."""
    x = (x + _U64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)
    return x ^ (x >> _U64(31))


class _SeqMaterializer:
    """Picklable ``payload_fn``: rebuilds the model object of block row
    ``i`` from its ``seq`` column through the scalar generator.  A plain
    closure would pin blocks to one process; this travels over the
    multiprocess backend's shared-memory rings."""

    __slots__ = ("gen",)

    def __init__(self, gen: "NexmarkGenerator"):
        self.gen = gen

    def __call__(self, blk: EventBlock, i: int) -> Any:
        return self.gen(int(blk.cols["seq"][i]))[2]


class NexmarkGenerator:
    """Callable ``gen(seq) -> (ts_ms, key, value)`` for the paced source."""

    def __init__(self, rate: float, n_keys: int = 10_000,
                 auction_filter_mod: int = 123):
        self.rate = rate
        self.n_keys = n_keys
        self.auction_filter_mod = auction_filter_mod

    def timestamp_ms(self, seq: int) -> int:
        return int(seq * 1000 / self.rate)

    def __call__(self, seq: int) -> Tuple[int, Any, Any]:
        ts = int(seq * 1000 / self.rate)
        # splitmix64 inlined: this is called once per generated event
        x = (seq + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        r = x ^ (x >> 31)
        slot = seq % TOTAL_PROPORTION
        if slot >= PERSON_PROPORTION + AUCTION_PROPORTION:
            # bids are 46/50 of the stream: branch for them first
            n = self.n_keys
            aid = r % n
            return ts, aid, Bid(aid, (r >> 16) % n,
                                100 + ((r >> 32) % 9900), ts)
        if slot < PERSON_PROPORTION:
            pid = r % self.n_keys
            v = Person(pid, f"person-{pid}", f"p{pid}@example.com",
                       CITIES[r % len(CITIES)],
                       US_STATES[(r >> 8) % len(US_STATES)], ts)
            return ts, pid, v
        aid = r % self.n_keys
        seller = (r >> 16) % self.n_keys
        v = Auction(aid, seller, (r >> 24) % 10, 100 + r % 900,
                    ts + 60_000, ts)
        return ts, aid, v

    # -- columnar form --------------------------------------------------------
    def gen_block(self, seqs) -> EventBlock:
        """Vectorized ``__call__`` over a sequence vector.

        ``ts``/``key`` match the scalar triples exactly; the ``value``
        column is the bid price (auction reserve for auctions, 0 for
        persons) and the model object of row *i* is rebuilt on demand by
        ``payload_fn`` from the ``seq`` column — bit-identical to the
        scalar path because it IS the scalar path.
        """
        seqs = np.asarray(seqs, dtype=np.int64)
        # ts = int(seq * 1000 / rate): seq*1000 is float64-exact for any
        # realistic run length, so the double rounding matches Python's
        ts = (seqs.astype(np.float64) * 1000.0 / self.rate).astype(np.int64)
        r = _mix64_vec(seqs.astype(_U64))
        slot = seqs % TOTAL_PROPORTION
        kind = np.where(
            slot >= PERSON_PROPORTION + AUCTION_PROPORTION, KIND_BID,
            np.where(slot < PERSON_PROPORTION, KIND_PERSON,
                     KIND_AUCTION)).astype(np.int8)
        n_keys = _U64(self.n_keys)
        key = (r % n_keys).astype(np.int64)
        bidder = ((r >> _U64(16)) % n_keys).astype(np.int64)
        price = (_U64(100) + ((r >> _U64(32)) % _U64(9900))).astype(np.int64)
        reserve = (_U64(100) + (r % _U64(900))).astype(np.int64)
        value = np.where(kind == KIND_BID, price,
                         np.where(kind == KIND_AUCTION, reserve, 0)
                         ).astype(np.float64)
        return EventBlock(
            ts, key, value, payload_fn=_SeqMaterializer(self),
            cols={"kind": kind, "seq": seqs, "bidder": bidder})


class DisorderedNexmarkGenerator:
    """Bounded-shuffle wrapper: the same events as ``inner``, emitted out of
    timestamp order with event-time skew bounded by ``max_skew_ms``.

    The sequence axis is cut into blocks of ``floor(max_skew_ms * rate /
    1000)`` events (the floor is what keeps the within-block timestamp
    spread at or under ``max_skew_ms``); each block is emitted in a seeded
    permutation of itself — the argsort of a splitmix64 rank vector, so
    the whole permutation is ONE vectorized op on the columnar path and
    the identical order on the scalar path.  Timestamps travel WITH their
    event (an event is early/late relative to its ideal emission slot), so
    the disordered stream contains exactly the ordered stream's events —
    window results must match the ordered run whenever the watermark lag
    covers the skew.  Pure function of ``seq`` given ``seed``: replayable,
    deterministic, parallelism-agnostic.

    Note: the permutation is block-local, so a run truncated mid-block
    draws a few tail events from beyond the cut (and omits their swapped
    counterparts).  For exact ordered-vs-disordered multiset equality,
    size runs to a multiple of ``self.block`` events.
    """

    def __init__(self, inner: NexmarkGenerator, max_skew_ms: int,
                 seed: int = 0):
        if max_skew_ms < 0:
            raise ValueError("max_skew_ms must be >= 0")
        self.inner = inner
        self.rate = inner.rate
        self.n_keys = inner.n_keys
        self.max_skew_ms = max_skew_ms
        self.seed = seed
        # events whose ideal timestamps span <= max_skew_ms of event time;
        # within-block ts spread is (block-1) * 1000/rate <= max_skew_ms
        self.block = max(1, int(max_skew_ms * inner.rate / 1000))
        self._perm_cache: dict = {}

    def timestamp_ms(self, seq: int) -> int:
        return self.inner.timestamp_ms(self._mapped(seq))

    def _perm(self, block_idx: int) -> np.ndarray:
        perm = self._perm_cache.get(block_idx)
        if perm is not None:
            return perm
        n = self.block
        # rank vector: splitmix64 of (seed, block, position); argsort is
        # the permutation (stable, so equal ranks break by position)
        base = _U64((_mix64(self.seed * 0x9E3779B97F4A7C15 + block_idx)))
        ranks = _mix64_vec(base + np.arange(n, dtype=_U64))
        perm = np.argsort(ranks, kind="stable").astype(np.int64)
        if len(self._perm_cache) >= 8:
            # block access is near-sequential: keep a small window
            self._perm_cache.pop(min(self._perm_cache))
        self._perm_cache[block_idx] = perm
        return perm

    def _mapped(self, seq: int) -> int:
        b, off = divmod(seq, self.block)
        return b * self.block + int(self._perm(b)[off])

    def __getstate__(self):
        # the permutation cache is pure derived data (~KBs of argsorts);
        # recompute after unpickling rather than shipping it per block
        state = self.__dict__.copy()
        state["_perm_cache"] = {}
        return state

    def __call__(self, seq: int) -> Tuple[int, Any, Any]:
        return self.inner(self._mapped(seq))

    # -- columnar form --------------------------------------------------------
    def gen_block(self, seqs) -> EventBlock:
        """Vectorized bounded shuffle: map the sequence vector through the
        block-local permutations (one argsort per touched block, cached),
        then delegate to the inner generator's columnar form."""
        seqs = np.asarray(seqs, dtype=np.int64)
        bsz = self.block
        blocks, offs = np.divmod(seqs, bsz)
        mapped = np.empty_like(seqs)
        # a burst touches very few distinct blocks (they are skew-sized)
        uniq = np.unique(blocks)
        for b in uniq.tolist():
            sel = blocks == b
            mapped[sel] = b * bsz + self._perm(b)[offs[sel]]
        return self.inner.gen_block(mapped)


def fill_journal(journal, generator, n_events: int) -> None:
    """Pre-materialize events into a replayable journal (FT tests).
    ``generator`` is a Nexmark or DisorderedNexmark generator."""
    for seq in range(n_events):
        ts, key, value = generator(seq)
        journal.append(ts, key, value)
