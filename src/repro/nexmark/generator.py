"""Deterministic NEXMark event generator (paper §7.1 configuration).

* 10,000 distinct keys for persons and auctions, drawn pseudo-randomly,
* configurable aggregate rate (events/second) — event time is the *ideal*
  emission instant ``ts_ms = seq * 1000 / rate``,
* the standard NEXMark mix: 1 person : 3 auctions : 46 bids per 50 events,
* pure function of ``seq`` -> replayable by construction.
"""

from __future__ import annotations

from typing import Any, Tuple

from .model import Auction, Bid, CITIES, Person, US_STATES

PERSON_PROPORTION = 1
AUCTION_PROPORTION = 3
BID_PROPORTION = 46
TOTAL_PROPORTION = PERSON_PROPORTION + AUCTION_PROPORTION + BID_PROPORTION


def _mix64(x: int) -> int:
    """splitmix64 finalizer: cheap deterministic pseudo-randomness."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


class NexmarkGenerator:
    """Callable ``gen(seq) -> (ts_ms, key, value)`` for the paced source."""

    def __init__(self, rate: float, n_keys: int = 10_000,
                 auction_filter_mod: int = 123):
        self.rate = rate
        self.n_keys = n_keys
        self.auction_filter_mod = auction_filter_mod

    def timestamp_ms(self, seq: int) -> int:
        return int(seq * 1000 / self.rate)

    def __call__(self, seq: int) -> Tuple[int, Any, Any]:
        ts = int(seq * 1000 / self.rate)
        # splitmix64 inlined: this is called once per generated event
        x = (seq + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        r = x ^ (x >> 31)
        slot = seq % TOTAL_PROPORTION
        if slot >= PERSON_PROPORTION + AUCTION_PROPORTION:
            # bids are 46/50 of the stream: branch for them first
            n = self.n_keys
            aid = r % n
            return ts, aid, Bid(aid, (r >> 16) % n,
                                100 + ((r >> 32) % 9900), ts)
        if slot < PERSON_PROPORTION:
            pid = r % self.n_keys
            v = Person(pid, f"person-{pid}", f"p{pid}@example.com",
                       CITIES[r % len(CITIES)],
                       US_STATES[(r >> 8) % len(US_STATES)], ts)
            return ts, pid, v
        aid = r % self.n_keys
        seller = (r >> 16) % self.n_keys
        v = Auction(aid, seller, (r >> 24) % 10, 100 + r % 900,
                    ts + 60_000, ts)
        return ts, aid, v


class DisorderedNexmarkGenerator:
    """Bounded-shuffle wrapper: the same events as ``inner``, emitted out of
    timestamp order with event-time skew bounded by ``max_skew_ms``.

    The sequence axis is cut into blocks of ``floor(max_skew_ms * rate /
    1000)`` events (the floor is what keeps the within-block timestamp
    spread at or under ``max_skew_ms``); each block is emitted in a seeded
    Fisher-Yates permutation of itself.  Timestamps travel WITH their event (an event is
    early/late relative to its ideal emission slot), so the disordered
    stream contains exactly the ordered stream's events — window results
    must match the ordered run whenever the watermark lag covers the skew.
    Pure function of ``seq`` given ``seed``: replayable, deterministic,
    parallelism-agnostic.

    Note: the permutation is block-local, so a run truncated mid-block
    draws a few tail events from beyond the cut (and omits their swapped
    counterparts).  For exact ordered-vs-disordered multiset equality,
    size runs to a multiple of ``self.block`` events.
    """

    def __init__(self, inner: NexmarkGenerator, max_skew_ms: int,
                 seed: int = 0):
        if max_skew_ms < 0:
            raise ValueError("max_skew_ms must be >= 0")
        self.inner = inner
        self.rate = inner.rate
        self.n_keys = inner.n_keys
        self.max_skew_ms = max_skew_ms
        self.seed = seed
        # events whose ideal timestamps span <= max_skew_ms of event time;
        # within-block ts spread is (block-1) * 1000/rate <= max_skew_ms
        self.block = max(1, int(max_skew_ms * inner.rate / 1000))
        self._perm_cache: dict = {}

    def timestamp_ms(self, seq: int) -> int:
        return self.inner.timestamp_ms(self._mapped(seq))

    def _perm(self, block_idx: int):
        perm = self._perm_cache.get(block_idx)
        if perm is not None:
            return perm
        n = self.block
        perm = list(range(n))
        # Fisher-Yates driven by splitmix64 of (seed, block, step)
        base = _mix64(self.seed * 0x9E3779B97F4A7C15 + block_idx)
        for i in range(n - 1, 0, -1):
            j = _mix64(base + i) % (i + 1)
            perm[i], perm[j] = perm[j], perm[i]
        if len(self._perm_cache) >= 8:
            # block access is near-sequential: keep a small window
            self._perm_cache.pop(min(self._perm_cache))
        self._perm_cache[block_idx] = perm
        return perm

    def _mapped(self, seq: int) -> int:
        b, off = divmod(seq, self.block)
        return b * self.block + self._perm(b)[off]

    def __call__(self, seq: int) -> Tuple[int, Any, Any]:
        return self.inner(self._mapped(seq))


def fill_journal(journal, generator, n_events: int) -> None:
    """Pre-materialize events into a replayable journal (FT tests).
    ``generator`` is a Nexmark or DisorderedNexmark generator."""
    for seq in range(n_events):
        ts, key, value = generator(seq)
        journal.append(ts, key, value)
