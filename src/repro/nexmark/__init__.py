"""NEXMark benchmark workload (Tucker et al.) as used in the paper §7."""

from .model import Person, Auction, Bid
from .generator import NexmarkGenerator
from . import queries

__all__ = ["Person", "Auction", "Bid", "NexmarkGenerator", "queries"]
