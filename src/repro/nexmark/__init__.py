"""NEXMark benchmark workload (Tucker et al.) as used in the paper §7."""

from .model import Person, Auction, Bid
from .generator import DisorderedNexmarkGenerator, NexmarkGenerator
from . import queries

__all__ = ["Person", "Auction", "Bid", "DisorderedNexmarkGenerator",
           "NexmarkGenerator", "queries"]
