"""NEXMark queries on the Pipeline API.

The paper evaluates Q1, Q2, Q5, Q8 and Q13 (§7.1); Q3, Q4 and Q7 are
implemented as well to cover the benchmark's remaining patterns
(incremental two-sided join, join + windowed aggregate, global windowed
max).  Each builder takes source/sink suppliers and returns a
:class:`~repro.core.pipeline.Pipeline`; window parameters default to the
paper's extreme configuration (10 s window, 10 ms slide).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

from ..core.events import MIN_TIME, Event, Watermark, block_form
from ..core.pipeline import Pipeline
from ..core.processor import Inbox, Processor
from ..core.window import (AggregateOperation, averaging, co_aggregate,
                           counting, max_by, session, sliding, tumbling)
from .generator import KIND_BID
from .model import Auction, Bid, Person

USD_TO_EUR = 0.9


class IncrementalJoinProcessor(Processor):
    """Unwindowed streaming equi-join (NEXMark Q3's "incremental join"):
    both sides are retained per key; every arrival emits the new matches.
    Keyed + partitioned, state snapshotted for exactly-once."""

    def __init__(self, combine_fn: Callable, emit_left_once: bool = False):
        self.combine_fn = combine_fn
        self.left: Dict = {}        # key -> list (ordinal 0)
        self.right: Dict = {}       # key -> list (ordinal 1)

    def process(self, ordinal: int, inbox: Inbox) -> None:
        mine, other = ((self.left, self.right) if ordinal == 0
                       else (self.right, self.left))
        offer = self.outbox.offer
        while True:
            ev = inbox.peek()
            if ev is None:
                return
            matches = other.get(ev.key, ())
            ok = True
            for (mts, m) in matches:
                pair = (self.combine_fn(ev.value, m) if ordinal == 0
                        else self.combine_fn(m, ev.value))
                # emit at the LATER event time of the pair: arrival order
                # across the two sources is not ts-ordered, and downstream
                # windows key on the pair's completion time
                if not offer(Event(max(ev.ts, mts), ev.key, pair)):
                    ok = False
                    break
            if not ok:
                return
            # an unwindowed incremental join retains both sides forever by
            # definition (NEXMark Q3 semantics) — the retained state IS the
            # query's keyed state, snapshotted and partitioned above
            mine.setdefault(ev.key, []).append((ev.ts, ev.value))  # jetlint: disable=hot-path-unbounded-growth -- Q3's unwindowed join retains all history by definition; bounded by the benchmark's finite key domain
            inbox.remove()

    def save_to_snapshot(self) -> bool:
        # copy each side's match list: process() keeps appending to the
        # live lists between this barrier and the job-wide commit, and an
        # aliased payload would leak post-barrier matches into the
        # snapshot (restore extends, so a copy is contract-identical)
        for k, vs in self.left.items():
            self.outbox.offer_to_snapshot(("l", k), list(vs))
        for k, vs in self.right.items():
            self.outbox.offer_to_snapshot(("r", k), list(vs))
        return True

    def snapshot_partition(self, skey):
        from ..core.dag import PARTITION_COUNT
        return hash(skey[1]) % PARTITION_COUNT

    def restore_from_snapshot(self, items) -> None:
        for (side, k), vs in items:
            d = self.left if side == "l" else self.right
            d.setdefault(k, []).extend(vs)


def is_bid(v) -> bool:
    return isinstance(v, Bid)


# columnar forms over NEXMark generator blocks (the fusion planner lowers
# filter/rekey chains to these when the whole chain declares block forms;
# any other block shape explodes to events first, so these only ever see
# blocks carrying the generator's aux columns)
block_form(is_bid, lambda blk: blk.cols["kind"] == KIND_BID)

#: grouping key of a bid stream by auction — the generator's key column
#: already IS the auction id for bid rows
bid_auction = block_form(lambda b: b.auction, lambda blk: blk.key)

#: grouping key by bidder (NEXMark Q11's session key)
bid_bidder = block_form(lambda b: b.bidder, lambda blk: blk.cols["bidder"])

#: vectorized price getter for summing-style aggregates over bid streams
#: (scalar form reads the Event, like every AggregateOperation getter)
bid_price = block_form(lambda ev: ev.value.price, lambda blk: blk.value)


# ---------------------------------------------------------------------------
# Q1 — currency conversion (map)
# ---------------------------------------------------------------------------

def q1(source, sink) -> Pipeline:
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .map(lambda b: Bid(b.auction, b.bidder, int(b.price * USD_TO_EUR),
                           b.ts))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q2 — selection (filter on auction id)
# ---------------------------------------------------------------------------

def q2(source, sink, mod: int = 123) -> Pipeline:
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .filter(lambda b: b.auction % mod == 0)
        .map(lambda b: (b.auction, b.price))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q5 — hot items: auctions with the most bids in a sliding window
# ---------------------------------------------------------------------------

class MaxPerWindowProcessor(Processor):
    """Selects the max-count auction for each window_end (second level of
    Q5).  Keyed by window_end via a distributed partitioned edge."""

    def __init__(self):
        self.best: Dict[int, tuple] = {}

    def process(self, ordinal: int, inbox: Inbox) -> None:
        best = self.best
        get = best.get
        for ev in inbox:
            wr = ev.value
            cur = get(wr.window_end)
            if cur is None or wr.value > cur[1]:
                best[wr.window_end] = (wr.key, wr.value)
        inbox.clear()

    def try_process_watermark(self, wm: Watermark) -> bool:
        # strict: a result carries ts == w - 1 and items with ts == wm may
        # still arrive after the watermark
        ready = sorted(w for w in self.best if w - 1 < wm.ts)
        for w in ready:
            key, count = self.best[w]
            if not self.outbox.offer(Event(w - 1, key, (w, key, count))):
                return False
            del self.best[w]
        return True

    def complete(self) -> bool:
        for w in sorted(self.best):
            key, count = self.best[w]
            if not self.outbox.offer(Event(w - 1, key, (w, key, count))):
                return False
            del self.best[w]
        return True

    def save_to_snapshot(self) -> bool:
        for w, v in self.best.items():
            self.outbox.offer_to_snapshot(("best", w), v)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (tag, w), v in items:
            if tag == "best":
                cur = self.best.get(w)
                if cur is None or v[1] > cur[1]:
                    self.best[w] = v


def q5(source, sink, window_ms: int = 10_000, slide_ms: int = 10,
       with_global_max: bool = False, placement: str = "host",
       device: Optional[Dict] = None) -> Pipeline:
    """Count bids per auction over a sliding window.

    ``with_global_max`` adds the "auction with most bids" second level; the
    paper's latency clock stops at window-result emission, so benchmarks use
    the two-stage aggregate output directly (the default).

    ``placement="device"`` swaps the host two-stage plan for the
    device-offloaded window vertex (EventBlocks pack into padded device
    batches, the compiled StreamExecutor aggregates) — same WindowResult
    stream, devices doing the math.  ``device`` forwards processor
    overrides; size ``n_key_buckets`` at or above the auction key space
    for per-auction-exact results.
    """
    p = Pipeline.create()
    counts = (p.read_from(source, name="bids")
                .filter(is_bid)
                .with_key(bid_auction)
                .window(sliding(window_ms, slide_ms))
                .aggregate(counting(), placement=placement, device=device))
    if with_global_max:
        (counts
            .rekey(lambda wr: wr.window_end)
            .custom_transform("max-per-window", MaxPerWindowProcessor,
                              partitioned=True, distributed=True)
            .write_to(sink))
    else:
        counts.write_to(sink)
    return p


# ---------------------------------------------------------------------------
# Q3 — who is selling in particular US states? (incremental join)
# ---------------------------------------------------------------------------

def q3(person_source, auction_source, sink,
       states=("OR", "ID", "CA"), category: int = 0) -> Pipeline:
    p = Pipeline.create()
    persons = (p.read_from(person_source, name="persons")
                 .filter(lambda v: isinstance(v, Person)
                         and v.state in states)
                 .rekey(lambda v: v.id))
    auctions = (p.read_from(auction_source, name="auctions")
                  .filter(lambda v: isinstance(v, Auction)
                          and v.category == category)
                  .rekey(lambda v: v.seller))
    join = _two_input_join(
        p, persons, auctions,
        lambda person, auction: (person.name, person.city, person.state,
                                 auction.id))
    join.write_to(sink)
    return p


def _two_input_join(p, left, right, combine_fn):
    """Wire a partitioned two-input IncrementalJoinProcessor (the planner
    lowers the "custom2" stage onto two distributed partitioned edges)."""
    from ..core.pipeline import GeneralStage, _Stage

    st = _Stage(p, "custom2", "inc_join", [left.stage, right.stage],
                {"supplier": lambda: IncrementalJoinProcessor(combine_fn)})
    return GeneralStage(p, st)


# ---------------------------------------------------------------------------
# Q4 — average closing price per category (join + windowed aggregate)
# ---------------------------------------------------------------------------

def q4(auction_source, bid_source, sink, window_ms: int = 10_000) -> Pipeline:
    """Join bids to their auction's category, then average the price per
    category over a tumbling window (the Beam simplification of Q4)."""
    p = Pipeline.create()
    auctions = (p.read_from(auction_source, name="auctions")
                  .filter(lambda v: isinstance(v, Auction))
                  .rekey(lambda v: v.id))
    bids = (p.read_from(bid_source, name="bids")
              .filter(is_bid)
              .rekey(lambda v: v.auction))
    joined = _two_input_join(p, auctions, bids,
                             lambda auction, bid: (auction.category,
                                                   bid.price))
    (joined
        .with_key(lambda cp: cp[0])
        .window(tumbling(window_ms))
        .aggregate(averaging(lambda ev: ev.value[1]))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q7 — highest bid per period (global tumbling max)
# ---------------------------------------------------------------------------

def q7(source, sink, window_ms: int = 10_000) -> Pipeline:
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .with_key(lambda b: 0)                 # global key
        .window(tumbling(window_ms))
        .aggregate(max_by(lambda ev: ev.value.price))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q8 — monitor new users: persons who created auctions in the last period
# ---------------------------------------------------------------------------

def q8(person_source, auction_source, sink,
       window_ms: int = 10_000, slide_ms: int = 10) -> Pipeline:
    """Windowed join of new persons with their new auctions (co-aggregate:
    both sides collected per key per window, joined at export)."""
    def export_join(acc):
        persons, auctions = acc
        if persons and auctions:
            return (persons[0], list(auctions))
        return None

    op = co_aggregate(left=lambda ev: ev, right=lambda ev: ev)
    join_op = AggregateOperation(
        create=op.create, accumulate_fns=op.accumulate_fns,
        combine=op.combine, deduct=None, export=export_join)

    p = Pipeline.create()
    persons = (p.read_from(person_source, name="persons")
                 .filter(lambda v: isinstance(v, Person))
                 .with_key(lambda v: v.id))
    auctions = (p.read_from(auction_source, name="auctions")
                  .filter(lambda v: isinstance(v, Auction))
                  .with_key(lambda v: v.seller))
    (persons.window(sliding(window_ms, slide_ms))
        .aggregate2(auctions, join_op)
        .filter(lambda wr: wr.value is not None)
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q11 — bids per user session (gap-based session windows)
# ---------------------------------------------------------------------------

def q11(source, sink, gap_ms: int = 10_000, allowed_lateness: int = 0,
        late_sink=None) -> Pipeline:
    """How many bids did each user make in each of their active sessions?
    The event-time-completeness showcase: session windows + allowed
    lateness + optional late-event side output, correct under disorder."""
    p = Pipeline.create()
    win = (p.read_from(source, name="bids")
             .filter(is_bid)
             .with_key(bid_bidder)
             .window(session(gap_ms))
             .allowed_lateness(allowed_lateness))
    if late_sink is not None:
        win = win.late_sink(late_sink)
    win.aggregate(counting()).write_to(sink)
    return p


# ---------------------------------------------------------------------------
# Q12 — bids per bidder per processing-time window
# ---------------------------------------------------------------------------

class ProcessingTimeWindowProcessor(Processor):
    """Tumbling *processing-time* window: frames are labelled by the
    cluster clock at ARRIVAL, so disorder in event time is irrelevant by
    construction (NEXMark Q12's defining property).  Emission is driven by
    the clock — checked whenever data or a watermark arrives — rather than
    by event-time watermarks."""

    #: frames ARE snapshotted, but restore routes them into the
    #: _restored epoch buffer (previous-clock-epoch frames flush as-is
    #: via finish_snapshot_restore, never merged with new-epoch frames),
    #: which the reference scan cannot see as a restore of ``frames``
    SNAPSHOT_STATE = frozenset({"frames"})
    #: _t0 anchors processing time and re-anchors after a restart by
    #: definition of processing time; _emit is flushed before barriers
    EPHEMERAL_STATE = frozenset({"_t0", "_emit"})

    def __init__(self, size_ms: int, op: AggregateOperation):
        from collections import deque
        self.size_ms = size_ms
        self.op = op
        self.frames: Dict = {}          # (key, frame_end_ms) -> acc
        self._t0: Optional[float] = None
        self._emit = deque()
        # frames from a restored snapshot (previous clock epoch); flushed
        # as-is by finish_snapshot_restore, never merged with new frames
        self._restored: Dict = {}

    def _now_ms(self) -> int:
        if self._t0 is None:
            self._t0 = self.ctx.clock.now()
        return int((self.ctx.clock.now() - self._t0) * 1000)

    def process(self, ordinal: int, inbox: Inbox) -> None:
        op, frames = self.op, self.frames
        acc_fn, create = op.accumulate, op.create
        size = self.size_ms
        fend = (self._now_ms() // size + 1) * size
        get = frames.get
        for ev in inbox:
            fkey = (ev.key, fend)
            acc = get(fkey)
            frames[fkey] = acc_fn(create() if acc is None else acc, ev)
        inbox.clear()
        self._emit_due()

    def _emit_due(self) -> None:
        now = self._now_ms()
        due = [kf for kf in self.frames if kf[1] <= now]
        due.sort(key=lambda kf: kf[1])
        export = self.op.export
        for key, fend in due:
            self._emit.append(
                Event(fend - 1, key,
                      (fend, key, export(self.frames.pop((key, fend))))))
        self._flush()

    def _flush(self) -> bool:
        while self._emit:
            if not self.outbox.offer(self._emit[0]):
                return False
            self._emit.popleft()
        return True

    def try_process_watermark(self, wm: Watermark) -> bool:
        # watermarks only serve as a liveness tick for the clock check
        self._emit_due()
        return self._flush()

    def complete(self) -> bool:
        # frames move into the emit queue unconditionally (popped as they
        # go, so re-calls under backpressure are safe); gating on a drained
        # queue would lose the final window of every key
        export = self.op.export
        for key, fend in sorted(self.frames, key=lambda kf: kf[1]):
            self._emit.append(
                Event(fend - 1, key,
                      (fend, key, export(self.frames.pop((key, fend))))))
        return self._flush()

    def save_to_snapshot(self) -> bool:
        # pre-barrier results stuck behind backpressure leave first
        if not self._flush():
            return False
        for (key, fend), acc in self.frames.items():
            self.outbox.offer_to_snapshot((key, fend), acc)
        return True

    def restore_from_snapshot(self, items) -> None:
        combine = self.op.combine
        for (key, fend), acc in items:
            cur = self._restored.get((key, fend))
            self._restored[(key, fend)] = (acc if cur is None
                                           else combine(cur, acc))

    def finish_snapshot_restore(self) -> None:
        # frame labels are epoch-relative (clock restarts at 0 after a
        # restore), so restored frames must NOT merge with the new epoch's
        # frames of the same label: their processing-time interval ended
        # with the old epoch — emit them immediately instead
        export = self.op.export
        for key, fend in sorted(self._restored, key=lambda kf: kf[1]):
            self._emit.append(
                Event(fend - 1, key,
                      (fend, key, export(self._restored.pop((key, fend))))))

    def snapshot_partition(self, skey):
        from ..core.dag import PARTITION_COUNT
        return hash(skey[0]) % PARTITION_COUNT


def q12(source, sink, window_ms: int = 10_000) -> Pipeline:
    """How many bids does each user make within a fixed *processing-time*
    window? (Uses the cluster clock, not event timestamps.)"""
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .with_key(lambda b: b.bidder)
        .custom_transform(
            "q12-ptime-window",
            lambda: ProcessingTimeWindowProcessor(window_ms, counting()),
            partitioned=True, distributed=True)
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q13 — bounded side-input join
# ---------------------------------------------------------------------------

def q13(bid_source, side_input_source, sink) -> Pipeline:
    """Enrich the bid stream against a bounded (batch) auction side input
    via a replicated hash join (Listing 2 pattern)."""
    p = Pipeline.create()
    side = (p.read_from(side_input_source, name="side-input")
              .filter(lambda v: isinstance(v, Auction)))
    (p.read_from(bid_source, name="bids")
        .filter(is_bid)
        .hash_join(side,
                   probe_key_fn=lambda b: b.auction,
                   build_key_fn=lambda a: a.id,
                   combine_fn=lambda b, a: (b, a))
        .write_to(sink))
    return p
