"""NEXMark queries on the Pipeline API.

The paper evaluates Q1, Q2, Q5, Q8 and Q13 (§7.1); Q3, Q4 and Q7 are
implemented as well to cover the benchmark's remaining patterns
(incremental two-sided join, join + windowed aggregate, global windowed
max).  Each builder takes source/sink suppliers and returns a
:class:`~repro.core.pipeline.Pipeline`; window parameters default to the
paper's extreme configuration (10 s window, 10 ms slide).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

from ..core.events import MIN_TIME, Event, Watermark
from ..core.pipeline import Pipeline
from ..core.processor import Inbox, Processor
from ..core.window import (AggregateOperation, averaging, co_aggregate,
                           counting, max_by, sliding, tumbling)
from .model import Auction, Bid, Person

USD_TO_EUR = 0.9


class IncrementalJoinProcessor(Processor):
    """Unwindowed streaming equi-join (NEXMark Q3's "incremental join"):
    both sides are retained per key; every arrival emits the new matches.
    Keyed + partitioned, state snapshotted for exactly-once."""

    def __init__(self, combine_fn: Callable, emit_left_once: bool = False):
        self.combine_fn = combine_fn
        self.left: Dict = {}        # key -> list (ordinal 0)
        self.right: Dict = {}       # key -> list (ordinal 1)

    def process(self, ordinal: int, inbox: Inbox) -> None:
        mine, other = ((self.left, self.right) if ordinal == 0
                       else (self.right, self.left))
        offer = self.outbox.offer
        while True:
            ev = inbox.peek()
            if ev is None:
                return
            matches = other.get(ev.key, ())
            ok = True
            for (mts, m) in matches:
                pair = (self.combine_fn(ev.value, m) if ordinal == 0
                        else self.combine_fn(m, ev.value))
                # emit at the LATER event time of the pair: arrival order
                # across the two sources is not ts-ordered, and downstream
                # windows key on the pair's completion time
                if not offer(Event(max(ev.ts, mts), ev.key, pair)):
                    ok = False
                    break
            if not ok:
                return
            mine.setdefault(ev.key, []).append((ev.ts, ev.value))
            inbox.remove()

    def save_to_snapshot(self) -> bool:
        for k, vs in self.left.items():
            self.outbox.offer_to_snapshot(("l", k), vs)
        for k, vs in self.right.items():
            self.outbox.offer_to_snapshot(("r", k), vs)
        return True

    def snapshot_partition(self, skey):
        from ..core.dag import PARTITION_COUNT
        return hash(skey[1]) % PARTITION_COUNT

    def restore_from_snapshot(self, items) -> None:
        for (side, k), vs in items:
            d = self.left if side == "l" else self.right
            d.setdefault(k, []).extend(vs)


def is_bid(v) -> bool:
    return isinstance(v, Bid)


# ---------------------------------------------------------------------------
# Q1 — currency conversion (map)
# ---------------------------------------------------------------------------

def q1(source, sink) -> Pipeline:
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .map(lambda b: Bid(b.auction, b.bidder, int(b.price * USD_TO_EUR),
                           b.ts))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q2 — selection (filter on auction id)
# ---------------------------------------------------------------------------

def q2(source, sink, mod: int = 123) -> Pipeline:
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .filter(lambda b: b.auction % mod == 0)
        .map(lambda b: (b.auction, b.price))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q5 — hot items: auctions with the most bids in a sliding window
# ---------------------------------------------------------------------------

class MaxPerWindowProcessor(Processor):
    """Selects the max-count auction for each window_end (second level of
    Q5).  Keyed by window_end via a distributed partitioned edge."""

    def __init__(self):
        self.best: Dict[int, tuple] = {}

    def process(self, ordinal: int, inbox: Inbox) -> None:
        best = self.best
        get = best.get
        for ev in inbox:
            wr = ev.value
            cur = get(wr.window_end)
            if cur is None or wr.value > cur[1]:
                best[wr.window_end] = (wr.key, wr.value)
        inbox.clear()

    def try_process_watermark(self, wm: Watermark) -> bool:
        # strict: a result carries ts == w - 1 and items with ts == wm may
        # still arrive after the watermark
        ready = sorted(w for w in self.best if w - 1 < wm.ts)
        for w in ready:
            key, count = self.best[w]
            if not self.outbox.offer(Event(w - 1, key, (w, key, count))):
                return False
            del self.best[w]
        return True

    def complete(self) -> bool:
        for w in sorted(self.best):
            key, count = self.best[w]
            if not self.outbox.offer(Event(w - 1, key, (w, key, count))):
                return False
            del self.best[w]
        return True

    def save_to_snapshot(self) -> bool:
        for w, v in self.best.items():
            self.outbox.offer_to_snapshot(("best", w), v)
        return True

    def restore_from_snapshot(self, items) -> None:
        for (tag, w), v in items:
            if tag == "best":
                cur = self.best.get(w)
                if cur is None or v[1] > cur[1]:
                    self.best[w] = v


def q5(source, sink, window_ms: int = 10_000, slide_ms: int = 10,
       with_global_max: bool = False) -> Pipeline:
    """Count bids per auction over a sliding window.

    ``with_global_max`` adds the "auction with most bids" second level; the
    paper's latency clock stops at window-result emission, so benchmarks use
    the two-stage aggregate output directly (the default).
    """
    p = Pipeline.create()
    counts = (p.read_from(source, name="bids")
                .filter(is_bid)
                .with_key(lambda b: b.auction)
                .window(sliding(window_ms, slide_ms))
                .aggregate(counting()))
    if with_global_max:
        (counts
            .rekey(lambda wr: wr.window_end)
            .custom_transform("max-per-window", MaxPerWindowProcessor,
                              partitioned=True, distributed=True)
            .write_to(sink))
    else:
        counts.write_to(sink)
    return p


# ---------------------------------------------------------------------------
# Q3 — who is selling in particular US states? (incremental join)
# ---------------------------------------------------------------------------

def q3(person_source, auction_source, sink,
       states=("OR", "ID", "CA"), category: int = 0) -> Pipeline:
    p = Pipeline.create()
    persons = (p.read_from(person_source, name="persons")
                 .filter(lambda v: isinstance(v, Person)
                         and v.state in states)
                 .rekey(lambda v: v.id))
    auctions = (p.read_from(auction_source, name="auctions")
                  .filter(lambda v: isinstance(v, Auction)
                          and v.category == category)
                  .rekey(lambda v: v.seller))
    join = _two_input_join(
        p, persons, auctions,
        lambda person, auction: (person.name, person.city, person.state,
                                 auction.id))
    join.write_to(sink)
    return p


def _two_input_join(p, left, right, combine_fn):
    """Wire a partitioned two-input IncrementalJoinProcessor (the planner
    lowers the "custom2" stage onto two distributed partitioned edges)."""
    from ..core.pipeline import GeneralStage, _Stage

    st = _Stage(p, "custom2", "inc_join", [left.stage, right.stage],
                {"supplier": lambda: IncrementalJoinProcessor(combine_fn)})
    return GeneralStage(p, st)


# ---------------------------------------------------------------------------
# Q4 — average closing price per category (join + windowed aggregate)
# ---------------------------------------------------------------------------

def q4(auction_source, bid_source, sink, window_ms: int = 10_000) -> Pipeline:
    """Join bids to their auction's category, then average the price per
    category over a tumbling window (the Beam simplification of Q4)."""
    p = Pipeline.create()
    auctions = (p.read_from(auction_source, name="auctions")
                  .filter(lambda v: isinstance(v, Auction))
                  .rekey(lambda v: v.id))
    bids = (p.read_from(bid_source, name="bids")
              .filter(is_bid)
              .rekey(lambda v: v.auction))
    joined = _two_input_join(p, auctions, bids,
                             lambda auction, bid: (auction.category,
                                                   bid.price))
    (joined
        .with_key(lambda cp: cp[0])
        .window(tumbling(window_ms))
        .aggregate(averaging(lambda ev: ev.value[1]))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q7 — highest bid per period (global tumbling max)
# ---------------------------------------------------------------------------

def q7(source, sink, window_ms: int = 10_000) -> Pipeline:
    p = Pipeline.create()
    (p.read_from(source, name="bids")
        .filter(is_bid)
        .with_key(lambda b: 0)                 # global key
        .window(tumbling(window_ms))
        .aggregate(max_by(lambda ev: ev.value.price))
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q8 — monitor new users: persons who created auctions in the last period
# ---------------------------------------------------------------------------

def q8(person_source, auction_source, sink,
       window_ms: int = 10_000, slide_ms: int = 10) -> Pipeline:
    """Windowed join of new persons with their new auctions (co-aggregate:
    both sides collected per key per window, joined at export)."""
    def export_join(acc):
        persons, auctions = acc
        if persons and auctions:
            return (persons[0], list(auctions))
        return None

    op = co_aggregate(left=lambda ev: ev, right=lambda ev: ev)
    join_op = AggregateOperation(
        create=op.create, accumulate_fns=op.accumulate_fns,
        combine=op.combine, deduct=None, export=export_join)

    p = Pipeline.create()
    persons = (p.read_from(person_source, name="persons")
                 .filter(lambda v: isinstance(v, Person))
                 .with_key(lambda v: v.id))
    auctions = (p.read_from(auction_source, name="auctions")
                  .filter(lambda v: isinstance(v, Auction))
                  .with_key(lambda v: v.seller))
    (persons.window(sliding(window_ms, slide_ms))
        .aggregate2(auctions, join_op)
        .filter(lambda wr: wr.value is not None)
        .write_to(sink))
    return p


# ---------------------------------------------------------------------------
# Q13 — bounded side-input join
# ---------------------------------------------------------------------------

def q13(bid_source, side_input_source, sink) -> Pipeline:
    """Enrich the bid stream against a bounded (batch) auction side input
    via a replicated hash join (Listing 2 pattern)."""
    p = Pipeline.create()
    side = (p.read_from(side_input_source, name="side-input")
              .filter(lambda v: isinstance(v, Auction)))
    (p.read_from(bid_source, name="bids")
        .filter(is_bid)
        .hash_join(side,
                   probe_key_fn=lambda b: b.auction,
                   build_key_fn=lambda a: a.id,
                   combine_fn=lambda b, a: (b, a))
        .write_to(sink))
    return p
