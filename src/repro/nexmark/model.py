"""NEXMark entities: people auctioning items and bidding on them."""

from __future__ import annotations

US_STATES = ("AZ", "CA", "ID", "OR", "WA", "WY")
CITIES = ("Phoenix", "Los Angeles", "San Francisco", "Boise", "Portland",
          "Seattle", "Cheyenne")


class Person:
    __slots__ = ("id", "name", "email", "city", "state", "ts")

    def __init__(self, id: int, name: str, email: str, city: str, state: str,
                 ts: int):
        self.id = id
        self.name = name
        self.email = email
        self.city = city
        self.state = state
        self.ts = ts

    def __repr__(self):  # pragma: no cover
        return f"Person({self.id}, {self.state})"


class Auction:
    __slots__ = ("id", "seller", "category", "initial_bid", "expires", "ts")

    def __init__(self, id: int, seller: int, category: int, initial_bid: int,
                 expires: int, ts: int):
        self.id = id
        self.seller = seller
        self.category = category
        self.initial_bid = initial_bid
        self.expires = expires
        self.ts = ts

    def __repr__(self):  # pragma: no cover
        return f"Auction({self.id}, seller={self.seller})"


class Bid:
    __slots__ = ("auction", "bidder", "price", "ts")

    def __init__(self, auction: int, bidder: int, price: int, ts: int):
        self.auction = auction
        self.bidder = bidder
        self.price = price
        self.ts = ts

    def __repr__(self):  # pragma: no cover
        return f"Bid(a={self.auction}, p={self.price})"
