"""Active-active deployment (paper §4.6): run the job twice, one active
and one hot standby, and deduplicate outputs by record id — trading the
snapshot protocol's latency for 2x resources.

The two replicas are independent JetClusters fed by the same replayable
source; outputs merge through :class:`DedupingOutput`, which keeps the
first result per record id (results are deterministic, so either replica's
answer is THE answer).  On primary failure the standby simply keeps
emitting — zero recovery gap, no snapshot restore.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

from ..core import JetCluster, JobConfig
from ..core.engine import JOB_COMPLETED


class DedupingOutput:
    """First-wins merge of the two replicas' outputs by record id."""

    def __init__(self, id_fn: Callable):
        self.id_fn = id_fn
        self.results: Dict = {}
        self.duplicates = 0

    def sink_for_replica(self, replica: int):
        def consume(ev):
            rid = self.id_fn(ev)
            if rid in self.results:
                self.duplicates += 1
            else:
                self.results[rid] = (replica, ev)
        return consume


class ActiveActiveRunner:
    def __init__(self, build_pipeline: Callable[[Callable], object],
                 id_fn: Callable, n_nodes: int = 2,
                 cooperative_threads: int = 2, clock_factory=None):
        """``build_pipeline(sink_consumer) -> Pipeline``."""
        self.output = DedupingOutput(id_fn)
        self.clusters: List[JetCluster] = []
        self.jobs = []
        for replica in range(2):
            clock = clock_factory() if clock_factory else None
            cluster = JetCluster(n_nodes=n_nodes,
                                 cooperative_threads=cooperative_threads,
                                 clock=clock)
            p = build_pipeline(self.output.sink_for_replica(replica))
            # §4.6: no snapshot bookkeeping at all in active-active mode
            job = cluster.submit(p.to_dag(), JobConfig())
            self.clusters.append(cluster)
            self.jobs.append(job)
        self.failed: Optional[int] = None

    def step(self) -> None:
        for i, cluster in enumerate(self.clusters):
            if self.failed == i:
                continue
            cluster.step()

    def kill_replica(self, replica: int) -> None:
        """Simulate a whole-replica loss: the other keeps serving."""
        self.failed = replica

    def run_until_complete(self, max_steps: int = 2_000_000) -> None:
        for _ in range(max_steps):
            done = [j.status == JOB_COMPLETED
                    for i, j in enumerate(self.jobs) if i != self.failed]
            if done and all(done):
                return
            self.step()
        raise TimeoutError("active-active run did not complete")
