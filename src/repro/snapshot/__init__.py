"""Exactly-once delivery endpoints (paper §4.5): transactional and
idempotent sinks, active-active deployment helper."""

from .sinks import (ExternalCollector, IdempotentSink,
                    TransactionalSink)
from .active_active import ActiveActiveRunner

__all__ = ["ExternalCollector", "IdempotentSink",
           "TransactionalSink", "ActiveActiveRunner"]
