"""Exactly-once delivery sinks (paper §4.5).

The snapshot protocol makes *state* effects exactly-once; making *output*
exactly-once needs the sink's cooperation:

* :class:`TransactionalSink` — two-phase commit: output buffers in a
  pending transaction per snapshot epoch; ``save_to_snapshot`` persists the
  pending buffer (commit-prepare), and the epoch is released to the
  external system only when the engine reports the snapshot committed.
  After a crash the restored pending buffer is re-committed — the external
  system sees each result exactly once (duplicates are fenced by the
  epoch id).
* :class:`IdempotentSink` — keyed writes: re-emission after replay
  overwrites the same key with the same value; the externally visible map
  converges to exactly the no-failure outcome.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.events import Event
from ..core.processor import Inbox, Processor


class ExternalCollector:
    """Stands in for the external system (e.g. a DB)."""

    def __init__(self):
        self.committed: List[Tuple[int, Any]] = []   # (epoch, value)
        self.kv: Dict[Any, Any] = {}
        self._epochs_seen: set = set()

    # transactional API
    def commit_epoch(self, epoch: int, items: List[Any]) -> None:
        if epoch in self._epochs_seen:     # fencing: re-commit is a no-op
            return
        self._epochs_seen.add(epoch)
        self.committed.extend((epoch, it) for it in items)

    # idempotent API
    def upsert(self, key, value) -> None:
        self.kv[key] = value


class TransactionalSink(Processor):
    """Buffers output per snapshot epoch; releases on snapshot commit.

    Transaction ids are STABLE across crashes — ``(snapshot_id, saver
    instance)`` is stored inside the snapshot itself — so a re-commit after
    restore is fenced by the external system exactly like a prepared XA
    transaction being re-committed."""

    #: pending IS snapshotted (under its stable txn id) but restores into
    #: ``prepared``: a restored buffer is by definition past its
    #: commit-prepare, so it re-enters phase 2, not the open epoch
    SNAPSHOT_STATE = frozenset({"pending"})

    def __init__(self, collector: ExternalCollector):
        self.collector = collector
        self.pending: List[Any] = []       # current (uncommitted) epoch
        # txn_id -> buffer, txn_id = (snapshot_id, saver_global_index)
        self.prepared: Dict[Any, List[Any]] = {}

    def process(self, ordinal: int, inbox: Inbox) -> None:
        while True:
            ev = inbox.poll()
            if ev is None:
                return
            self.pending.append(ev.value)

    # -- two-phase commit hooks --------------------------------------------------
    def save_to_snapshot(self) -> bool:
        # commit-prepare: the pending buffer (with its stable txn id)
        # rides in the snapshot; ``current_snapshot_id`` is set by the
        # tasklet before this hook runs
        sid = getattr(self, "current_snapshot_id", 0)
        txn = (sid, self.ctx.global_index)
        self.outbox.offer_to_snapshot(("txn", self.ctx.global_index),
                                      (txn, list(self.pending)))
        self.prepared[txn] = self.pending
        self.pending = []
        return True

    def on_snapshot_committed(self, snapshot_id: int) -> None:
        """Called by the engine when the snapshot commits (phase 2)."""
        for txn in sorted(self.prepared):
            self.collector.commit_epoch(txn, self.prepared[txn])
        self.prepared.clear()

    def restore_from_snapshot(self, items) -> None:
        # prepared-but-unreleased buffers re-commit after restart (phase 2
        # after crash); stable txn ids make double commits no-ops
        for (tag, _idx), (txn, buf) in items:
            if tag == "txn" and buf:
                self.prepared[tuple(txn)] = list(buf)

    def finish_snapshot_restore(self) -> None:
        self.on_snapshot_committed(-1)

    def complete(self) -> bool:
        # batch jobs: release whatever is pending at end-of-stream
        self.on_snapshot_committed(-1)
        if self.pending:
            self.collector.commit_epoch(
                ("final", self.ctx.global_index), self.pending)
            self.pending = []
        return True


class IdempotentSink(Processor):
    """Keyed upserts: replayed results overwrite identically."""

    def __init__(self, collector: ExternalCollector,
                 key_fn: Optional[Callable[[Event], Any]] = None):
        self.collector = collector
        self.key_fn = key_fn or (lambda ev: ev.key)

    def process(self, ordinal: int, inbox: Inbox) -> None:
        while True:
            ev = inbox.poll()
            if ev is None:
                return
            self.collector.upsert(self.key_fn(ev), ev.value)
