"""Version-compatibility shims for jax APIs used across the repo.

The repo targets both pre- and post-0.5 jax: ``shard_map`` moved from
``jax.experimental`` to the top level (renaming ``check_rep`` to
``check_vma``), ``AbstractMesh`` changed its constructor signature, and
``Compiled.cost_analysis`` switched between returning a dict and a
one-element list of dicts.  Centralising the differences here keeps the
call sites clean.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: top-level shard_map with check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # jax < 0.5: experimental shard_map with check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` with replication checking off, on any jax."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def abstract_mesh(axis_sizes, axis_names):
    """Build an ``AbstractMesh`` across both constructor generations."""
    AbstractMesh = jax.sharding.AbstractMesh
    try:  # newer: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # older: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def cost_analysis_dict(compiled) -> dict:
    """Normalise ``Compiled.cost_analysis()`` to a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
