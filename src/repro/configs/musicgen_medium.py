"""MusicGen-medium: decoder-only transformer over EnCodec tokens
[arXiv:2306.05284; hf].  The EnCodec frontend is a STUB: input_specs()
feeds precomputed frame token ids (vocab 2048)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    norm="layernorm", modality="audio_stub",
)
