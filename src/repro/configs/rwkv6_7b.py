"""RWKV-6 (Finch) 7B: attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", ssm_kind="rwkv6",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=14336, vocab_size=65536,
    norm="layernorm", rwkv_head_size=64,
)
