"""Architecture registry: the 10 assigned configs + shape sets.

Shapes (per the task spec) pair each architecture with four input shapes;
``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV/SSM
cache of seq_len), the others lower ``train_step``.  ``long_500k`` is only
run for sub-quadratic architectures (SWA / SSM / hybrid); pure
full-attention archs skip it (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig

from .internlm2_20b import CONFIG as internlm2_20b
from .minitron_4b import CONFIG as minitron_4b
from .olmo_1b import CONFIG as olmo_1b
from .qwen2_1_5b import CONFIG as qwen2_1_5b
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .phi3_5_moe import CONFIG as phi3_5_moe
from .rwkv6_7b import CONFIG as rwkv6_7b
from .jamba_v0_1 import CONFIG as jamba_v0_1
from .musicgen_medium import CONFIG as musicgen_medium
from .llava_next_34b import CONFIG as llava_next_34b

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c for c in [
        internlm2_20b, minitron_4b, olmo_1b, qwen2_1_5b, mixtral_8x7b,
        phi3_5_moe, rwkv6_7b, jamba_v0_1, musicgen_medium, llava_next_34b,
    ]
}

ARCH_IDS = list(REGISTRY)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def is_subquadratic(cfg: ModelConfig) -> bool:
    """Can this architecture decode at 500k context?  SWA, SSM and hybrid
    (few attention layers) qualify; pure full attention does not."""
    return cfg.family in ("ssm", "hybrid") or cfg.attention == "swa"


def applicable_cells(arch: Optional[str] = None
                     ) -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells, honouring the long_500k skip rule."""
    cells = []
    for a, cfg in REGISTRY.items():
        if arch and a != arch:
            continue
        for s, spec in SHAPES.items():
            if s == "long_500k" and not is_subquadratic(cfg):
                continue
            cells.append((a, s))
    return cells


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
