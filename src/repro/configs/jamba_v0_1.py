"""Jamba-v0.1 52B: Mamba+attention 1:7 interleave, 16-expert top-2 MoE
every other layer [arXiv:2403.19887; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2,
    attn_period=8, ssm_kind="mamba", d_state=16, d_conv=4, expand=2,
    norm="rmsnorm",
)
