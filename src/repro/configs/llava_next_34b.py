"""LLaVA-NeXT-34B backbone: dense GQA decoder; the anyres vision tower is
a STUB: input_specs() feeds precomputed patch embeddings
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    norm="rmsnorm", rope_theta=5_000_000.0, modality="vlm_stub",
)
