"""AdamW with decoupled weight decay and global-norm clipping.

Implemented directly over pytrees (no external deps).  Moments inherit the
parameters' sharding via identical pytree structure, so FSDP-sharded
parameters automatically give ZeRO-sharded optimizer state.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamW:
    def __init__(self, lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: Optional[float] = 1.0,
                 warmup_steps: int = 100, total_steps: int = 10_000):
        self.lr = lr
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.warmup_steps = warmup_steps
        self.total_steps = total_steps

    def init(self, params) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)

    def update(self, params, grads, opt_state) -> Tuple[Any, Dict[str, Any]]:
        count = opt_state["count"] + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                              for g in jax.tree_util.tree_leaves(g32)))
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          opt_state["mu"], g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          opt_state["nu"], g32)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        lr = self.schedule(count)

        def upd(p, m, v):
            step = m / bc1 / (jnp.sqrt(v / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": count}
