"""Gradient compression for the data-parallel reduce (distributed-
optimization trick for 1000+-node fleets).

With pjit the DP gradient reduction is implicit; to compress it we take
the reduction into our own hands with ``shard_map``: per-device gradients
quantize to int8 with a per-tensor fp32 scale, ``psum`` in int32 (exact
for <= 2^23 contributions), and dequantize — wire traffic drops 4x
(fp32 -> int8) at ~0.4% RMS quantization noise per tensor, mitigated by
error feedback (the residual carries to the next step).

Use through ``make_train_step(grad_transform=...)`` when gradients are
computed per data shard, or standalone via :func:`compressed_psum`.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name: str):
    """int8-quantized psum of a gradient pytree over ``axis_name``.

    Each leaf quantizes with its local scale; scales are max-reduced so
    every participant dequantizes against the same grid, then the int32
    sum is exact."""
    def one(x):
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0, axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
        s = jax.lax.psum(q, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return s.astype(jnp.float32) * scale / n
    return jax.tree.map(one, tree)


class ErrorFeedback:
    """Residual-carrying quantizer: e_{t+1} = g_t - dequant(quant(g_t + e_t)).

    Keeps long-run bias at zero; state is a pytree matching the grads."""

    def init(self, grads_like):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                            grads_like)

    def apply(self, grads, residual):
        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, scale = quantize_int8(x)
            deq = dequantize_int8(q, scale)
            return deq, x - deq
        pairs = jax.tree.map(one, grads, residual)
        new_grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_resid = jax.tree.map(lambda p: p[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_grads, new_resid
