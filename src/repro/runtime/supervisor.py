"""Coordinator-side worker supervision: detecting failures the engine
did not schedule.

:class:`WorkerSupervisor` watches one execution attempt's worker
processes (``runtime/worker_proc.py``) through two independent signals
and classifies every uncooperative death:

* **exitcodes** — a worker process that is gone without having reported
  its data plane DONE is abnormal.  A negative exitcode means the OS
  delivered a fatal signal (SIGKILL'd mid-run -> :data:`FAILURE_CRASHED`);
  a non-negative one means the interpreter exited on its own, i.e. a
  processor raised (:data:`FAILURE_ERROR` — usually preceded by the
  child's ``("error", traceback)`` message, which carries the detail).
* **heartbeats** — children send a tiny ``("hb",)`` record on their
  control pipe every :data:`~repro.runtime.worker_proc._HEARTBEAT_S`
  seconds, even while parked idle or blocked post-DONE.  A live process
  whose heartbeat is older than ``heartbeat_timeout_s`` is **hung**
  (wedged in a slice, SIGSTOP'd, deadlocked on a ring): the supervisor
  SIGKILLs it — a hung worker holds ring slots and barrier alignment
  hostage, so it must die before recovery can run — and reports
  :data:`FAILURE_HUNG`.

The supervisor never decides *policy*: it only produces
:class:`~repro.core.backend.WorkerFailure` records, which the backend
surfaces through ``take_failures`` and the engine routes into the job's
:class:`~repro.core.engine.RestartPolicy` (bounded backoff restarts from
the last committed snapshot, then terminal FAILED).

Each failure is reported exactly once per worker; a worker that already
delivered its DONE is exempt (its exit is expected at teardown).
"""

from __future__ import annotations

import os
import signal
import time as _time
from typing import Dict, Iterable, List, Optional

from ..core.backend import (FAILURE_CRASHED, FAILURE_ERROR, FAILURE_HUNG,
                            Location, WorkerFailure)

#: default heartbeat deadline — generous next to the ~4/s child cadence so
#: scheduler hiccups on a loaded box never read as failures
DEFAULT_HEARTBEAT_TIMEOUT_S = 5.0


def failure_fingerprint(failure: WorkerFailure, restored_snapshot_id):
    """Identity of a detected failure for crash-loop escalation.

    ``(vertex, exception type, restored snapshot id)`` when the failure
    is attributable to a processor raise; the worker location and failure
    kind otherwise.  Keying on the *restored* snapshot id is what makes
    the fingerprint mean "deterministic": the same vertex raising the
    same exception twice after restoring the same epoch is replaying an
    identical crash, and the engine escalates (fall back a chain entry /
    quarantine the stamped poison record) instead of burning the restart
    budget on it.  See ``Job._note_failures`` in core/engine.py."""
    return (failure.vertex or failure.key,
            failure.exc_type or failure.kind,
            restored_snapshot_id)


class WorkerSupervisor:
    """Watches the worker processes of one execution attempt."""

    __slots__ = ("heartbeat_timeout_s", "_last_hb", "_reported")

    def __init__(self,
                 heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S):
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self._last_hb: Dict[Location, float] = {}
        self._reported: set = set()

    # -- signal intake -------------------------------------------------------
    def worker_started(self, key: Location,
                       now: Optional[float] = None) -> None:
        """Arm the heartbeat deadline at fork time, so a worker that wedges
        before its first heartbeat is still caught."""
        self._last_hb[key] = _time.monotonic() if now is None else now

    def heartbeat(self, key: Location, now: Optional[float] = None) -> None:
        self._last_hb[key] = _time.monotonic() if now is None else now

    def mark_reported(self, key: Location) -> None:
        """Suppress double-reporting for a failure classified elsewhere
        (e.g. the drain loop saw the child's ``("error", tb)`` message and
        recorded it with the full traceback)."""
        self._reported.add(key)

    # -- classification ------------------------------------------------------
    def check(self, handles: Iterable,
              now: Optional[float] = None) -> List[WorkerFailure]:
        """Classify every not-yet-reported abnormal worker among
        ``handles`` (``_WorkerHandle``-shaped: key/proc/done attributes).
        Hung workers are SIGKILLed as a side effect."""
        if now is None:
            now = _time.monotonic()
        failures: List[WorkerFailure] = []
        for h in handles:
            if h.done or h.key in self._reported:
                continue
            code = h.proc.exitcode
            if code is not None:
                self._reported.add(h.key)
                if code < 0:
                    failures.append(WorkerFailure(
                        FAILURE_CRASHED, key=h.key, exitcode=code,
                        pid=h.proc.pid,
                        detail=f"worker n{h.key[0]}-w{h.key[1]} killed by "
                               f"signal {-code} without reporting DONE"))
                else:
                    failures.append(WorkerFailure(
                        FAILURE_ERROR, key=h.key, exitcode=code,
                        pid=h.proc.pid,
                        detail=f"worker n{h.key[0]}-w{h.key[1]} exited "
                               f"with code {code} without reporting DONE"))
                continue
            last = self._last_hb.get(h.key)
            if (last is not None
                    and now - last > self.heartbeat_timeout_s):
                self._reported.add(h.key)
                # a hung worker still owns ring cursors and an un-acked
                # barrier; it cannot be left running while the job
                # restarts around it
                try:
                    os.kill(h.proc.pid, signal.SIGKILL)
                except (ProcessLookupError, OSError):  # pragma: no cover
                    pass
                failures.append(WorkerFailure(
                    FAILURE_HUNG, key=h.key, pid=h.proc.pid,
                    detail=f"worker n{h.key[0]}-w{h.key[1]}: no heartbeat "
                           f"for {now - last:.2f}s "
                           f"(deadline {self.heartbeat_timeout_s}s); "
                           f"SIGKILLed"))
        return failures
