"""True multi-process execution: one OS process per cooperative worker.

:class:`MultiprocessBackend` implements the
:class:`~repro.core.backend.ExecutionBackend` contract by turning every
(node, cooperative-thread) pair of the planned execution into a real
``fork``-spawned worker process.  Edges between tasklets in the same
process stay plain :class:`~repro.core.queues.SPSCQueue`s; every edge that
crosses a process boundary — local threads of one JetNode as much as
cross-node links — becomes a fixed-capacity shared-memory
:class:`~repro.core.shm_ring.ShmRing` carrying EventBlock columns as raw
slabs plus a control lane for watermarks/barriers/scalar stragglers.

Coordination stays in the parent ("coordinator"), which never touches the
data plane:

* a duplex pipe per worker carries control: parent -> child ``("snapshot",
  id)`` / ``("committed", id)`` / ``("stop",)``; child -> parent
  ``("ack", id, entries)`` / ``("results", batch)`` / ``("done", stats)``
  / ``("error", traceback)``.
* the Chandy-Lamport protocol itself is unchanged — barriers flow through
  the rings exactly as through in-process queues; each worker aligns and
  snapshots its local tasklets, buffers the state entries, and ships them
  with its ack.  :class:`MpSnapshotContext` (parent side) completes the
  snapshot when every live worker acked, lands all entries in the
  IMap-backed store in one bulk write, commits, and broadcasts phase 2.
* ``kill_node`` / ``add_node`` keep their whole-job restart semantics:
  the backend tears every worker process down, the engine rebuilds and
  restores in the parent, and ``start_execution`` re-forks — children
  inherit the restored state, so exactly-once replay works unchanged.
* sink results (processors exposing an ``out`` list, e.g.
  :class:`~repro.core.sources.CollectorSink`) are shipped incrementally to
  the parent and merged into the parent-side processor's list, so tests
  and benchmarks observe results exactly as under the in-process backend.

Workers inherit the built execution via ``fork`` (no pickling of the DAG
or closures); only items crossing rings and control messages serialize.

The conventions above are jetlint-enforced (ROADMAP "Machine-checked
contracts"): the control-pipe vocabulary is closed by the
``protocol-unhandled-message`` / ``protocol-dead-arm`` pass — every tag
sent on either side of the fork must have a dispatch arm on the other,
and every arm a live sender (``repro.analysis.protocol`` classifies
call sites coordinator vs worker by reachability from
:func:`_worker_main`); the "coordinator never touches the data plane"
rule is the process-role half of ``ring-role-violation``
(``repro.analysis.ring_roles``); and pipe/process/shm acquisitions here
carry ``resource-leak`` obligations — the pass caught the parent's copy
of ``child_conn`` leaking on failed spawns in exactly this module.

Failure semantics: cooperative vs detected
==========================================

``kill_node`` / ``add_node`` above are *cooperative* failures — the
engine initiates them, tears the attempt down in order, and restarts
unconditionally.  Everything below is about failures the engine did NOT
schedule:

* children heartbeat (``("hb",)`` every :data:`_HEARTBEAT_S` seconds) on
  their control pipe; the coordinator-side
  :class:`~repro.runtime.supervisor.WorkerSupervisor` classifies a worker
  as **crashed** (exitcode < 0 without DONE — e.g. SIGKILL'd by the OS),
  **hung** (live process, heartbeat older than the deadline — wedged,
  SIGSTOP'd, deadlocked; the supervisor SIGKILLs it), or **error-exited**
  (the child shipped ``("error", traceback)`` and re-raised).
* ``step``/``_drain_handle`` route ``EOFError``/``BrokenPipeError`` from
  a dead worker's pipe into this detection instead of crashing the
  coordinator or silently dropping the worker: the handle is marked dead,
  the snapshot context is told (see below), and the supervisor's next
  check turns the exitcode into a :class:`~repro.core.backend
  .WorkerFailure` surfaced via ``take_failures`` — the engine's
  :class:`~repro.core.engine.RestartPolicy` then drives the same
  teardown -> restore-from-committed-snapshot -> re-fork path as
  ``kill_node``, with bounded attempts and exponential backoff.

Abort vs commit rules for the barrier protocol:

* a snapshot COMMITS only when every worker that received its barrier
  broadcast acked with its buffered state entries (workers that finished
  their data plane beforehand are exempt — they hold no in-flight state);
* a snapshot is ABORTED — buffered entries discarded, ``aborted_count``
  bumped, the previous *committed* snapshot left authoritative, the job
  free to schedule a new snapshot — whenever its barrier protocol can no
  longer complete: the ack deadline (``JobConfig.barrier_timeout_s``)
  lapses, a worker dies holding an un-acked barrier, or the barrier
  broadcast itself hits a dead pipe.  An abort never stalls the job and
  never completes with partial state.
* children **serialize barrier generations**: an abort lets the
  coordinator begin snapshot *n+1* while a loaded worker still has the
  ``("snapshot", n)`` command queued, so a child begins each queued id
  only after its previous local snapshot completed — every barrier id is
  emitted into the rings, in order, and downstream alignment can never
  park on a generation that nobody will ever forward (the coordinator
  simply ignores late acks for aborted ids).
"""

from __future__ import annotations

import multiprocessing
import os
import signal
import time as _time
import traceback
from multiprocessing import connection as _mpc
from typing import Any, Dict, List, Optional, Tuple

from ..core.backend import (ExecutionBackend, FAILURE_ERROR, Location,
                            WorkerFailure)
from ..core.clock import Clock, VirtualClock
from ..core.queues import SPSCQueue
from ..core.shm_ring import DEFAULT_RING_BYTES, ShmRing
from ..core.tasklet import (CooperativeWorker, GUARANTEE_NONE,
                            SnapshotContext)
from ..state.snapshot_store import own_snapshot_value
from .supervisor import DEFAULT_HEARTBEAT_TIMEOUT_S, WorkerSupervisor

_MP = multiprocessing.get_context("fork")

#: child idle backoff (spin -> yield -> park), mirroring the engine driver
_IDLE_SPIN_ITERS = 64
_IDLE_YIELD_ITERS = 192
_IDLE_PARK_MIN_S = 0.00005
_IDLE_PARK_MAX_S = 0.0005
#: how often a child ships new sink results to the coordinator
_RESULT_SHIP_S = 0.02
#: command-pipe poll cadence (iterations) while the child is busy
_CMD_POLL_ITERS = 32
#: child liveness heartbeat cadence (supervisor deadline is several x this)
_HEARTBEAT_S = 0.25


class _BufferWriter:
    """Child-local SnapshotWriter stand-in: buffers entries until the ack
    ships them to the coordinator.  Values are copied at ``put`` time —
    the processor keeps mutating its live containers between its barrier
    and the worker-wide ack, and a buffered reference would ship the
    mutated state (see :func:`repro.state.snapshot_store
    .own_snapshot_value`)."""

    __slots__ = ("entries",)

    def __init__(self):
        self.entries: List[Tuple] = []

    def put(self, snapshot_id: int, vertex: str, key, value, pid: int,
            instance: int = 0) -> None:
        self.entries.append((snapshot_id, vertex, key,
                             own_snapshot_value(value), pid, instance))

    def take(self) -> List[Tuple]:
        entries, self.entries = self.entries, []
        return entries


def _sink_list(processor) -> Optional[list]:
    """The results list of a collector-style sink, if this processor is
    one (duck-typed: an ``out`` attribute holding a list)."""
    out = getattr(processor, "out", None)
    return out if isinstance(out, list) else None


def _tasklet_stats(tasklet) -> Dict[str, Any]:
    p = tasklet.processor
    inner = getattr(p, "inner", p)
    stats: Dict[str, Any] = {
        "items_in": tasklet.items_in, "items_out": tasklet.items_out,
        "calls": tasklet.calls, "idle_calls": tasklet.idle_calls,
    }
    for obj in (p, inner):
        if hasattr(obj, "late_dropped"):
            stats["late_dropped"] = obj.late_dropped
            break
    start = getattr(inner, "_start", None)
    if isinstance(start, float):
        stats["source_start"] = start
    return stats


def _apply_stats(tasklet, stats: Dict[str, Any]) -> None:
    tasklet.items_in = stats["items_in"]
    tasklet.items_out = stats["items_out"]
    tasklet.calls = stats["calls"]
    tasklet.idle_calls = stats["idle_calls"]
    if "late_dropped" in stats:
        p = tasklet.processor
        target = p if hasattr(p, "late_dropped") else getattr(p, "inner", p)
        target.late_dropped = stats["late_dropped"]


# --------------------------------------------------------------------------
# child side
# --------------------------------------------------------------------------

def _ship_results(conn, sinks) -> None:
    batch = []
    for entry in sinks:
        name, out, cursor = entry
        n = len(out)
        if n > cursor:
            batch.append((name, out[cursor:n]))
            entry[2] = n
    if batch:
        conn.send(("results", batch))


def _worker_main(execution, key: Location, conn) -> None:
    """Entry point of one worker process (runs post-fork; everything it
    needs — tasklets, queues, rings — arrived by inheritance)."""
    try:
        assignment = execution.backend_data["assignment"]
        tasklets = [t for t in execution.tasklets
                    if assignment[id(t)] == key]
        parent_ctx = execution.ssctx
        writer = _BufferWriter()
        local_ctx = SnapshotContext(parent_ctx.guarantee, writer)
        local_ctx.requested_id = parent_ctx.requested_id
        local_ctx.completed_id = parent_ctx.completed_id
        local_ctx.tasklets = tasklets

        def _acked(snapshot_id: int) -> None:
            conn.send(("ack", snapshot_id, writer.take()))

        local_ctx.on_complete = _acked
        worker = CooperativeWorker(f"n{key[0]}-w{key[1]}")
        for t in tasklets:
            t.ssctx = local_ctx
            worker.add(t)
        sinks = [[t.name, out, len(out)] for t in tasklets
                 for out in (_sink_list(t.processor),) if out is not None]

        idle_streak = 0
        done_sent = False
        pending_snapshots: List[int] = []
        last_ship = last_hb = _time.monotonic()
        iters = 0
        while True:
            iters += 1
            if done_sent or not iters % _CMD_POLL_ITERS or idle_streak:
                while conn.poll(0):
                    cmd = conn.recv()
                    op = cmd[0]
                    if op == "snapshot":
                        # Serialize barrier generations.  Two snapshot
                        # commands can be queued back-to-back when the
                        # coordinator ABORTS snapshot n (ack deadline) and
                        # begins n+1 before this (descheduled, loaded)
                        # worker drained its pipe.  Calling begin(n+1)
                        # straight over begin(n) would mean no tasklet
                        # slice ever observes requested_id == n, so this
                        # worker's sources would never emit barrier n into
                        # the rings — while a faster sibling worker DID
                        # forward n, leaving downstream tasklets parked on
                        # a mix of generations that can never align (a
                        # permanent, heartbeat-alive wedge).  Begin each
                        # id only after the previous local snapshot
                        # completed, so every barrier id is emitted, in
                        # order; the coordinator ignores late acks for
                        # aborted ids.
                        pending_snapshots.append(cmd[1])
                    elif op == "committed":
                        for t in tasklets:
                            hook = getattr(t.processor,
                                           "on_snapshot_committed", None)
                            if hook is not None:
                                hook(cmd[1])
                    elif op == "chaos_raise":
                        # parent-triggered fault: plant an exception in the
                        # named (or first live) tasklet's next slice
                        live = [t for t in tasklets if not t.is_done]
                        target = next((t for t in live if t.name == cmd[1]),
                                      live[0] if live else None)
                        if target is not None:
                            target._chaos_exc = RuntimeError(cmd[2])
                    elif op == "stop":
                        _ship_results(conn, sinks)
                        return
            if (pending_snapshots
                    and local_ctx.completed_id == local_ctx.requested_id):
                local_ctx.begin(pending_snapshots.pop(0))
            progress = worker.run_iteration()
            now = _time.monotonic()
            if now - last_hb >= _HEARTBEAT_S:
                conn.send(("hb",))
                last_hb = now
            if sinks and now - last_ship >= _RESULT_SHIP_S:
                _ship_results(conn, sinks)
                last_ship = now
            if not done_sent and all(t.is_done for t in tasklets):
                _ship_results(conn, sinks)
                conn.send(("done",
                           [(t.name, _tasklet_stats(t)) for t in tasklets]))
                done_sent = True
            if progress:
                idle_streak = 0
            elif done_sent:
                # data plane finished: block on the command pipe
                conn.poll(0.05)
            else:
                idle_streak += 1
                if idle_streak > _IDLE_YIELD_ITERS:
                    park = _IDLE_PARK_MIN_S * (
                        1 << min(idle_streak - _IDLE_YIELD_ITERS, 8))
                    _time.sleep(min(park, _IDLE_PARK_MAX_S))
                elif idle_streak > _IDLE_SPIN_ITERS:
                    _time.sleep(0)
    except (EOFError, BrokenPipeError, KeyboardInterrupt):
        pass
    except BaseException as e:
        # ship the full traceback to the coordinator (it becomes the
        # WorkerFailure detail) and exit nonzero WITHOUT re-raising:
        # multiprocessing's bootstrap would print a duplicate traceback
        # for a failure the parent is about to handle and heal.  The
        # attribution info (vertex, root exception type, any pinpointed
        # poison record) rides along so the engine's failure
        # fingerprinting works across the process boundary.
        cause = getattr(e, "cause", e)
        info = {
            "vertex": getattr(getattr(e, "tasklet", None),
                              "vertex_name", None),
            "exc_type": type(cause).__name__,
            "poison": getattr(cause, "_jet_poison", None),
        }
        try:
            conn.send(("error", traceback.format_exc(), info))
        except Exception:
            try:
                # a poison payload that does not pickle must not mask
                # the failure report itself
                info["poison"] = None
                conn.send(("error", traceback.format_exc(), info))
            except Exception:
                pass
        try:
            conn.close()
        except Exception:
            pass
        os._exit(1)
    finally:
        try:
            conn.close()
        except Exception:
            pass


# --------------------------------------------------------------------------
# parent side
# --------------------------------------------------------------------------

class MpSnapshotContext(SnapshotContext):
    """Coordinator-side snapshot state: ``begin`` broadcasts to workers,
    completion needs an ack (with state entries) from every live worker;
    entries land in the snapshot store in one bulk write before commit.

    Unlike the in-process context, acks here CAN be lost (a worker dies
    holding an un-acked barrier, or the barrier broadcast itself hits a
    dead pipe), so an in-flight snapshot may be **aborted**: buffered
    entries are discarded, ``aborted_count`` is bumped and the last
    *committed* snapshot stays authoritative — ``on_complete`` (which
    commits) is never called for an aborted snapshot, and late acks for
    it are ignored.  ``ack_timeout_s`` (wired from
    ``JobConfig.barrier_timeout_s``) bounds how long a snapshot may wait
    for its acks before the engine's ``check_timeout`` poll aborts it."""

    __slots__ = ("backend", "execution", "store_writer", "ack_timeout_s",
                 "_await", "_entries", "_deadline")

    def __init__(self, guarantee: str, store_writer):
        super().__init__(guarantee, writer=None)
        self.backend: Optional["MultiprocessBackend"] = None
        self.execution = None
        self.store_writer = store_writer
        self.ack_timeout_s: Optional[float] = None
        self._await: set = set()
        self._entries: List[Tuple] = []
        self._deadline: Optional[float] = None

    def begin(self, snapshot_id: int) -> None:
        self.requested_id = snapshot_id
        self._entries = []
        if self.ack_timeout_s is not None:
            self._deadline = _time.monotonic() + self.ack_timeout_s
        reached, failed = self.backend.broadcast(self.execution,
                                                 ("snapshot", snapshot_id))
        self._await = reached
        if failed:
            # a not-yet-done worker never received its barrier: it will
            # never align, so this snapshot cannot be consistent
            self.abort(f"barrier broadcast failed for workers {failed}")
            return
        self._maybe_complete()

    def worker_ack(self, key: Location, snapshot_id: int,
                   entries: List[Tuple]) -> None:
        if (snapshot_id != self.requested_id
                or self.completed_id == self.requested_id):
            return      # stale, or a late ack for an aborted snapshot
        self._entries.extend(entries)
        self._await.discard(key)
        self._maybe_complete()

    def worker_gone(self, key: Location, crashed: bool = False) -> None:
        """A worker left the data plane.  ``crashed=False`` means it
        finished cleanly (reported DONE): it holds no in-flight state, so
        it is exempt from the barrier — same as the in-process rule.
        ``crashed=True`` means it died; if it still owed us an ack, its
        state is lost and the snapshot must be aborted, never completed
        without it."""
        if key not in self._await:
            return
        if crashed:
            self.abort(f"worker {key} died holding an un-acked barrier")
            return
        self._await.discard(key)
        self._maybe_complete()

    def abort(self, reason: str = "") -> None:
        """Abort the in-flight snapshot: discard buffered entries, retire
        the ongoing snapshot's IMap storage, leave the last committed
        snapshot authoritative, and free the job to schedule a new
        snapshot.  No commit, no ``on_complete``."""
        if self.completed_id == self.requested_id:
            return      # nothing in flight
        self._entries = []
        self._await = set()
        self._deadline = None
        # destroy the aborted epoch's IMap storage BEFORE marking it
        # complete: entries may have landed there (e.g. a partial
        # put_many, or a restore that reused the id) and nothing will
        # ever commit or retire this id again — without the destroy the
        # __jet.snapshot.<job>.<id> map leaks for the life of the cluster
        self.retire_aborted()
        self.completed_id = self.requested_id
        self.aborted_count += 1

    def retire_aborted(self) -> None:
        # the mp context writes through store_writer, not the base
        # class's writer slot
        if (self.store_writer is not None
                and self.completed_id != self.requested_id):
            store = self.store_writer.store
            store._map(self.store_writer.job_id, self.requested_id).destroy()

    def check_timeout(self) -> bool:
        if (self.completed_id != self.requested_id
                and self._deadline is not None
                and _time.monotonic() > self._deadline):
            self.abort(f"barrier acks overdue after {self.ack_timeout_s}s")
            return True
        return False

    def _maybe_complete(self) -> None:
        if self.completed_id == self.requested_id or self._await:
            return
        if self.store_writer is not None and self._entries:
            self.store_writer.put_many(self._entries)
        self._entries = []
        self._deadline = None
        self.completed_id = self.requested_id
        if self.on_complete is not None:
            self.on_complete(self.completed_id)


class _WorkerHandle:
    __slots__ = ("key", "proc", "conn", "alive", "done")

    def __init__(self, key: Location, proc, conn):
        self.key = key
        self.proc = proc
        self.conn = conn
        self.alive = True
        self.done = False


def _kill_handle_hard(proc) -> None:
    """Last-resort teardown for a worker that survived ``terminate()``:
    SIGTERM stays *pending* on a SIGSTOPped process, so escalate to
    SIGKILL (which cannot be blocked or stopped) and reap."""
    try:
        os.kill(proc.pid, signal.SIGKILL)
    except (ProcessLookupError, OSError):  # pragma: no cover
        pass
    proc.join(timeout=5.0)


class MultiprocessBackend(ExecutionBackend):
    """Execution substrate running cooperative workers as OS processes
    over shared-memory rings (module docstring has the full protocol)."""

    name = "mp"

    def __init__(self, ring_bytes: int = DEFAULT_RING_BYTES,
                 heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S):
        super().__init__()
        self.ring_bytes = ring_bytes
        self.heartbeat_timeout_s = heartbeat_timeout_s

    def clock_supported(self, clock: Clock) -> bool:
        return not isinstance(clock, VirtualClock)

    # -- build time ----------------------------------------------------------
    def create_snapshot_context(self, job):
        writer = (self.cluster.snapshot_store.writer(job.id)
                  if job.config.processing_guarantee != GUARANTEE_NONE
                  else None)
        ctx = MpSnapshotContext(job.config.processing_guarantee, writer)
        ctx.ack_timeout_s = job.config.barrier_timeout_s
        return ctx

    def make_transport(self, execution, edge, src: Location, dst: Location):
        if src == dst:
            return SPSCQueue(edge.queue_size)
        ring = ShmRing(self.ring_bytes)
        execution.backend_data.setdefault("rings", []).append(ring)
        return ring

    def assign_tasklet(self, execution, inst, tasklet) -> None:
        key = (inst.node,
               inst.local_index % self.cluster.cooperative_threads)
        data = execution.backend_data
        data.setdefault("assignment", {})[id(tasklet)] = key
        data.setdefault("by_worker", {}).setdefault(key, []).append(tasklet)

    # -- lifecycle -----------------------------------------------------------
    def start_execution(self, execution) -> None:
        data = execution.backend_data
        if data.get("started"):
            return
        for t in execution.tasklets:
            if t._poll_async is not None:
                raise NotImplementedError(
                    "device-offloaded vertices need the coordinator's "
                    "accelerator context; run them on backend='inproc'")
        ssctx = execution.ssctx
        ssctx.backend = self
        ssctx.execution = execution
        supervisor = WorkerSupervisor(self.heartbeat_timeout_s)
        workers: Dict[Location, _WorkerHandle] = {}
        for key in sorted(data.get("by_worker", {})):
            parent_conn, child_conn = _MP.Pipe(duplex=True)
            try:
                proc = _MP.Process(
                    target=_worker_main, args=(execution, key, child_conn),
                    name=f"jet-n{key[0]}-w{key[1]}", daemon=True)
                proc.start()
            finally:
                # the child inherited its end across the fork; the
                # parent's copy of that fd must close even if the spawn
                # itself blew up, or every failed start leaks a pipe
                child_conn.close()
            workers[key] = _WorkerHandle(key, proc, parent_conn)
            supervisor.worker_started(key)
        data["workers"] = workers
        data["supervisor"] = supervisor
        data["done"] = set()
        data["failures"] = []
        data["by_name"] = {t.name: t for t in execution.tasklets}
        data["started"] = True
        data["stopped"] = False

    def stop_execution(self, execution) -> None:
        data = execution.backend_data
        if not data.get("started") or data.get("stopped"):
            data["stopped"] = True
            return
        # un-stall chaos-SIGSTOPped workers first: a stopped process can
        # neither honor ("stop",) nor die from the pending SIGTERM
        for pid in list(data.get("stalled", {})):
            try:
                os.kill(pid, signal.SIGCONT)
            except (ProcessLookupError, OSError):
                pass
        data.pop("stalled", None)
        workers = data["workers"]
        for h in workers.values():
            if h.alive:
                try:
                    h.conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    h.alive = False
        deadline = _time.monotonic() + 5.0
        pending = list(workers.values())
        while pending and _time.monotonic() < deadline:
            still = []
            for h in pending:
                self._drain_handle(execution, h, detect=False)
                h.proc.join(timeout=0.05)
                if h.proc.exitcode is None:
                    still.append(h)
            pending = still
        for h in pending:  # stuck worker: escalate SIGTERM -> SIGKILL
            h.proc.terminate()
            h.proc.join(timeout=1.0)
            if h.proc.exitcode is None:  # pragma: no cover - hard path
                _kill_handle_hard(h.proc)
        for h in workers.values():
            h.alive = False
            try:
                h.conn.close()
            except Exception:
                pass
        for ring in data.get("rings", ()):
            ring.unlink()
            ring.close()
        data["stopped"] = True

    def shutdown(self) -> None:
        pass    # per-execution teardown covers everything

    # -- driving -------------------------------------------------------------
    def step(self, jobs) -> bool:
        progress = False
        waitable = []
        now = _time.monotonic()
        for job in jobs:
            execution = job.execution
            if execution is None:
                continue
            data = execution.backend_data
            if not data.get("started") or data.get("stopped"):
                continue
            for h in data["workers"].values():
                if h.alive:
                    progress |= self._drain_handle(execution, h,
                                                   detect=True)
                    if h.alive:
                        waitable.append(h.conn)
            progress |= self._deliver_due_acks(execution, now)
            self._resume_due_stalls(data, now)
            supervisor = data["supervisor"]
            failures = supervisor.check(data["workers"].values(), now=now)
            if failures:
                progress = True
                for f in failures:
                    h = data["workers"].get(f.key)
                    if h is not None:
                        h.alive = False
                    # a dead worker can never ack: abort any snapshot
                    # still awaiting it rather than stalling
                    execution.ssctx.worker_gone(f.key, crashed=True)
                data["failures"].extend(failures)
        if not progress and waitable:
            # nothing pending: block briefly on the control pipes instead
            # of burning the coordinator's core (the data plane lives in
            # the workers)
            _mpc.wait(waitable, timeout=0.002)
        return progress

    @staticmethod
    def _deliver_due_acks(execution, now: float) -> bool:
        """Release chaos-delayed barrier acks whose hold expired."""
        delayed = execution.backend_data.get("delayed_acks")
        if not delayed:
            return False
        due = [d for d in delayed if d[0] <= now]
        if not due:
            return False
        execution.backend_data["delayed_acks"] = [
            d for d in delayed if d[0] > now]
        for _, key, snapshot_id, entries in due:
            execution.ssctx.worker_ack(key, snapshot_id, entries)
        return True

    @staticmethod
    def _resume_due_stalls(data, now: float) -> None:
        """SIGCONT chaos-stalled workers whose stall duration elapsed."""
        stalled = data.get("stalled")
        if not stalled:
            return
        for pid, resume_at in list(stalled.items()):
            if resume_at is not None and now >= resume_at:
                try:
                    os.kill(pid, signal.SIGCONT)
                except (ProcessLookupError, OSError):
                    pass
                del stalled[pid]

    def _drain_handle(self, execution, h: _WorkerHandle,
                      detect: bool) -> bool:
        """Pump one worker's control pipe.  In ``detect`` mode (the live
        driving loop) a dead pipe or an ``("error", tb)`` message becomes
        a recorded failure for the restart policy; in teardown mode
        (``detect=False``, from ``stop_execution``) the worker is simply
        marked finished."""
        data = execution.backend_data
        supervisor = data.get("supervisor")
        progress = False
        try:
            while h.conn.poll(0):
                msg = h.conn.recv()
                op = msg[0]
                if op == "hb":
                    if supervisor is not None:
                        supervisor.heartbeat(h.key)
                    continue
                progress = True
                if op == "results":
                    by_name = data["by_name"]
                    for name, items in msg[1]:
                        sink = _sink_list(by_name[name].processor)
                        if sink is not None:
                            sink.extend(items)
                elif op == "ack":
                    if self._chaos_intercept_ack(execution, h.key,
                                                 msg[1], msg[2]):
                        continue
                    execution.ssctx.worker_ack(h.key, msg[1], msg[2])
                elif op == "done":
                    for name, stats in msg[1]:
                        _apply_stats(data["by_name"][name], stats)
                        if "source_start" in stats:
                            starts = data.setdefault("source_starts", {})
                            starts[name] = stats["source_start"]
                    h.done = True
                    data["done"].add(h.key)
                    execution.ssctx.worker_gone(h.key)
                elif op == "error":
                    # the child re-raises after shipping the traceback, so
                    # its exit is imminent; record the failure here (with
                    # the full traceback) instead of crashing the driver
                    h.alive = False
                    info = msg[2] if len(msg) > 2 else {}
                    if detect:
                        if supervisor is not None:
                            supervisor.mark_reported(h.key)
                        data["failures"].append(WorkerFailure(
                            FAILURE_ERROR, key=h.key, pid=h.proc.pid,
                            detail=f"worker {h.key} raised:\n{msg[1]}",
                            vertex=info.get("vertex"),
                            exc_type=info.get("exc_type"),
                            poison=info.get("poison")))
                    execution.ssctx.worker_gone(h.key, crashed=True)
        except (EOFError, OSError):
            # dead pipe: never raise — mark the handle dead and leave
            # classification to the supervisor's exitcode check (detect
            # mode) or mark the worker finished (teardown mode)
            h.alive = False
            if not h.done and not detect:
                h.done = True
                data["done"].add(h.key)
            execution.ssctx.worker_gone(h.key, crashed=not h.done)
        return progress

    def _chaos_intercept_ack(self, execution, key: Location,
                             snapshot_id: int, entries) -> bool:
        """Chaos seam for barrier acks: drop one ack on the floor (the
        snapshot must then abort via its deadline) or hold it for a
        while.  One-shot per injected fault; returns True if the ack was
        intercepted."""
        chaos = execution.backend_data.get("chaos_acks")
        if not chaos or key not in chaos:
            return False
        action, delay_s = chaos.pop(key)
        if action == "drop":
            return True
        execution.backend_data.setdefault("delayed_acks", []).append(
            (_time.monotonic() + delay_s, key, snapshot_id, entries))
        return True

    def execution_done(self, execution) -> bool:
        data = execution.backend_data
        if not data.get("started"):
            return False
        return len(data["done"]) >= len(data["workers"])

    # -- snapshot fan-out ----------------------------------------------------
    def broadcast(self, execution, message) -> Tuple[set, set]:
        """Send ``message`` to every live, not-yet-done worker.  Returns
        ``(reached, failed)``: keys the message reached, and keys of
        workers still owing work (not done) that could NOT be reached —
        dead pipe mid-send, or already marked dead.  A barrier broadcast
        with a non-empty ``failed`` set can never form a consistent
        snapshot (the unreached worker will never align) and must be
        aborted by the caller."""
        reached: set = set()
        failed: set = set()
        data = execution.backend_data
        if not data.get("started") or data.get("stopped"):
            return reached, failed
        for h in data["workers"].values():
            if h.done:
                continue
            if not h.alive:
                failed.add(h.key)
                continue
            try:
                h.conn.send(message)
                reached.add(h.key)
            except (BrokenPipeError, OSError):
                h.alive = False
                failed.add(h.key)
        return reached, failed

    def notify_snapshot_committed(self, execution, snapshot_id: int) -> None:
        # phase-2 fan-out: a worker that died between commit and this
        # notification is already handled by the failure path; nothing
        # to do about it here
        self.broadcast(execution, ("committed", snapshot_id))

    # -- chaos ---------------------------------------------------------------
    def inject_fault(self, execution, kind: str, worker_index: int = 0,
                     **params) -> bool:
        """Translate an abstract chaos fault into the realest failure this
        substrate can produce:

        * ``kill`` — SIGKILL the worker process (crash detection path);
        * ``stall`` — SIGSTOP it (hung detection path; ``duration_s``
          resumes it with SIGCONT, else it stays stopped until the
          supervisor SIGKILLs it or teardown resumes it);
        * ``raise`` — command the child to plant an exception inside a
          processor slice (error-exit path; ``tasklet``/``message``);
        * ``drop_ack`` / ``delay_ack`` — intercept the worker's next
          barrier ack in the coordinator (barrier timeout / late-ack
          paths; ``delay_s`` for the hold).
        """
        data = execution.backend_data
        if not data.get("started") or data.get("stopped"):
            return False
        live = [h for h in data["workers"].values()
                if h.alive and not h.done and h.proc.exitcode is None]
        if not live:
            return False
        h = sorted(live, key=lambda x: x.key)[worker_index % len(live)]
        if kind == "kill":
            try:
                os.kill(h.proc.pid, signal.SIGKILL)
            except (ProcessLookupError, OSError):  # pragma: no cover
                return False
            return True
        if kind == "stall":
            try:
                os.kill(h.proc.pid, signal.SIGSTOP)
            except (ProcessLookupError, OSError):  # pragma: no cover
                return False
            duration = params.get("duration_s")
            resume_at = (None if duration is None
                         else _time.monotonic() + duration)
            data.setdefault("stalled", {})[h.proc.pid] = resume_at
            return True
        if kind == "raise":
            try:
                h.conn.send(("chaos_raise", params.get("tasklet"),
                             params.get("message", "chaos[raise] injected")))
            except (BrokenPipeError, OSError):  # pragma: no cover
                return False
            return True
        if kind == "drop_ack":
            data.setdefault("chaos_acks", {})[h.key] = ("drop", None)
            return True
        if kind == "delay_ack":
            data.setdefault("chaos_acks", {})[h.key] = (
                "delay", params.get("delay_s", 0.5))
            return True
        return False

    # -- telemetry -----------------------------------------------------------
    def source_start(self, execution) -> Optional[float]:
        """Earliest paced-source schedule anchor across workers (shipped
        with the final stats); the latency benchmark's t0."""
        starts = execution.backend_data.get("source_starts")
        return min(starts.values()) if starts else None
