"""True multi-process execution: one OS process per cooperative worker.

:class:`MultiprocessBackend` implements the
:class:`~repro.core.backend.ExecutionBackend` contract by turning every
(node, cooperative-thread) pair of the planned execution into a real
``fork``-spawned worker process.  Edges between tasklets in the same
process stay plain :class:`~repro.core.queues.SPSCQueue`s; every edge that
crosses a process boundary — local threads of one JetNode as much as
cross-node links — becomes a fixed-capacity shared-memory
:class:`~repro.core.shm_ring.ShmRing` carrying EventBlock columns as raw
slabs plus a control lane for watermarks/barriers/scalar stragglers.

Coordination stays in the parent ("coordinator"), which never touches the
data plane:

* a duplex pipe per worker carries control: parent -> child ``("snapshot",
  id)`` / ``("committed", id)`` / ``("stop",)``; child -> parent
  ``("ack", id, entries)`` / ``("results", batch)`` / ``("done", stats)``
  / ``("error", traceback)``.
* the Chandy-Lamport protocol itself is unchanged — barriers flow through
  the rings exactly as through in-process queues; each worker aligns and
  snapshots its local tasklets, buffers the state entries, and ships them
  with its ack.  :class:`MpSnapshotContext` (parent side) completes the
  snapshot when every live worker acked, lands all entries in the
  IMap-backed store in one bulk write, commits, and broadcasts phase 2.
* ``kill_node`` / ``add_node`` keep their whole-job restart semantics:
  the backend tears every worker process down, the engine rebuilds and
  restores in the parent, and ``start_execution`` re-forks — children
  inherit the restored state, so exactly-once replay works unchanged.
* sink results (processors exposing an ``out`` list, e.g.
  :class:`~repro.core.sources.CollectorSink`) are shipped incrementally to
  the parent and merged into the parent-side processor's list, so tests
  and benchmarks observe results exactly as under the in-process backend.

Workers inherit the built execution via ``fork`` (no pickling of the DAG
or closures); only items crossing rings and control messages serialize.
"""

from __future__ import annotations

import multiprocessing
import time as _time
import traceback
from multiprocessing import connection as _mpc
from typing import Any, Dict, List, Optional, Tuple

from ..core.backend import ExecutionBackend, Location
from ..core.clock import Clock, VirtualClock
from ..core.queues import SPSCQueue
from ..core.shm_ring import DEFAULT_RING_BYTES, ShmRing
from ..core.tasklet import (CooperativeWorker, GUARANTEE_NONE,
                            SnapshotContext)
from ..state.snapshot_store import own_snapshot_value

_MP = multiprocessing.get_context("fork")

#: child idle backoff (spin -> yield -> park), mirroring the engine driver
_IDLE_SPIN_ITERS = 64
_IDLE_YIELD_ITERS = 192
_IDLE_PARK_MIN_S = 0.00005
_IDLE_PARK_MAX_S = 0.0005
#: how often a child ships new sink results to the coordinator
_RESULT_SHIP_S = 0.02
#: command-pipe poll cadence (iterations) while the child is busy
_CMD_POLL_ITERS = 32


class _BufferWriter:
    """Child-local SnapshotWriter stand-in: buffers entries until the ack
    ships them to the coordinator.  Values are copied at ``put`` time —
    the processor keeps mutating its live containers between its barrier
    and the worker-wide ack, and a buffered reference would ship the
    mutated state (see :func:`repro.state.snapshot_store
    .own_snapshot_value`)."""

    __slots__ = ("entries",)

    def __init__(self):
        self.entries: List[Tuple] = []

    def put(self, snapshot_id: int, vertex: str, key, value, pid: int,
            instance: int = 0) -> None:
        self.entries.append((snapshot_id, vertex, key,
                             own_snapshot_value(value), pid, instance))

    def take(self) -> List[Tuple]:
        entries, self.entries = self.entries, []
        return entries


def _sink_list(processor) -> Optional[list]:
    """The results list of a collector-style sink, if this processor is
    one (duck-typed: an ``out`` attribute holding a list)."""
    out = getattr(processor, "out", None)
    return out if isinstance(out, list) else None


def _tasklet_stats(tasklet) -> Dict[str, Any]:
    p = tasklet.processor
    inner = getattr(p, "inner", p)
    stats: Dict[str, Any] = {
        "items_in": tasklet.items_in, "items_out": tasklet.items_out,
        "calls": tasklet.calls, "idle_calls": tasklet.idle_calls,
    }
    for obj in (p, inner):
        if hasattr(obj, "late_dropped"):
            stats["late_dropped"] = obj.late_dropped
            break
    start = getattr(inner, "_start", None)
    if isinstance(start, float):
        stats["source_start"] = start
    return stats


def _apply_stats(tasklet, stats: Dict[str, Any]) -> None:
    tasklet.items_in = stats["items_in"]
    tasklet.items_out = stats["items_out"]
    tasklet.calls = stats["calls"]
    tasklet.idle_calls = stats["idle_calls"]
    if "late_dropped" in stats:
        p = tasklet.processor
        target = p if hasattr(p, "late_dropped") else getattr(p, "inner", p)
        target.late_dropped = stats["late_dropped"]


# --------------------------------------------------------------------------
# child side
# --------------------------------------------------------------------------

def _ship_results(conn, sinks) -> None:
    batch = []
    for entry in sinks:
        name, out, cursor = entry
        n = len(out)
        if n > cursor:
            batch.append((name, out[cursor:n]))
            entry[2] = n
    if batch:
        conn.send(("results", batch))


def _worker_main(execution, key: Location, conn) -> None:
    """Entry point of one worker process (runs post-fork; everything it
    needs — tasklets, queues, rings — arrived by inheritance)."""
    try:
        assignment = execution.backend_data["assignment"]
        tasklets = [t for t in execution.tasklets
                    if assignment[id(t)] == key]
        parent_ctx = execution.ssctx
        writer = _BufferWriter()
        local_ctx = SnapshotContext(parent_ctx.guarantee, writer)
        local_ctx.requested_id = parent_ctx.requested_id
        local_ctx.completed_id = parent_ctx.completed_id
        local_ctx.tasklets = tasklets

        def _acked(snapshot_id: int) -> None:
            conn.send(("ack", snapshot_id, writer.take()))

        local_ctx.on_complete = _acked
        worker = CooperativeWorker(f"n{key[0]}-w{key[1]}")
        for t in tasklets:
            t.ssctx = local_ctx
            worker.add(t)
        sinks = [[t.name, out, len(out)] for t in tasklets
                 for out in (_sink_list(t.processor),) if out is not None]

        idle_streak = 0
        done_sent = False
        last_ship = _time.monotonic()
        iters = 0
        while True:
            iters += 1
            if done_sent or not iters % _CMD_POLL_ITERS or idle_streak:
                while conn.poll(0):
                    cmd = conn.recv()
                    op = cmd[0]
                    if op == "snapshot":
                        local_ctx.begin(cmd[1])
                    elif op == "committed":
                        for t in tasklets:
                            hook = getattr(t.processor,
                                           "on_snapshot_committed", None)
                            if hook is not None:
                                hook(cmd[1])
                    elif op == "stop":
                        _ship_results(conn, sinks)
                        return
            progress = worker.run_iteration()
            now = _time.monotonic()
            if sinks and now - last_ship >= _RESULT_SHIP_S:
                _ship_results(conn, sinks)
                last_ship = now
            if not done_sent and all(t.is_done for t in tasklets):
                _ship_results(conn, sinks)
                conn.send(("done",
                           [(t.name, _tasklet_stats(t)) for t in tasklets]))
                done_sent = True
            if progress:
                idle_streak = 0
            elif done_sent:
                # data plane finished: block on the command pipe
                conn.poll(0.05)
            else:
                idle_streak += 1
                if idle_streak > _IDLE_YIELD_ITERS:
                    park = _IDLE_PARK_MIN_S * (
                        1 << min(idle_streak - _IDLE_YIELD_ITERS, 8))
                    _time.sleep(min(park, _IDLE_PARK_MAX_S))
                elif idle_streak > _IDLE_SPIN_ITERS:
                    _time.sleep(0)
    except (EOFError, BrokenPipeError, KeyboardInterrupt):
        pass
    except BaseException:
        try:
            conn.send(("error", traceback.format_exc()))
        except Exception:
            pass
        raise
    finally:
        try:
            conn.close()
        except Exception:
            pass


# --------------------------------------------------------------------------
# parent side
# --------------------------------------------------------------------------

class MpSnapshotContext(SnapshotContext):
    """Coordinator-side snapshot state: ``begin`` broadcasts to workers,
    completion needs an ack (with state entries) from every live worker;
    entries land in the snapshot store in one bulk write before commit."""

    __slots__ = ("backend", "execution", "store_writer", "_await",
                 "_entries")

    def __init__(self, guarantee: str, store_writer):
        super().__init__(guarantee, writer=None)
        self.backend: Optional["MultiprocessBackend"] = None
        self.execution = None
        self.store_writer = store_writer
        self._await: set = set()
        self._entries: List[Tuple] = []

    def begin(self, snapshot_id: int) -> None:
        self.requested_id = snapshot_id
        self._entries = []
        self._await = self.backend.broadcast(self.execution,
                                             ("snapshot", snapshot_id))
        self._maybe_complete()

    def worker_ack(self, key: Location, snapshot_id: int,
                   entries: List[Tuple]) -> None:
        if snapshot_id != self.requested_id:
            return
        self._entries.extend(entries)
        self._await.discard(key)
        self._maybe_complete()

    def worker_gone(self, key: Location) -> None:
        """A worker finished (or died) without acking; it can no longer
        contribute in-flight state — same as the in-process exempt rule."""
        if key in self._await:
            self._await.discard(key)
            self._maybe_complete()

    def _maybe_complete(self) -> None:
        if self.completed_id == self.requested_id or self._await:
            return
        if self.store_writer is not None and self._entries:
            self.store_writer.put_many(self._entries)
        self._entries = []
        self.completed_id = self.requested_id
        if self.on_complete is not None:
            self.on_complete(self.completed_id)


class _WorkerHandle:
    __slots__ = ("key", "proc", "conn", "alive", "done")

    def __init__(self, key: Location, proc, conn):
        self.key = key
        self.proc = proc
        self.conn = conn
        self.alive = True
        self.done = False


class MultiprocessBackend(ExecutionBackend):
    """Execution substrate running cooperative workers as OS processes
    over shared-memory rings (module docstring has the full protocol)."""

    name = "mp"

    def __init__(self, ring_bytes: int = DEFAULT_RING_BYTES):
        super().__init__()
        self.ring_bytes = ring_bytes

    def clock_supported(self, clock: Clock) -> bool:
        return not isinstance(clock, VirtualClock)

    # -- build time ----------------------------------------------------------
    def create_snapshot_context(self, job):
        writer = (self.cluster.snapshot_store.writer(job.id)
                  if job.config.processing_guarantee != GUARANTEE_NONE
                  else None)
        return MpSnapshotContext(job.config.processing_guarantee, writer)

    def make_transport(self, execution, edge, src: Location, dst: Location):
        if src == dst:
            return SPSCQueue(edge.queue_size)
        ring = ShmRing(self.ring_bytes)
        execution.backend_data.setdefault("rings", []).append(ring)
        return ring

    def assign_tasklet(self, execution, inst, tasklet) -> None:
        key = (inst.node,
               inst.local_index % self.cluster.cooperative_threads)
        data = execution.backend_data
        data.setdefault("assignment", {})[id(tasklet)] = key
        data.setdefault("by_worker", {}).setdefault(key, []).append(tasklet)

    # -- lifecycle -----------------------------------------------------------
    def start_execution(self, execution) -> None:
        data = execution.backend_data
        if data.get("started"):
            return
        for t in execution.tasklets:
            if t._poll_async is not None:
                raise NotImplementedError(
                    "device-offloaded vertices need the coordinator's "
                    "accelerator context; run them on backend='inproc'")
        ssctx = execution.ssctx
        ssctx.backend = self
        ssctx.execution = execution
        workers: Dict[Location, _WorkerHandle] = {}
        for key in sorted(data.get("by_worker", {})):
            parent_conn, child_conn = _MP.Pipe(duplex=True)
            proc = _MP.Process(target=_worker_main,
                               args=(execution, key, child_conn),
                               name=f"jet-n{key[0]}-w{key[1]}", daemon=True)
            proc.start()
            child_conn.close()
            workers[key] = _WorkerHandle(key, proc, parent_conn)
        data["workers"] = workers
        data["done"] = set()
        data["by_name"] = {t.name: t for t in execution.tasklets}
        data["started"] = True
        data["stopped"] = False

    def stop_execution(self, execution) -> None:
        data = execution.backend_data
        if not data.get("started") or data.get("stopped"):
            data["stopped"] = True
            return
        workers = data["workers"]
        for h in workers.values():
            if h.alive:
                try:
                    h.conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    h.alive = False
        deadline = _time.monotonic() + 5.0
        pending = list(workers.values())
        while pending and _time.monotonic() < deadline:
            still = []
            for h in pending:
                self._drain_handle(execution, h, raise_errors=False)
                h.proc.join(timeout=0.05)
                if h.proc.exitcode is None:
                    still.append(h)
            pending = still
        for h in pending:  # pragma: no cover - stuck worker safety net
            h.proc.terminate()
            h.proc.join(timeout=1.0)
        for h in workers.values():
            h.alive = False
            try:
                h.conn.close()
            except Exception:
                pass
        for ring in data.get("rings", ()):
            ring.unlink()
            ring.close()
        data["stopped"] = True

    def shutdown(self) -> None:
        pass    # per-execution teardown covers everything

    # -- driving -------------------------------------------------------------
    def step(self, jobs) -> bool:
        progress = False
        waitable = []
        for job in jobs:
            execution = job.execution
            if execution is None:
                continue
            data = execution.backend_data
            if not data.get("started") or data.get("stopped"):
                continue
            for h in data["workers"].values():
                if h.alive:
                    progress |= self._drain_handle(execution, h,
                                                   raise_errors=True)
                    if h.alive:
                        waitable.append(h.conn)
        if not progress and waitable:
            # nothing pending: block briefly on the control pipes instead
            # of burning the coordinator's core (the data plane lives in
            # the workers)
            _mpc.wait(waitable, timeout=0.002)
        return progress

    def _drain_handle(self, execution, h: _WorkerHandle,
                      raise_errors: bool) -> bool:
        data = execution.backend_data
        progress = False
        try:
            while h.conn.poll(0):
                msg = h.conn.recv()
                progress = True
                op = msg[0]
                if op == "results":
                    by_name = data["by_name"]
                    for name, items in msg[1]:
                        sink = _sink_list(by_name[name].processor)
                        if sink is not None:
                            sink.extend(items)
                elif op == "ack":
                    execution.ssctx.worker_ack(h.key, msg[1], msg[2])
                elif op == "done":
                    for name, stats in msg[1]:
                        _apply_stats(data["by_name"][name], stats)
                        if "source_start" in stats:
                            starts = data.setdefault("source_starts", {})
                            starts[name] = stats["source_start"]
                    h.done = True
                    data["done"].add(h.key)
                    execution.ssctx.worker_gone(h.key)
                elif op == "error":
                    h.alive = False
                    self.stop_execution(execution)
                    raise RuntimeError(
                        f"worker {h.key} failed:\n{msg[1]}")
        except (EOFError, OSError):
            h.alive = False
            if not h.done:
                if raise_errors and not data.get("stopped"):
                    self.stop_execution(execution)
                    raise RuntimeError(
                        f"worker {h.key} (pid {h.proc.pid}) exited "
                        f"unexpectedly (exitcode {h.proc.exitcode})")
                h.done = True
                data["done"].add(h.key)
            execution.ssctx.worker_gone(h.key)
        return progress

    def execution_done(self, execution) -> bool:
        data = execution.backend_data
        if not data.get("started"):
            return False
        return len(data["done"]) >= len(data["workers"])

    # -- snapshot fan-out ----------------------------------------------------
    def broadcast(self, execution, message) -> set:
        """Send ``message`` to every live, not-yet-done worker; returns the
        set of worker keys the message reached."""
        reached = set()
        data = execution.backend_data
        if not data.get("started") or data.get("stopped"):
            return reached
        for h in data["workers"].values():
            if h.alive and not h.done:
                try:
                    h.conn.send(message)
                    reached.add(h.key)
                except (BrokenPipeError, OSError):
                    h.alive = False
        return reached

    def notify_snapshot_committed(self, execution, snapshot_id: int) -> None:
        self.broadcast(execution, ("committed", snapshot_id))

    # -- telemetry -----------------------------------------------------------
    def source_start(self, execution) -> Optional[float]:
        """Earliest paced-source schedule anchor across workers (shipped
        with the final stats); the latency benchmark's t0."""
        starts = execution.backend_data.get("source_starts")
        return min(starts.values()) if starts else None
