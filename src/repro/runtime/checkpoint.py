"""Checkpointing: disk tier + IMDG (in-memory, replicated) tier.

Jet stores snapshots exclusively in replicated RAM (paper §4.2); for a
1000+-node training fleet we keep that as the fast tier (restores after
single-node failures never touch disk) and add an asynchronous disk tier
for whole-job restarts.  Both are exposed through one manager:

* ``save(state, step)`` — writes the disk checkpoint (optionally in a
  background thread so serialization overlaps the next step — the
  standard async-checkpoint trick) and/or the IMap tier.
* two-phase commit: data files first, then an atomic ``COMMIT`` marker;
  ``latest_step`` only trusts committed checkpoints (a torn write is
  invisible, mirroring the snapshot store's commit protocol).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..state import IMap, IMapService


def _flatten(state) -> List[Tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 2,
                 async_save: bool = False,
                 imap_service: Optional[IMapService] = None):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self.imap_service = imap_service
        self._thread: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------
    def save(self, state, step: int) -> None:
        flat = _flatten(state)       # device->host copy happens here
        if self.async_save:
            self.wait()              # at most one in-flight save
            self._thread = threading.Thread(
                target=self._write, args=(flat, step), daemon=True)
            self._thread.start()
        else:
            self._write(flat, step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, flat, step: int) -> None:
        d = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{n: a for n, a in flat})
        (tmp / "meta.json").write_text(json.dumps({"step": step}))
        (tmp / "COMMIT").write_text("ok")
        if d.exists():
            shutil.rmtree(d)
        tmp.rename(d)
        if self.imap_service is not None:
            imap = IMap(self.imap_service, f"__ckpt.{step}")
            for n, a in flat:
                imap.put(n, a)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
            if self.imap_service is not None:
                IMap(self.imap_service, f"__ckpt.{s}").destroy()

    # -- restore -------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: Optional[int] = None):
        """Restore into the structure (and shardings) of ``state_like``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        arrays = np.load(self.dir / f"step_{step:010d}" / "arrays.npz")
        flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
        new_leaves = []
        for path, leaf in flat:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            a = arrays[name]
            if hasattr(leaf, "sharding") and hasattr(leaf, "shape"):
                new_leaves.append(
                    jax.device_put(a.astype(leaf.dtype), leaf.sharding))
            else:
                new_leaves.append(jax.numpy.asarray(a, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_like), new_leaves)

    def restore_from_imap(self, state_like, step: int):
        """Fast tier: rebuild from the replicated in-memory copy (survives
        node loss via IMap backup promotion)."""
        assert self.imap_service is not None
        imap = IMap(self.imap_service, f"__ckpt.{step}")
        flat, _ = jax.tree_util.tree_flatten_with_path(state_like)
        new_leaves = []
        for path, leaf in flat:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            a = imap.get(name)
            assert a is not None, f"missing {name} in IMap checkpoint"
            new_leaves.append(jax.numpy.asarray(a, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_like), new_leaves)
