"""Training/serving runtime: optimizer, data pipeline, checkpointing,
distributed-optimization tricks."""

from .optimizer import AdamW

__all__ = ["AdamW"]
