"""Seeded chaos layer: deterministic fault injection for self-healing
tests and the chaos soak harness (``benchmarks/bench_chaos.py``).

The layer has three pieces, kept deliberately small:

* :class:`Fault` — one planned fault: *what* to inject (an abstract kind
  the backend translates: ``kill`` / ``stall`` / ``raise`` /
  ``drop_ack`` / ``delay_ack``), *where* (a worker index, resolved
  deterministically by the backend against its sorted live workers) and
  *when* — expressed as a **logical** trigger (the sink has produced at
  least ``at_result`` results, and at least one snapshot has committed —
  or, for the ack kinds, is at least in flight), not a wall-clock
  instant, so the same schedule hits comparable points of the
  computation on any substrate and at any machine speed.
* :class:`ChaosSchedule` — an ordered list of faults, either hand-built
  or derived entirely from an integer seed (:meth:`ChaosSchedule
  .from_seed`), so a failing run is reproduced by its seed alone.
* :class:`ChaosController` — the driver-loop hook: call :meth:`tick`
  once per scheduler iteration; it fires the next due fault through
  ``backend.inject_fault`` and records *when* it fired (wall clock and
  result count) for recovery-gap measurement.  Kinds a substrate cannot
  express (``inject_fault`` returning False — e.g. ``stall`` in-process)
  are recorded as skipped and the schedule moves on, so one schedule
  runs everywhere.

Faults only fire while the job is RUNNING — injecting into a job that is
already tearing down or backing off for a restart would chaos-test the
chaos layer, not the engine.
"""

from __future__ import annotations

import random
import time as _time
from typing import Dict, List, Optional, Sequence

from ..core.engine import JOB_RUNNING

#: fault kinds every schedule may draw from; backends translate each into
#: the realest failure they can produce and veto the rest
KIND_KILL = "kill"
KIND_STALL = "stall"
KIND_RAISE = "raise"
KIND_DROP_ACK = "drop_ack"
KIND_DELAY_ACK = "delay_ack"
ALL_KINDS = (KIND_KILL, KIND_STALL, KIND_RAISE, KIND_DROP_ACK,
             KIND_DELAY_ACK)

#: snapshot-corruption kinds: these hit the durable snapshot store on
#: disk (the chain head at fire time), not a worker — they are injected
#: by the controller itself, so they work on every backend but need a
#: :class:`~repro.state.durable_store.DurableSnapshotStore`
KIND_CORRUPT_FLIP = "corrupt_flip"          # XOR one byte of a segment
KIND_CORRUPT_TRUNCATE = "corrupt_truncate"  # cut a segment file short
KIND_CORRUPT_MANIFEST = "corrupt_manifest"  # delete the manifest
CORRUPTION_KINDS = (KIND_CORRUPT_FLIP, KIND_CORRUPT_TRUNCATE,
                    KIND_CORRUPT_MANIFEST)


class Fault:
    """One planned fault (see module docstring for trigger semantics)."""

    __slots__ = ("kind", "at_result", "worker_index", "params",
                 "fired", "skipped", "fired_at", "fired_at_result")

    def __init__(self, kind: str, at_result: int, worker_index: int = 0,
                 params: Optional[Dict] = None):
        self.kind = kind
        self.at_result = at_result
        self.worker_index = worker_index
        self.params = params or {}
        self.fired = False
        #: True when the substrate could not express the kind
        self.skipped = False
        self.fired_at: Optional[float] = None
        self.fired_at_result: Optional[int] = None

    def __repr__(self):
        state = ("fired" if self.fired else
                 "skipped" if self.skipped else "pending")
        return (f"Fault({self.kind}@{self.at_result}"
                f"+w{self.worker_index}, {state})")


class ChaosSchedule:
    """An ordered fault plan.  ``from_seed`` derives the whole plan —
    kinds, injection points, target workers — from one integer, which is
    all a failing run needs to be replayed."""

    __slots__ = ("faults", "seed")

    def __init__(self, faults: Sequence[Fault], seed: Optional[int] = None):
        self.faults = sorted(faults, key=lambda f: f.at_result)
        self.seed = seed

    @classmethod
    def from_seed(cls, seed: int, n_faults: int, total_results: int,
                  kinds: Sequence[str] = ALL_KINDS,
                  lo_frac: float = 0.1, hi_frac: float = 0.7,
                  stall_duration_s: float = 0.5,
                  ack_delay_s: float = 0.3) -> "ChaosSchedule":
        """Derive ``n_faults`` faults spread over the logical interval
        ``[lo_frac, hi_frac] * total_results`` (the tail is left quiet so
        every fault has room to recover inside the run)."""
        rng = random.Random(seed)
        lo = max(1, int(total_results * lo_frac))
        hi = max(lo + 1, int(total_results * hi_frac))
        points = sorted(rng.sample(range(lo, hi), min(n_faults, hi - lo)))
        # cycle the kinds in a seed-shuffled order: n_faults >= len(kinds)
        # guarantees every kind fires at least once per schedule
        order = list(kinds)
        rng.shuffle(order)
        faults = []
        for i, at in enumerate(points):
            kind = order[i % len(order)]
            params: Dict = {}
            if kind == KIND_STALL:
                params["duration_s"] = stall_duration_s
            elif kind == KIND_DELAY_ACK:
                params["delay_s"] = ack_delay_s
            faults.append(Fault(kind, at,
                                worker_index=rng.randrange(0, 1 << 16),
                                params=params))
        return cls(faults, seed=seed)

    @classmethod
    def corruption_from_seed(cls, seed: int, n_faults: int,
                             total_results: int,
                             kinds: Sequence[str] = CORRUPTION_KINDS,
                             lo_frac: float = 0.15,
                             hi_frac: float = 0.7) -> "ChaosSchedule":
        """Corruption plan: each corruption fault is immediately chased
        by a ``kill`` at the same logical point, so the very next
        recovery must restore *through* the snapshot that was just
        corrupted — forcing the verified-fallback path rather than
        letting a later commit quietly replace the damaged head.  The
        controller fires both back-to-back within one tick (no commit
        can slip between them)."""
        rng = random.Random(seed)
        lo = max(1, int(total_results * lo_frac))
        hi = max(lo + 1, int(total_results * hi_frac))
        points = sorted(rng.sample(range(lo, hi), min(n_faults, hi - lo)))
        order = list(kinds)
        rng.shuffle(order)
        faults = []
        for i, at in enumerate(points):
            faults.append(Fault(order[i % len(order)], at,
                                worker_index=rng.randrange(0, 1 << 16)))
            faults.append(Fault(KIND_KILL, at,
                                worker_index=rng.randrange(0, 1 << 16)))
        return cls(faults, seed=seed)

    def pending(self) -> Optional[Fault]:
        for f in self.faults:
            if not f.fired and not f.skipped:
                return f
        return None

    @property
    def done(self) -> bool:
        return self.pending() is None

    def fired(self) -> List[Fault]:
        return [f for f in self.faults if f.fired]


def corrupt_snapshot(store, job_id: str, snapshot_id: int, kind: str,
                     index: int = 0) -> bool:
    """Damage one on-disk snapshot the way real storage does: flip a byte
    mid-segment, truncate a segment, or lose the manifest.  ``store``
    must expose the durable path helpers
    (:class:`~repro.state.durable_store.DurableSnapshotStore`).  Returns
    False when the damage could not be applied."""
    if kind == KIND_CORRUPT_MANIFEST:
        try:
            store.manifest_path(job_id, snapshot_id).unlink()
            return True
        except OSError:
            return False
    segs = store.segment_paths(job_id, snapshot_id)
    if not segs:
        return False
    path = segs[index % len(segs)]
    try:
        size = path.stat().st_size
        if kind == KIND_CORRUPT_FLIP:
            if size == 0:
                return False
            with open(path, "r+b") as f:
                f.seek(size // 2)
                byte = f.read(1)
                f.seek(size // 2)
                f.write(bytes([byte[0] ^ 0xFF]))
            return True
        if kind == KIND_CORRUPT_TRUNCATE:
            with open(path, "r+b") as f:
                f.truncate(max(0, size // 2))
            return True
    except OSError:
        return False
    return False


class ChaosController:
    """Fires a :class:`ChaosSchedule` into one job from the driver loop.

    ``sink`` is the results list whose length is the logical clock
    (``Fault.at_result`` triggers); ``require_snapshot`` gates disruptive
    kinds (kill/stall/raise) until the first snapshot committed, so a
    kill always exercises the restore path rather than a from-scratch
    replay.  Ack faults (drop/delay) instead gate on a barrier being *in
    flight* (``ssctx.requested_id >= 1``): a commit is exactly what they
    sabotage, and on a slow or loaded machine the first commit may never
    beat its own ack deadline — waiting for it would mean the fault
    never fires at all."""

    __slots__ = ("cluster", "job", "sink", "schedule", "require_snapshot",
                 "log")

    def __init__(self, cluster, job, sink: list, schedule: ChaosSchedule,
                 require_snapshot: bool = True):
        self.cluster = cluster
        self.job = job
        self.sink = sink
        self.schedule = schedule
        self.require_snapshot = require_snapshot
        #: chronological record of fired/skipped faults (the harness's
        #: ground truth for recovery-gap attribution)
        self.log: List[Fault] = []

    def tick(self) -> bool:
        """Fire the next due fault, if any.  Returns True when a fault
        was injected this call.

        A fired *corruption* fault keeps the loop going, so the fault
        scheduled at the same logical point (its paired ``kill``) lands
        in the same tick: no ``cluster.step()`` — and therefore no
        commit that would replace the corrupted chain head — can run
        between the damage and the failure that must recover through
        it."""
        fired_any = False
        while True:
            fault = self.schedule.pending()
            if fault is None:
                return fired_any
            job = self.job
            if job.status != JOB_RUNNING or job.execution is None:
                return fired_any
            if len(self.sink) < fault.at_result:
                return fired_any
            if fault.kind in CORRUPTION_KINDS:
                injected = self._inject_store_fault(fault)
                if injected is None:
                    # no committed chain head yet: stay pending
                    return fired_any
            else:
                if self.require_snapshot and job.snapshots_taken < 1:
                    ssctx = getattr(job.execution, "ssctx", None)
                    barrier_inflight = (
                        fault.kind in (KIND_DROP_ACK, KIND_DELAY_ACK)
                        and getattr(ssctx, "requested_id", 0) >= 1)
                    if not barrier_inflight:
                        return fired_any
                injected = self.cluster.backend.inject_fault(
                    job.execution, fault.kind, fault.worker_index,
                    **fault.params)
            if not injected:
                fault.skipped = True
                self.log.append(fault)
                return fired_any
            fault.fired = True
            fault.fired_at = _time.monotonic()
            fault.fired_at_result = len(self.sink)
            self.log.append(fault)
            fired_any = True
            if fault.kind not in CORRUPTION_KINDS:
                return fired_any

    def _inject_store_fault(self, fault: Fault):
        """Corrupt the durable chain head on disk.  True = injected,
        False = the store cannot express the kind (skipped), None = no
        committed chain head yet (fault stays pending)."""
        store = getattr(self.cluster, "snapshot_store", None)
        if not hasattr(store, "segment_paths"):
            return False
        chain = store.recovery_chain(self.job.id)
        if not chain:
            return None
        sid = chain[0]
        ok = corrupt_snapshot(store, self.job.id, sid, fault.kind,
                              index=fault.worker_index)
        if ok:
            # record the victim epoch for recovery-gap attribution
            fault.params["snapshot_id"] = sid
        return ok
