"""Training data pipeline: a *replayable source* in the paper's sense.

Batches are a pure function of the step index (seeded splitmix), so a
restart from checkpoint step N regenerates exactly the batches N+1, N+2...
— the data pipeline participates in exactly-once recovery the same way a
Jet replayable source does (§4.5).  ``Prefetcher`` double-buffers batch
construction on a host thread so ingestion overlaps device compute (the
host-side analogue of Jet's dedicated non-cooperative source threads).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class SyntheticLMData:
    """Deterministic synthetic token stream (zipf-ish unigram mix)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, embed_dim: Optional[int] = None):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.embed_dim = embed_dim   # vlm stub: emit embeddings instead

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step)
                                    % (2**31 - 1))
        tokens = rng.randint(0, self.vocab_size,
                             size=(self.batch, self.seq_len + 1),
                             dtype=np.int32)
        if self.embed_dim:
            embeds = rng.randn(self.batch, self.seq_len,
                               self.embed_dim).astype(np.float32)
            return {"embeds": embeds, "labels": tokens[:, 1:]}
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class Prefetcher:
    """Host-thread double buffering: build batch N+1 while N computes."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop:
            batch = self.source.batch_at(step)
            self._q.put((step, batch))
            step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
