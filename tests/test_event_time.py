"""Event-time completeness (PR 3): session windows, allowed lateness, late
side outputs, and ordered-vs-disordered equivalence on the host engine.

The core property: a bounded-disorder stream produces IDENTICAL window
results to its sorted counterpart whenever the watermark lag covers the
skew — and events later than the allowed lateness are dropped deliberately,
exactly counted, and routed to the late side output when one is wired.
"""

import numpy as np
import pytest

from repro.core import (CollectorSink, GUARANTEE_EXACTLY_ONCE, JetCluster,
                        JobConfig, Journal, JournalSource, LateEvent,
                        PacedGeneratorSource, Pipeline, SessionResult,
                        VirtualClock, counting, session, sliding, summing,
                        tumbling)
from repro.core.engine import JOB_COMPLETED
from repro.core.events import Event, Watermark
from repro.core.processor import Inbox, Outbox, ProcessorContext
from repro.core.watermark import EventTimePolicy
from repro.core.window import (AccumulateByFrameProcessor,
                               SessionWindowProcessor, SessionWindowDef)
from repro.nexmark import DisorderedNexmarkGenerator, NexmarkGenerator, queries
from repro.nexmark.model import Bid


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


def run_job(pipeline, n_nodes=1, threads=2):
    cluster = JetCluster(n_nodes=n_nodes, cooperative_threads=threads,
                         clock=VirtualClock())
    job = cluster.submit(pipeline.to_dag())
    cluster.run_until_complete(job)
    return job


def journal_of(events, n_partitions=1):
    """1 partition by default: the journal merge-read picks the min-ts head
    across partitions, which would partially re-sort a disordered stream."""
    j = Journal(n_partitions=n_partitions)
    j.extend(events)
    return j


def session_oracle(events, gap):
    """key -> list of (start, end, count): sort per key, split on gaps."""
    by_key = {}
    for ts, key, _v in events:
        by_key.setdefault(key, []).append(ts)
    out = {}
    for key, tss in by_key.items():
        tss.sort()
        sessions = []
        start, last = tss[0], tss[0]
        n = 1
        for ts in tss[1:]:
            if ts - last < gap:
                last, n = ts, n + 1
            else:
                sessions.append((start, last + gap, n))
                start, last, n = ts, ts, 1
        sessions.append((start, last + gap, n))
        out[key] = sessions
    return out


def sliding_count_oracle(events, size, slide):
    expect = {}
    for ts, key, _v in events:
        fw = (ts // slide + 1) * slide
        for w in range(fw, fw + size, slide):
            expect[(w, key)] = expect.get((w, key), 0) + 1
    return expect


# ---------------------------------------------------------------------------
# session windows: end-to-end correctness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_nodes", [1, 2])
def test_session_windows_match_oracle(n_nodes):
    rng = np.random.RandomState(3)
    events = []
    t = 0
    for _ in range(300):
        t += int(rng.randint(1, 40))
        events.append((t, int(rng.randint(0, 5)), 1))
    gap = 60
    out = []
    p = Pipeline.create()
    keyed = [(ts, k, k) for ts, k, _ in events]
    (p.read_from(lambda: JournalSource(journal_of(keyed, 4)), name="src")
       .with_key(lambda v: v)
       .window(session(gap))
       .aggregate(counting())
       .write_to(lambda: CollectorSink(out)))
    run_job(p, n_nodes)
    got = {}
    for ev in out:
        sr = ev.value
        assert isinstance(sr, SessionResult)
        got.setdefault(sr.key, []).append(
            (sr.window_start, sr.window_end, sr.value))
    oracle = session_oracle([(ts, k, k) for ts, k, _ in events], gap)
    for key in oracle:
        assert sorted(got[key]) == sorted(oracle[key]), key
    assert set(got) == set(oracle)


def test_session_results_emitted_incrementally_by_watermark():
    """A session closes when the watermark passes its end — the result must
    not wait for end-of-stream."""
    proc = SessionWindowProcessor(SessionWindowDef(10), counting())
    outbox = Outbox()
    proc.init(outbox, ProcessorContext("s", 0, 0, 1, 0, 1, ()))
    inbox = Inbox()
    inbox.extend([Event(0, "a", 1), Event(5, "a", 1), Event(40, "a", 1)])
    proc.process(0, inbox)
    assert proc.try_process_watermark(Watermark(30))
    emitted = outbox.drain()
    assert len(emitted) == 1
    sr = emitted[0].value
    assert (sr.window_start, sr.window_end, sr.value) == (0, 15, 2)
    # the open session at ts=40 flushes on complete
    assert proc.complete()
    tail = outbox.drain()
    assert [(e.value.window_start, e.value.window_end, e.value.value)
            for e in tail] == [(40, 50, 1)]


# ---------------------------------------------------------------------------
# the disorder equivalence property (the paper's out-of-order claim)
# ---------------------------------------------------------------------------


def _q5_windows(journal, wm_lag, window_ms=100, slide_ms=20):
    out = []
    p = queries.q5(lambda: JournalSource(journal, wm_lag=wm_lag),
                   lambda: CollectorSink(out),
                   window_ms=window_ms, slide_ms=slide_ms)
    run_job(p, n_nodes=2)
    return {(ev.value.window_end, ev.value.key): ev.value.value
            for ev in out}


@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_q5_disordered_equals_ordered(seed):
    """Acceptance: Q5 on bounded-disorder input (skew <= watermark lag)
    produces identical window results to the ordered input."""
    rate = 10_000
    skew_ms = 40
    gen = NexmarkGenerator(rate=rate, n_keys=30)
    dis = DisorderedNexmarkGenerator(gen, max_skew_ms=skew_ms, seed=seed)
    n = 7 * dis.block              # whole blocks: exact permutation
    ordered = [gen(i) for i in range(n)]
    shuffled = [dis(i) for i in range(n)]
    assert sorted(map(repr, ordered)) == sorted(map(repr, shuffled)), \
        "bounded shuffle must be a permutation"
    assert ordered != shuffled, "disorder mode must actually disorder"
    # skew bound: event at emission slot i carries a timestamp at most
    # max_skew_ms behind the running maximum
    top = -1 << 60
    for ts, _k, _v in shuffled:
        assert top - ts <= skew_ms
        top = max(top, ts)
    got_o = _q5_windows(journal_of(ordered), wm_lag=0)
    got_d = _q5_windows(journal_of(shuffled), wm_lag=skew_ms)
    assert got_o == got_d
    assert got_o == sliding_count_oracle(
        [(ts, k, v) for ts, k, v in ordered if isinstance(v, Bid)], 100, 20)


@pytest.mark.parametrize("seed", [0, 7])
def test_q11_sessions_disordered_equals_ordered(seed):
    rate = 10_000
    skew_ms = 60
    gen = NexmarkGenerator(rate=rate, n_keys=10)
    dis = DisorderedNexmarkGenerator(gen, max_skew_ms=skew_ms, seed=seed)
    n = 4 * dis.block              # whole blocks: exact permutation
    ordered = [gen(i) for i in range(n)]
    shuffled = [dis(i) for i in range(n)]

    def run(events, lag):
        out = []
        p = queries.q11(lambda: JournalSource(journal_of(events),
                                              wm_lag=lag),
                        lambda: CollectorSink(out), gap_ms=25)
        run_job(p, n_nodes=2)
        return sorted((ev.value.key, ev.value.window_start,
                       ev.value.window_end, ev.value.value) for ev in out)

    got_o = run(ordered, 0)
    got_d = run(shuffled, skew_ms)
    assert got_o == got_d
    bids = [(v.ts, v.bidder, 1) for _t, _k, v in ordered
            if isinstance(v, Bid)]
    oracle = session_oracle(bids, 25)
    assert got_o == sorted((k, s, e, c) for k, ss in oracle.items()
                           for s, e, c in ss)


def test_paced_generator_disordered_equals_ordered():
    """Same property through the paced source (the benchmark datapath)."""
    rate = 50_000
    skew_ms = 10
    gen = NexmarkGenerator(rate=rate, n_keys=20)
    dis = DisorderedNexmarkGenerator(gen, max_skew_ms=skew_ms, seed=11)
    n = 3 * dis.block              # whole blocks: exact permutation

    def run(g, lag):
        out = []
        p = queries.q5(
            lambda: PacedGeneratorSource(g, rate=rate, max_events=n,
                                         wm_lag=lag),
            lambda: CollectorSink(out), window_ms=20, slide_ms=5)
        run_job(p)
        return {(ev.value.window_end, ev.value.key): ev.value.value
                for ev in out}

    assert run(gen, 0) == run(dis, skew_ms)


# ---------------------------------------------------------------------------
# allowed lateness: re-fires, deliberate drops, late side output
# ---------------------------------------------------------------------------


def _late_pipeline(events, wm_lag, lateness, late_out, out,
                   size=10, slide=10):
    p = Pipeline.create()
    (p.read_from(lambda: JournalSource(journal_of(events), wm_lag=wm_lag),
                 name="src")
       .with_key(lambda v: v[0])
       .window(sliding(size, slide))
       .allowed_lateness(lateness)
       .late_sink(lambda: CollectorSink(late_out))
       .aggregate(summing(lambda ev: ev.value[1]))
       .write_to(lambda: CollectorSink(out)))
    return p


def test_too_late_events_dropped_and_side_routed_exactly():
    # emission order: ts 5 and 20 open/close frame [0,10); 7 and 3 are then
    # 13+ behind the watermark (20) — too late for lateness 0
    events = [(5, "a", ("a", 1)), (20, "a", ("a", 2)), (7, "a", ("a", 4)),
              (3, "a", ("a", 8)), (25, "a", ("a", 16))]
    out, late_out = [], []
    run_job(_late_pipeline(events, 0, 0, late_out, out))
    got = {(ev.value.window_end, ev.value.key): ev.value.value for ev in out}
    # frame [0,10) fired with only the on-time event; late ones dropped
    assert got[(10, "a")] == 1
    assert got[(30, "a")] == 2 + 16
    late = sorted((ev.ts, ev.value[1]) for ev in late_out)
    assert late == [(3, 8), (7, 4)]
    assert all(isinstance(ev, LateEvent) for ev in late_out)


def test_admissible_late_event_refires_updated_window():
    # lateness 15 keeps frame [0,10) re-firable until wm >= 25
    events = [(5, "a", ("a", 1)), (20, "a", ("a", 2)), (7, "a", ("a", 4)),
              (40, "a", ("a", 8))]
    out, late_out = [], []
    # threads=1: with parallel accumulate instances the combiner's
    # COALESCED watermark lags the data, so the delta may merge before the
    # first firing (correct final value, fewer speculative firings) — a
    # single-instance topology makes the two-firing sequence deterministic
    run_job(_late_pipeline(events, 0, 15, late_out, out), threads=1)
    assert late_out == []
    fires = [ev.value.value for ev in out if ev.value.window_end == 10]
    # first firing without the late event, re-fire with it
    assert fires == [1, 5]
    # final state of every window is exact
    final = {}
    for ev in out:
        final[(ev.value.window_end, ev.value.key)] = ev.value.value
    assert final[(10, "a")] == 5
    assert final[(30, "a")] == 2
    assert final[(50, "a")] == 8


def test_session_late_drop_and_refire():
    gap, lateness = 15, 20
    # session [30,50) fires at wm=55; the late ts=40 (admissible: >= 55-20)
    # merges into the RETAINED emitted session and re-fires it extended to
    # [30,55) with the updated count; ts=5 is behind the lateness horizon
    events = [(30, "a", "a"), (35, "a", "a"), (55, "a", "a"),
              (40, "a", "a"), (90, "a", "a"), (5, "a", "a")]
    out, late_out = [], []
    p = Pipeline.create()
    (p.read_from(lambda: JournalSource(journal_of(events)), name="src")
       .with_key(lambda v: v)
       .window(session(gap))
       .allowed_lateness(lateness)
       .late_sink(lambda: CollectorSink(late_out))
       .aggregate(counting())
       .write_to(lambda: CollectorSink(out)))
    run_job(p)
    assert [(ev.ts, ev.value) for ev in late_out] == [(5, "a")]
    assert all(isinstance(ev, LateEvent) for ev in late_out)
    results = [(ev.value.window_start, ev.value.window_end, ev.value.value)
               for ev in out]
    assert (30, 50, 2) in results         # first firing, on time
    assert (30, 55, 3) in results         # re-fire: merged late event
    assert (55, 70, 1) in results         # 40 vs 55: separation == gap
    assert (90, 105, 1) in results
    assert len(results) == 4


def test_q5_late_drop_counts_exact_under_disorder_seed():
    """Acceptance: with a watermark lag SMALLER than the disorder skew,
    some events arrive behind the watermark — their count and identity
    must match an independent replay of the watermark policy exactly, and
    the window results must equal the oracle over the admitted events."""
    from repro.core.events import MIN_TIME as MINT

    rate, skew_ms, lag = 10_000, 80, 20
    gen = NexmarkGenerator(rate=rate, n_keys=15)
    dis = DisorderedNexmarkGenerator(gen, max_skew_ms=skew_ms, seed=5)
    n = 4 * dis.block
    emission = [dis(i) for i in range(n)]

    # independent oracle: walk the emission order replaying the policy
    # (the source observes EVERY event — the bid filter is fused after)
    policy = EventTimePolicy(lag=lag)
    wm = MINT
    dropped, admitted = [], []
    slide, size = 20, 100
    for ts, key, v in emission:
        if isinstance(v, Bid):
            fts = (ts // slide + 1) * slide
            if fts <= wm:
                dropped.append((ts, v.auction))
            else:
                admitted.append((ts, v.auction, v))
        new = policy.observe(ts)
        if new is not None:
            wm = new
    assert dropped, "scenario must actually produce late events"

    out, late_out = [], []
    p = Pipeline.create()
    (p.read_from(lambda: JournalSource(journal_of(emission),
                                       wm_lag=lag), name="bids")
       .filter(lambda v: isinstance(v, Bid))
       .with_key(lambda b: b.auction)
       .window(sliding(size, slide))
       .late_sink(lambda: CollectorSink(late_out))
       .aggregate(counting())
       .write_to(lambda: CollectorSink(out)))
    # single instance: the oracle's watermark replay assumes ONE source
    # subsequence (instances split the journal's partitions)
    run_job(p, n_nodes=1, threads=1)
    assert sorted((ev.ts, ev.key) for ev in late_out) == sorted(dropped)
    got = {(ev.value.window_end, ev.value.key): ev.value.value for ev in out}
    assert got == sliding_count_oracle(admitted, size, slide)


def test_late_frame_beyond_keys_max_frame_still_fires():
    """A key whose emission front was dragged ahead by OTHER keys'
    activity receives an admissible late frame newer than anything it has
    seen: the window fired empty, so the result must come out as a
    re-fire, not be swallowed by the last_emitted guard."""
    events = [(5, "b", ("b", 1)),     # b: frame [0,10)
              (50, "a", ("a", 1)),    # wm -> 50; windows <= 50 fire
              (35, "b", ("b", 1)),    # admissible (>= 50-30), frame [30,40)
              (70, "a", ("a", 1))]    # wm -> 70; flush the late delta
    out, late_out = [], []
    run_job(_late_pipeline(events, 0, 30, late_out, out), threads=1)
    assert late_out == []
    got = {(ev.value.window_end, ev.value.key): ev.value.value for ev in out}
    assert got[(40, "b")] == 1
    assert got[(10, "b")] == 1


def test_watermark_not_swallowed_by_backpressured_late_event():
    """A watermark arriving while a backpressured LateEvent sits in the
    emit buffer must still close its frames (regression: the old buffer
    guard drained and returned True, forwarding the watermark AHEAD of the
    frames it closes — with lateness 0 those counts were lost)."""
    from repro.core.window import tumbling as _tumbling

    proc = AccumulateByFrameProcessor(_tumbling(10), counting(),
                                      late_output=True)
    outbox = Outbox(batch_limit=1)
    proc.init(outbox, ProcessorContext("a", 0, 0, 1, 0, 1, ()))
    ib = Inbox()
    ib.extend([Event(5, "k", 1)])
    proc.process(0, ib)
    assert proc.try_process_watermark(Watermark(30))
    outbox.drain()
    # two too-late events: the first fills the 1-slot outbox, the second
    # lands in the emit buffer; plus one fresh on-time event
    ib.extend([Event(2, "k", 1), Event(3, "k", 1), Event(35, "k", 1)])
    proc.process(0, ib)
    assert proc.late_dropped == 2
    drained = outbox.drain()
    done = proc.try_process_watermark(Watermark(50))
    drained += outbox.drain()
    while not done:
        done = proc.try_process_watermark(Watermark(50))
        drained += outbox.drain()
    assert proc._last_wm == 50
    closed = [ev.value for ev in drained
              if not isinstance(ev, LateEvent)]
    assert closed == [(40, 1)], closed   # frame [30,40) closed at wm 50
    assert sorted(ev.ts for ev in drained
                  if isinstance(ev, LateEvent)) == [2, 3]


def test_accumulate_processor_counts_late_drops():
    proc = AccumulateByFrameProcessor(tumbling(10), counting())
    proc.init(Outbox(), ProcessorContext("a", 0, 0, 1, 0, 1, ()))
    inbox = Inbox()
    inbox.extend([Event(5, "k", 1)])
    proc.process(0, inbox)
    assert proc.try_process_watermark(Watermark(30))
    inbox.extend([Event(7, "k", 1), Event(3, "k", 1), Event(35, "k", 1)])
    proc.process(0, inbox)
    assert proc.late_dropped == 2


# ---------------------------------------------------------------------------
# sessions x exactly-once: snapshot -> node failure -> restore
# ---------------------------------------------------------------------------


def test_session_windows_exactly_once_after_node_failure():
    rng = np.random.RandomState(9)
    events = []
    t = 0
    for _ in range(400):
        t += int(rng.randint(1, 12))
        events.append((t, int(rng.randint(0, 5)), 1))
    gap = 30
    out = []
    journal = Journal(n_partitions=8)
    journal.extend((ts, k, k) for ts, k, _ in events)
    p = Pipeline.create()
    (p.read_from(lambda: JournalSource(journal, rate=150.0), name="src")
       .with_key(lambda v: v)
       .window(session(gap))
       .aggregate(counting())
       .write_to(lambda: CollectorSink(out)))
    cluster = JetCluster(n_nodes=3, cooperative_threads=2,
                         clock=VirtualClock(auto_step=0.01))
    job = cluster.submit(p.to_dag(),
                         JobConfig(processing_guarantee=GUARANTEE_EXACTLY_ONCE,
                                   snapshot_interval_s=0.05))
    for _ in range(20000):
        cluster.step()
        if job.snapshots_taken >= 1:
            break
    assert job.snapshots_taken >= 1, "no snapshot committed before failure"
    cluster.kill_node(1)
    cluster.run_until_complete(job)
    oracle = session_oracle([(ts, k, k) for ts, k, _ in events], gap)
    expect = {(k, s, e): c for k, ss in oracle.items() for s, e, c in ss}
    got = {}
    for ev in out:
        sr = ev.value
        key = (sr.key, sr.window_start, sr.window_end)
        # exactly-once state: every emission carries the exact count
        # (results between last snapshot and failure re-emit identically)
        assert expect[key] == sr.value, key
        got[key] = sr.value
    assert got == expect


# ---------------------------------------------------------------------------
# Q12: processing-time windows
# ---------------------------------------------------------------------------


def test_q12_processing_time_windows_count_all_bids():
    n = 2000
    gen = NexmarkGenerator(rate=10_000, n_keys=25)
    events = [gen(i) for i in range(n)]
    out = []
    p = queries.q12(lambda: JournalSource(journal_of(events, 8)),
                    lambda: CollectorSink(out), window_ms=50)
    run_job(p, n_nodes=2)
    n_bids = sum(1 for _t, _k, v in events if isinstance(v, Bid))
    per_key = {}
    for ev in out:
        fend, key, count = ev.value
        per_key[key] = per_key.get(key, 0) + count
    # processing-time windows partition arrivals: totals must be exact
    assert sum(per_key.values()) == n_bids
    oracle_keys = {v.bidder for _t, _k, v in events if isinstance(v, Bid)}
    assert set(per_key) == oracle_keys


# ---------------------------------------------------------------------------
# disordered generator unit properties
# ---------------------------------------------------------------------------


def test_latency_histogram_p9999_gated_on_sample_count():
    """<10k samples: the p99.99 is 'roughly the max of a small run', so the
    report must say null + warning instead of printing a number."""
    from benchmarks.bench_latency import LatencyHistogram, P9999_MIN_SAMPLES

    h = LatencyHistogram()
    for v in range(5000):
        h.record(v)
    s = h.summary_ms()
    assert s["p99.99"] is None
    assert "unreliable" in s["warning"]
    assert s["p99.9"] is not None         # other percentiles still report
    h2 = LatencyHistogram()
    for v in range(P9999_MIN_SAMPLES):
        h2.record(1000)
    s2 = h2.summary_ms()
    assert s2["p99.99"] is not None
    assert "warning" not in s2


def test_disordered_generator_is_deterministic():
    gen = NexmarkGenerator(rate=5000, n_keys=10)
    a = DisorderedNexmarkGenerator(gen, max_skew_ms=50, seed=42)
    b = DisorderedNexmarkGenerator(gen, max_skew_ms=50, seed=42)
    c = DisorderedNexmarkGenerator(gen, max_skew_ms=50, seed=43)
    def key_of(t):
        ts, key, value = t
        return (ts, key, repr(value))  # model values compare by identity

    xs = [key_of(a(i)) for i in range(1000)]
    # random access (replay from an offset) agrees with sequential access
    assert [key_of(b(i)) for i in range(999, -1, -1)][::-1] == xs
    assert [key_of(c(i)) for i in range(1000)] != xs
