"""Self-healing execution: failures the engine did NOT schedule.

``kill_node`` (tests/test_mp_backend.py) is a cooperative failure — an
API call the test makes.  This file covers the *detected* path: a worker
SIGKILL'd / hung / raising mid-run with no API call must be classified
by the supervisor, routed through the bounded RestartPolicy, healed from
the last committed snapshot, and the exactly-once results must equal an
unfailed run.  Barrier robustness rides along: a snapshot whose acks are
lost (dead worker, dropped or late ack, broken pipe mid-broadcast) is
ABORTED — never stalls the job, never commits partial state.
"""

import multiprocessing as mp
import signal
import time
from types import SimpleNamespace

import pytest

from repro.core import (CollectorSink, JetCluster, JobConfig,
                        PacedGeneratorSource, GUARANTEE_EXACTLY_ONCE,
                        GUARANTEE_NONE)
from repro.core.backend import (FAILURE_CRASHED, FAILURE_ERROR,
                                FAILURE_HUNG)
from repro.core.engine import (JOB_COMPLETED, JOB_FAILED, JobFailedError,
                               RestartPolicy)
from repro.nexmark import NexmarkGenerator, queries
from repro.runtime.supervisor import WorkerSupervisor
from repro.runtime.worker_proc import (MpSnapshotContext,
                                       MultiprocessBackend,
                                       _kill_handle_hard)

RATE = 60_000
# 0.8s of paced event time: wide enough that a fault injected once the
# first snapshot committed (~0.15s in) always finds live mid-run workers,
# and a 0.4s barrier-ack deadline expires while the job is still running
TOTAL = 48_000


def _dedup(out):
    return sorted(set((ev.ts, ev.key, ev.value.window_end, ev.value.value)
                      for ev in out))


def _run_q5_fault(backend, fault=None, fault_at=200, guarantee="none",
                  n_nodes=2, threads=2, restart_policy=None,
                  barrier_timeout_s=5.0, expect_completed=True,
                  fault_params=None, gate="commit",
                  snapshot_interval_s=0.1):
    """Paced Q5; inject one fault via the backend's chaos seam once the
    sink holds ``fault_at`` results and a snapshot has committed
    (``gate="commit"``) or merely been requested (``gate="barrier"`` —
    for ack faults, which must land while barriers are still in flight
    and must not depend on a commit having beaten the ack deadline).
    Returns (deduped results, job, late-drop tally)."""
    cluster = JetCluster(n_nodes=n_nodes, cooperative_threads=threads,
                         backend=backend)
    out = []
    p = queries.q5(
        lambda: PacedGeneratorSource(NexmarkGenerator(rate=RATE, n_keys=40),
                                     rate=RATE, max_events=TOTAL),
        lambda: CollectorSink(out), window_ms=100, slide_ms=20)
    cfg = JobConfig(processing_guarantee=guarantee,
                    snapshot_interval_s=snapshot_interval_s,
                    restart_policy=restart_policy,
                    barrier_timeout_s=barrier_timeout_s)
    job = cluster.submit(p.to_dag(), cfg)
    injected = False
    deadline = time.monotonic() + 120.0
    try:
        for _ in range(4_000_000):
            if job.status in (JOB_COMPLETED, JOB_FAILED):
                break
            if time.monotonic() > deadline:
                ssctx = (job.execution.ssctx
                         if job.execution is not None else None)
                workers = {}
                if job.execution is not None:
                    workers = {
                        h.key: (h.alive, h.done, h.proc.exitcode)
                        for h in job.execution.backend_data.get(
                            "workers", {}).values()}
                raise TimeoutError(
                    f"job stuck in status {job.status}: results={len(out)} "
                    f"snapshots={job.snapshots_taken} "
                    f"aborted={job.snapshots_aborted} "
                    f"auto_restarts={job.auto_restarts} "
                    f"failures={job.failures} "
                    f"ssctx=({getattr(ssctx, 'requested_id', None)},"
                    f"{getattr(ssctx, 'completed_id', None)}) "
                    f"workers={workers}")
            cluster.step()
            if (fault is not None and not injected
                    and job.execution is not None
                    and len(out) >= fault_at
                    and (job.snapshots_taken > 0
                         or (gate == "barrier"
                             and job.execution.ssctx is not None
                             and job.execution.ssctx.requested_id >= 1)
                         or guarantee == GUARANTEE_NONE)):
                injected = cluster.backend.inject_fault(
                    job.execution, fault, 0, **(fault_params or {}))
        if fault is not None:
            assert injected, "fault was never injected — test setup broken"
        if expect_completed:
            assert job.status == JOB_COMPLETED
        drops = 0
        if job.execution is not None:
            drops = sum(getattr(t.processor, "late_dropped", 0)
                        for t in job.execution.tasklets)
    finally:
        cluster.shutdown()
    return _dedup(out), job, drops


@pytest.fixture(scope="module")
def clean_q5():
    """One unfailed exactly-once run all healing tests compare against."""
    results, job, drops = _run_q5_fault("mp",
                                        guarantee=GUARANTEE_EXACTLY_ONCE)
    assert len(results) > 0 and drops == 0
    return results


# --------------------------------------------------------------- detection --

@pytest.mark.slow
def test_mp_sigkill_detected_and_healed(clean_q5):
    """Acceptance: a worker SIGKILL'd mid-run (no API call) is detected,
    the job auto-restores from the committed snapshot, and the deduped
    sink output equals the unfailed run exactly."""
    results, job, drops = _run_q5_fault(
        "mp", fault="kill", guarantee=GUARANTEE_EXACTLY_ONCE)
    assert results == clean_q5
    assert drops == 0
    assert job.auto_restarts >= 1
    kinds = [f.kind for f in job.failures]
    assert FAILURE_CRASHED in kinds
    crashed = next(f for f in job.failures if f.kind == FAILURE_CRASHED)
    assert crashed.exitcode is not None and crashed.exitcode < 0


@pytest.mark.slow
def test_mp_error_exit_detected_with_traceback(clean_q5):
    """A processor raising inside a worker ships its traceback to the
    coordinator, is classified as an error exit, and heals."""
    results, job, _ = _run_q5_fault(
        "mp", fault="raise", guarantee=GUARANTEE_EXACTLY_ONCE,
        fault_params={"message": "chaos-injected failure"})
    assert results == clean_q5
    assert job.auto_restarts >= 1
    errors = [f for f in job.failures if f.kind == FAILURE_ERROR]
    assert errors and "chaos-injected failure" in errors[0].detail


@pytest.mark.slow
def test_mp_hung_worker_detected_and_healed(clean_q5):
    """A SIGSTOPped worker stops heartbeating; the supervisor SIGKILLs it
    after the deadline and the job heals."""
    backend = MultiprocessBackend(heartbeat_timeout_s=1.0)
    results, job, _ = _run_q5_fault(
        backend, fault="stall", guarantee=GUARANTEE_EXACTLY_ONCE)
    assert results == clean_q5
    assert job.auto_restarts >= 1
    assert FAILURE_HUNG in [f.kind for f in job.failures]


def test_inproc_injected_exception_healed():
    """The in-process substrate's uncooperative failure (an exception out
    of a cooperative slice) is detected and healed identically."""
    clean, _, _ = _run_q5_fault("inproc", guarantee=GUARANTEE_EXACTLY_ONCE)
    results, job, drops = _run_q5_fault(
        "inproc", fault="raise", guarantee=GUARANTEE_EXACTLY_ONCE)
    assert results == clean and len(clean) > 0
    assert drops == 0
    assert job.auto_restarts >= 1
    assert FAILURE_ERROR in [f.kind for f in job.failures]


# ------------------------------------------------------------ restart policy --

def test_restart_budget_exhausted_is_terminal():
    """With a zero restart budget one detected failure is terminal:
    status FAILED, no healing loop, run_until_complete raises."""
    _, job, _ = _run_q5_fault(
        "inproc", fault="kill", guarantee=GUARANTEE_EXACTLY_ONCE,
        restart_policy=RestartPolicy(max_restarts=0),
        expect_completed=False)
    assert job.status == JOB_FAILED
    assert job.auto_restarts == 0
    assert job.failures
    with pytest.raises(JobFailedError, match="FAILED after 0 automatic"):
        raise JobFailedError(job)


def test_no_guarantee_detected_failure_fails_fast():
    """Without a snapshot guarantee there is nothing to restore from — a
    detected failure fails the job instead of replaying into sinks that
    already saw the stream."""
    _, job, _ = _run_q5_fault(
        "inproc", fault="kill", guarantee=GUARANTEE_NONE,
        expect_completed=False)
    assert job.status == JOB_FAILED
    assert job.auto_restarts == 0


def test_restart_policy_backoff_schedule():
    p = RestartPolicy(max_restarts=5, backoff_base_s=0.1, backoff_max_s=0.5)
    assert p.delay_for(1) == pytest.approx(0.1)
    assert p.delay_for(2) == pytest.approx(0.2)
    assert p.delay_for(3) == pytest.approx(0.4)
    assert p.delay_for(4) == pytest.approx(0.5)   # capped
    assert p.delay_for(10) == pytest.approx(0.5)


# ---------------------------------------------------------- barrier aborts --

class _FakeBackend:
    """MpSnapshotContext collaborator double: scripted broadcast."""

    def __init__(self, reached=(), failed=()):
        self.reached = set(reached)
        self.failed = set(failed)
        self.sent = []

    def broadcast(self, execution, message):
        self.sent.append(message)
        return set(self.reached), set(self.failed)


def _mp_ctx(backend, timeout=None):
    ctx = MpSnapshotContext(GUARANTEE_EXACTLY_ONCE, store_writer=None)
    ctx.backend = backend
    ctx.execution = None
    ctx.ack_timeout_s = timeout
    return ctx


def test_broadcast_broken_pipe_aborts_inflight():
    """Regression (satellite): a barrier broadcast that cannot reach a
    not-yet-done worker must abort the snapshot, not wait for an ack that
    will never come."""
    committed = []
    ctx = _mp_ctx(_FakeBackend(reached={(0, 0)}, failed={(0, 1)}))
    ctx.on_complete = committed.append
    ctx.begin(7)
    assert ctx.aborted_count == 1
    assert ctx.completed_id == 7          # freed, not stalled
    assert committed == []                # but never committed
    # a late ack for the aborted snapshot is ignored
    ctx.worker_ack((0, 0), 7, [(7, "v", "k", 1, 0, 0)])
    assert committed == [] and ctx._entries == []


def test_worker_death_mid_barrier_aborts_then_next_commits():
    backend = _FakeBackend(reached={(0, 0), (0, 1)})
    committed = []
    ctx = _mp_ctx(backend)
    ctx.on_complete = committed.append
    ctx.begin(1)
    ctx.worker_ack((0, 0), 1, [(1, "v", "k", 1, 0, 0)])
    ctx.worker_gone((0, 1), crashed=True)   # died holding its barrier
    assert ctx.aborted_count == 1 and committed == []
    # the next snapshot is unaffected and commits normally
    ctx.begin(2)
    ctx.worker_ack((0, 0), 2, [])
    ctx.worker_ack((0, 1), 2, [])
    assert committed == [2] and ctx.aborted_count == 1


def test_done_worker_is_barrier_exempt():
    ctx = _mp_ctx(_FakeBackend(reached={(0, 0), (0, 1)}))
    committed = []
    ctx.on_complete = committed.append
    ctx.begin(1)
    ctx.worker_ack((0, 0), 1, [])
    ctx.worker_gone((0, 1), crashed=False)  # clean DONE: no state owed
    assert committed == [1] and ctx.aborted_count == 0


def test_barrier_ack_deadline_aborts():
    ctx = _mp_ctx(_FakeBackend(reached={(0, 0)}), timeout=0.01)
    committed = []
    ctx.on_complete = committed.append
    ctx.begin(3)
    assert not ctx.check_timeout()          # not yet due
    time.sleep(0.03)
    assert ctx.check_timeout()
    assert ctx.aborted_count == 1 and committed == []
    assert not ctx.check_timeout()          # idempotent once aborted


@pytest.mark.slow
def test_mp_dropped_ack_aborts_snapshot_and_completes(clean_q5):
    """A dropped barrier ack only costs that snapshot: it aborts at the
    deadline, later snapshots commit, the job completes exactly-once."""
    # inject right after the first commit: late in the run every worker
    # may already be DONE (barrier-exempt), leaving no ack to intercept
    results, job, _ = _run_q5_fault(
        "mp", fault="drop_ack", guarantee=GUARANTEE_EXACTLY_ONCE,
        barrier_timeout_s=0.4, fault_at=0, gate="barrier")
    assert results == clean_q5
    assert job.snapshots_aborted >= 1
    assert job.auto_restarts == 0           # nobody died — no restart


@pytest.mark.slow
def test_mp_rapid_aborts_never_wedge_alignment(clean_q5):
    """Regression: when the coordinator aborts barrier n on its deadline
    and begins n+1 before a descheduled worker drained its command pipe,
    that worker used to begin(n+1) straight over begin(n) — its sources
    never emitted barrier n, siblings that DID forward n left downstream
    queues parked on mixed generations, and the job wedged forever with
    heartbeats still flowing.  Children now serialize barrier
    generations (every id emitted, in order), so a run whose tiny
    deadline and interval force many overlapping abort/begin pairs must
    still complete, exactly-once."""
    results, job, drops = _run_q5_fault(
        "mp", guarantee=GUARANTEE_EXACTLY_ONCE,
        barrier_timeout_s=0.05, snapshot_interval_s=0.02)
    assert results == clean_q5
    assert drops == 0


@pytest.mark.slow
def test_mp_late_ack_after_abort_is_ignored(clean_q5):
    """An ack delayed past the deadline arrives for an already-aborted
    snapshot and must be discarded, not half-commit stale state."""
    results, job, _ = _run_q5_fault(
        "mp", fault="delay_ack", guarantee=GUARANTEE_EXACTLY_ONCE,
        barrier_timeout_s=0.3, fault_at=0, gate="barrier",
        fault_params={"delay_s": 0.8})
    assert results == clean_q5
    assert job.snapshots_aborted >= 1


# -------------------------------------------------------------- supervisor --

def _handle(key, exitcode=None, pid=4_000_000, done=False):
    return SimpleNamespace(key=key, done=done,
                           proc=SimpleNamespace(exitcode=exitcode, pid=pid))


def test_supervisor_classifies_exitcodes():
    sup = WorkerSupervisor(heartbeat_timeout_s=5.0)
    handles = [_handle((0, 0), exitcode=-9),
               _handle((0, 1), exitcode=3),
               _handle((1, 0), exitcode=-9, done=True),   # exempt: DONE
               _handle((1, 1), exitcode=None)]            # alive, fine
    for h in handles:
        sup.worker_started(h.key, now=0.0)
    fails = sup.check(handles, now=1.0)
    assert {(f.kind, f.key) for f in fails} == {
        (FAILURE_CRASHED, (0, 0)), (FAILURE_ERROR, (0, 1))}
    # each failure reports exactly once
    assert sup.check(handles, now=2.0) == []


def test_supervisor_mark_reported_suppresses():
    sup = WorkerSupervisor()
    h = _handle((0, 0), exitcode=1)
    sup.worker_started(h.key, now=0.0)
    sup.mark_reported(h.key)    # drain loop already saw ("error", tb)
    assert sup.check([h], now=1.0) == []


def test_supervisor_kills_hung_worker():
    """A live process with a stale heartbeat is classified HUNG and
    SIGKILLed so it cannot hold rings/barriers hostage."""
    proc = mp.get_context("fork").Process(target=time.sleep, args=(60,))
    proc.start()
    try:
        sup = WorkerSupervisor(heartbeat_timeout_s=0.5)
        h = SimpleNamespace(key=(0, 0), done=False, proc=proc)
        sup.worker_started(h.key, now=0.0)
        sup.heartbeat(h.key, now=1.0)
        fails = sup.check([h], now=10.0)
        assert [f.kind for f in fails] == [FAILURE_HUNG]
        proc.join(timeout=5.0)
        assert proc.exitcode == -signal.SIGKILL
    finally:
        if proc.is_alive():     # pragma: no cover - cleanup on failure
            proc.kill()
            proc.join()


# ----------------------------------------------------- shutdown escalation --

def _ignore_sigterm_and_sleep():
    signal.signal(signal.SIGTERM, signal.SIG_IGN)
    while True:
        time.sleep(0.1)


def test_stop_escalates_to_sigkill_on_stuck_worker():
    """Satellite: shutdown can never hang on a wedged worker — after
    terminate() fails, the backend escalates to SIGKILL."""
    proc = mp.get_context("fork").Process(target=_ignore_sigterm_and_sleep)
    proc.start()
    try:
        time.sleep(0.2)                 # let the child install its handler
        proc.terminate()
        proc.join(timeout=1.0)
        assert proc.exitcode is None    # survived SIGTERM: truly stuck
        _kill_handle_hard(proc)
        assert proc.exitcode == -signal.SIGKILL
    finally:
        if proc.is_alive():     # pragma: no cover - cleanup on failure
            proc.kill()
            proc.join()
