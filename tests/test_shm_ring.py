"""Shared-memory ring transport: wire-format round-trips plus a seeded
randomized stress suite run against BOTH transports (in-process
``SPSCQueue`` and cross-process ``ShmRing``) through one oracle — the two
must be observably identical FIFO transports under arbitrary offer/poll
interleavings, including ring wraparound, PAD records, control-item
segregation in ``poll_prefix``, and the ``has_room_for`` all-or-nothing
admission guarantee."""

import pickle
import random

import numpy as np
import pytest

from repro.core.events import (Barrier, DONE, Event, EventBlock, LateEvent,
                               Watermark)
from repro.core.queues import SPSCQueue
from repro.core.shm_ring import DEFAULT_RING_BYTES, ShmRing


# ---------------------------------------------------------------------------
# EventBlock wire format
# ---------------------------------------------------------------------------

def _block(n=16, value=True, payload=None, payload_fn=None, cols=True):
    return EventBlock(
        np.arange(n, dtype=np.int64) * 3,
        (np.arange(n, dtype=np.int64) * 7) % 5,
        np.arange(n, dtype=np.float64) * 1.5 if value else None,
        payload=payload, payload_fn=payload_fn,
        cols={"kind": np.arange(n, dtype=np.int8) % 3,
              "seq": np.arange(n, dtype=np.int64) + 1000} if cols else None)


def _assert_blocks_equal(a: EventBlock, b: EventBlock):
    assert a.ts.tolist() == b.ts.tolist()
    assert a.key.tolist() == b.key.tolist()
    if a.value is None:
        assert b.value is None
    else:
        assert a.value.tolist() == b.value.tolist()
    a_cols = a.cols or {}
    b_cols = b.cols or {}
    assert sorted(a_cols) == sorted(b_cols)
    for name in a_cols:
        assert a_cols[name].dtype == b_cols[name].dtype
        assert a_cols[name].tolist() == b_cols[name].tolist()


def test_wire_roundtrip_plain():
    blk = _block()
    out = EventBlock.from_wire(blk.to_wire())
    _assert_blocks_equal(blk, out)
    assert out.payload is None and out.payload_fn is None


def test_wire_roundtrip_no_value_no_cols():
    blk = _block(value=False, cols=False)
    out = EventBlock.from_wire(blk.to_wire())
    _assert_blocks_equal(blk, out)


def test_wire_roundtrip_payload_list():
    blk = _block(8, payload=[f"v{i}" for i in range(8)])
    out = EventBlock.from_wire(blk.to_wire())
    _assert_blocks_equal(blk, out)
    assert out.values() == blk.payload


def test_wire_roundtrip_picklable_payload_fn():
    from repro.nexmark.generator import NexmarkGenerator
    gen = NexmarkGenerator(rate=1000, n_keys=10)
    blk = gen.gen_block(np.arange(50, dtype=np.int64))
    out = EventBlock.from_wire(blk.to_wire())
    _assert_blocks_equal(blk, out)
    # the lazy materializer itself travels: values rebuilt on the far side
    assert [type(v).__name__ for v in out.values()] == \
        [type(v).__name__ for v in blk.values()]


def test_wire_fallback_materializes_unpicklable_payload_fn():
    blk = _block(4, payload_fn=lambda b, i: ("row", int(b.cols["seq"][i])))
    with pytest.raises(Exception):
        pickle.dumps(blk.payload_fn)
    out = EventBlock.from_wire(blk.to_wire())
    # closure could not travel -> payload was materialized instead
    assert out.values() == blk.values()


def test_wire_copy_decouples_from_buffer():
    blk = _block()
    buf = bytearray(blk.to_wire())
    out = EventBlock.from_wire(buf)
    before = out.ts.tolist()
    buf[:] = b"\x00" * len(buf)     # ring memory gets recycled
    assert out.ts.tolist() == before


# ---------------------------------------------------------------------------
# Ring basics: wraparound, pads, lifecycle
# ---------------------------------------------------------------------------

@pytest.fixture
def small_ring():
    ring = ShmRing(capacity_bytes=1 << 12)
    yield ring
    ring.unlink()
    ring.close()


def test_ring_fifo_and_wraparound(small_ring):
    """Push far more bytes than capacity through a small ring; every item
    must come out once, in order, across many physical wraps."""
    ring = small_ring
    rng = random.Random(7)
    pending = []
    sent = recv = 0
    while sent < 3000 or pending:
        if sent < 3000 and (not pending or rng.random() < 0.6):
            item = Event(sent, sent % 13, float(sent))
            if ring.offer(item):
                pending.append(sent)
                sent += 1
        else:
            got = ring.poll()
            if got is not None:
                assert got.ts == pending.pop(0)
                recv += 1
            else:
                assert not pending or sent < 3000
    assert recv == 3000 and ring.is_empty()


def test_ring_oversize_item_rejected(small_ring):
    big = EventBlock(np.arange(4096, dtype=np.int64),
                     np.arange(4096, dtype=np.int64))
    with pytest.raises(ValueError):
        small_ring.offer(big)


def test_ring_attach_sees_producer_writes():
    ring = ShmRing(capacity_bytes=1 << 12)
    other = ring.attach()
    try:
        assert ring.offer(Watermark(42))
        got = other.poll()
        assert isinstance(got, Watermark) and got.ts == 42
        assert ring.is_empty()
    finally:
        other.close()
        ring.unlink()
        ring.close()


def test_ring_not_picklable(small_ring):
    with pytest.raises(TypeError):
        pickle.dumps(small_ring)


def test_has_room_for_admission_guarantee(small_ring):
    """The transport contract: has_room_for(x) True => offer(x) succeeds.
    Fill until it says no, then verify offer agrees, then drain one and
    re-check — the all-or-nothing primitive block routing relies on."""
    ring = small_ring
    blk = _block(48)
    n = 0
    while ring.has_room_for(blk):
        assert ring.offer(blk)
        n += 1
        assert n < 100, "ring never filled"
    assert not ring.offer(blk)
    assert ring.poll() is not None
    assert ring.has_room_for(blk) and ring.offer(blk)


# ---------------------------------------------------------------------------
# Randomized oracle: SPSCQueue and ShmRing must behave identically
# ---------------------------------------------------------------------------

def _canon(item):
    """Canonical comparable form of any transport item."""
    cls = item.__class__
    if cls is EventBlock:
        return ("B", item.ts.tolist(), item.key.tolist(),
                None if item.value is None else item.value.tolist(),
                sorted((k, v.tolist()) for k, v in (item.cols or {}).items()))
    if cls is LateEvent:
        return ("L", item.ts, item.key, item.value)
    if isinstance(item, Event):
        return ("E", item.ts, item.key, item.value)
    if cls is Watermark:
        return ("W", item.ts)
    if cls is Barrier:
        return ("X", item.snapshot_id, item.terminal)
    if item is DONE:
        return ("D",)
    return ("P", item)


def _random_item(rng):
    roll = rng.random()
    if roll < 0.45:
        value = (rng.randrange(-10**6, 10**6) if rng.random() < 0.5
                 else rng.random() * 100)
        return Event(rng.randrange(10**6), rng.randrange(64), value)
    if roll < 0.70:
        n = rng.randrange(1, 40)
        return EventBlock(
            np.sort(np.asarray(
                [rng.randrange(10**6) for _ in range(n)], dtype=np.int64)),
            np.asarray([rng.randrange(64) for _ in range(n)],
                       dtype=np.int64),
            np.asarray([rng.random() for _ in range(n)], dtype=np.float64)
            if rng.random() < 0.7 else None,
            cols={"seq": np.arange(n, dtype=np.int64)}
            if rng.random() < 0.5 else None)
    if roll < 0.80:
        return Watermark(rng.randrange(10**6))
    if roll < 0.88:
        return Barrier(rng.randrange(1, 100), rng.random() < 0.1)
    if roll < 0.92:
        return DONE
    if roll < 0.96:
        return LateEvent(rng.randrange(10**6), rng.randrange(64), "late")
    return ("tuple", rng.randrange(100), [rng.random()])


def _is_data(item):
    return isinstance(item, (Event, EventBlock))


def _model_poll_prefix(model, limit, explode):
    """Reference semantics of poll_prefix over the pending-item model."""
    events, ctrl, k = [], None, 0
    while k < limit and model:
        item = model[0]
        k += 1
        if _is_data(item):
            model.pop(0)
            if item.__class__ is EventBlock and explode:
                events.extend(item.to_events())
            else:
                events.append(item)
        else:
            ctrl = model.pop(0)
            break
    return events, ctrl


@pytest.mark.parametrize("make", [
    pytest.param(lambda: SPSCQueue(64), id="spsc"),
    pytest.param(lambda: ShmRing(1 << 14), id="shm_ring"),
])
@pytest.mark.parametrize("seed", range(6))
def test_transport_oracle_random_interleavings(make, seed):
    q = make()
    rng = random.Random(1000 + seed)
    model = []          # items offered and not yet observed
    offered = polled = 0
    try:
        for _ in range(2500):
            op = rng.random()
            if op < 0.40:
                item = _random_item(rng)
                fits = q.has_room_for(item)
                ok = q.offer(item)
                assert ok or not fits, \
                    "has_room_for promised room but offer failed"
                if ok:
                    model.append(item)
                    offered += 1
            elif op < 0.60:
                got = q.poll()
                if got is None:
                    assert not model
                else:
                    assert _canon(got) == _canon(model.pop(0))
                    polled += 1
            elif op < 0.70:
                got = q.peek()
                if got is None:
                    assert not model
                else:
                    assert _canon(got) == _canon(model[0])
            elif op < 0.80:
                limit = rng.randrange(1, 8)
                got = q.poll_many(limit)
                assert len(got) <= limit
                for item in got:
                    assert _canon(item) == _canon(model.pop(0))
                polled += len(got)
            else:
                limit = rng.randrange(1, 8)
                explode = rng.random() < 0.5
                events, ctrl = q.poll_prefix(limit, explode_blocks=explode)
                ref_events, ref_ctrl = _model_poll_prefix(model, limit,
                                                          explode)
                assert [_canon(e) for e in events] == \
                    [_canon(e) for e in ref_events]
                assert (ctrl is None) == (ref_ctrl is None)
                if ctrl is not None:
                    assert _canon(ctrl) == _canon(ref_ctrl)
            assert len(q) == len(model)
            assert q.is_empty() == (not model)
        # drain and verify the tail
        while model:
            got = q.poll()
            assert got is not None
            assert _canon(got) == _canon(model.pop(0))
        assert q.poll() is None
        assert offered > 200 and polled > 100, "degenerate interleaving"
    finally:
        if isinstance(q, ShmRing):
            q.unlink()
            q.close()


@pytest.mark.parametrize("make", [
    pytest.param(lambda: SPSCQueue(4), id="spsc"),
    pytest.param(lambda: ShmRing(1 << 9), id="shm_ring"),
])
@pytest.mark.parametrize("seed", range(4))
def test_transport_oracle_capacity_edge(make, seed):
    """Tiny capacity: constant full/empty transitions exercise the
    admission boundary and (for the ring) the PAD/wrap corner cases."""
    q = make()
    rng = random.Random(7000 + seed)
    model = []
    rejections = 0
    try:
        for i in range(4000):
            if rng.random() < 0.55:
                item = (Event(i, i % 7, float(i)) if rng.random() < 0.7
                        else Watermark(i))
                fits = q.has_room_for(item)
                ok = q.offer(item)
                assert ok or not fits
                if ok:
                    model.append(item)
                else:
                    rejections += 1
            else:
                got = q.poll()
                if got is None:
                    assert not model
                else:
                    assert _canon(got) == _canon(model.pop(0))
        assert rejections > 50, "capacity edge never reached"
    finally:
        if isinstance(q, ShmRing):
            q.unlink()
            q.close()


def test_default_ring_capacity_holds_full_blocks():
    """The sized-for-the-workload claim: a default ring admits several
    full 4096-row generator blocks back to back."""
    from repro.nexmark.generator import NexmarkGenerator
    gen = NexmarkGenerator(rate=60_000)
    ring = ShmRing(DEFAULT_RING_BYTES)
    try:
        n = 0
        blk = gen.gen_block(np.arange(4096, dtype=np.int64))
        while ring.has_room_for(blk):
            assert ring.offer(blk)
            n += 1
            blk = gen.gen_block(np.arange(4096, dtype=np.int64) + n * 4096)
        assert n >= 4
        for i in range(n):
            got = ring.poll()
            assert got.ts[0] == i * 4096 * 1000 // 60_000
    finally:
        ring.unlink()
        ring.close()
